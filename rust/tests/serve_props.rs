//! Property suite for the serving path (`kcd::serve`, `kcd::model`),
//! pinning the serving determinism contract (see `crate::serve`):
//!
//! * **Engine ≡ reference bitwise** — `predict_batch` routed through
//!   `ServeProduct` + `ParallelProduct` + the kernel-row cache returns
//!   the naive rowwise reference's bits for every kernel × thread count
//!   (1, 4, and the CI lane's `THREADS`) × cache capacity × batch
//!   split, on both the sparse (transpose) and dense (blocked) product
//!   paths.
//! * **Save → load → predict roundtrip** — a `.kcd` save reproduces the
//!   pre-save predictions bitwise, including when the training rows are
//!   first extracted from `GridStorage::Sharded` cells at every
//!   `(pr, pc)` factorization of `P ∈ {2, …, 8}` (and the CI lane's
//!   `GRID` row count): the sharded-assembled save is *byte*-identical
//!   to the replicated one.
//! * **Support-vector compaction edges** — an all-zero-α K-SVM model
//!   saves, loads, and predicts zeros without panicking; bound-α rows
//!   are retained; K-RR models are never compacted; and the compacted
//!   model's predictions equal the uncompacted full-coefficient sum
//!   bitwise (`f += 0 · k` preserves bits).
//! * **Corruption is loud** — truncation, version/kind mismatches, and
//!   header inconsistencies are hard errors naming the offending field,
//!   never silent garbage.
//! * **CLI end to end** — `kcd train-svm --save` + `kcd predict` work
//!   through `cli::run`, and a sharded-grid save serves the same
//!   response bits as the 1D run it is contracted to reproduce.

use kcd::costmodel::Ledger;
use kcd::data::{gen_dense_classification, gen_uniform_sparse, Dataset, SynthParams, Task};
use kcd::kernelfn::Kernel;
use kcd::model::{KrrModel, SvmModel};
use kcd::serve::format::{assemble_cells, shard_cells, ModelKind};
use kcd::serve::{parse_requests, LoadedModel, PredictOptions, Predictor};
use kcd::sparse::Csr;
use kcd::testkit;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A synthetic dual with zeros (compacted away), interior values, and a
/// bound coordinate — the three α regimes a save must handle.
fn synth_alpha(m: usize) -> Vec<f64> {
    (0..m)
        .map(|i| {
            if i % 3 == 0 {
                0.0
            } else if i % 7 == 0 {
                1.0 // at the box bound C = 1: must be retained
            } else {
                ((i * 5) % 11) as f64 / 11.0
            }
        })
        .collect()
}

fn kernels() -> [Kernel; 3] {
    [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()]
}

/// Every (pr, pc) with pr·pc == p, in deterministic order.
fn factorizations(p: usize) -> Vec<(usize, usize)> {
    (1..=p).filter(|pr| p % pr == 0).map(|pr| (pr, p / pr)).collect()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("kcd_serve_props");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Engine-routed prediction ≡ naive reference, bitwise, across kernels
/// × threads × cache × batch split, on sparse and dense training data.
#[test]
fn prop_predict_batch_bitwise_equals_reference() {
    let sparse = gen_uniform_sparse(
        SynthParams {
            m: 40,
            n: 18,
            density: 0.15,
            seed: 21,
        },
        Task::Classification,
    );
    let dense = gen_dense_classification(40, 10, 0.02, 22);
    let threads = {
        let mut t = vec![1, 4];
        let env = testkit::env_threads();
        if !t.contains(&env) {
            t.push(env);
        }
        t
    };
    for ds in [&sparse, &dense] {
        let alpha = synth_alpha(ds.m());
        let queries = gen_uniform_sparse(
            SynthParams {
                m: 13,
                n: ds.n(),
                density: 0.4,
                seed: 23,
            },
            Task::Classification,
        )
        .a;
        for kernel in kernels() {
            let svm = SvmModel::from_dual(ds, &alpha, kernel);
            let krr = KrrModel::from_dual(ds, &alpha, kernel, 0.5);
            let svm_ref = bits(&svm.decision_function(&queries));
            let krr_ref = bits(&krr.predict(&queries));
            for &t in &threads {
                for cache in [0, 8] {
                    for batch in [0, 1, 7] {
                        let opts = PredictOptions {
                            threads: t,
                            cache_rows: cache,
                            batch,
                        };
                        let got = svm.predict_batch(&queries, &opts, &mut Ledger::new());
                        assert_eq!(
                            bits(&got),
                            svm_ref,
                            "svm {} t={t} cache={cache} batch={batch}",
                            kernel.name()
                        );
                        let got = krr.predict_batch(&queries, &opts, &mut Ledger::new());
                        assert_eq!(
                            bits(&got),
                            krr_ref,
                            "krr {} t={t} cache={cache} batch={batch}",
                            kernel.name()
                        );
                    }
                }
            }
        }
    }
}

/// Save → load → predict reproduces the pre-save bits for both model
/// kinds, and the sharded-cell extraction path produces byte-identical
/// files at every factorization of P ∈ {2, …, 8} (plus the CI lane's
/// GRID point) × row-block.
#[test]
fn prop_kcd_roundtrip_and_sharded_extraction_are_bitwise() {
    let ds = gen_uniform_sparse(
        SynthParams {
            m: 30,
            n: 12,
            density: 0.25,
            seed: 31,
        },
        Task::Classification,
    );
    let alpha = synth_alpha(ds.m());
    let queries = gen_dense_classification(9, 12, 0.02, 32).a;
    let kernel = Kernel::paper_rbf();

    // Replicated-path roundtrip, both kinds.
    let svm = SvmModel::from_dual(&ds, &alpha, kernel);
    let path = tmp("roundtrip_svm.kcd");
    svm.save_kcd(&path).unwrap();
    let back = SvmModel::load_kcd(&path).unwrap();
    assert_eq!(back.n_support(), svm.n_support());
    assert_eq!(
        bits(&back.decision_function(&queries)),
        bits(&svm.decision_function(&queries)),
        "svm roundtrip must be bitwise"
    );
    let krr = KrrModel::from_dual(&ds, &alpha, kernel, 0.5);
    let kpath = tmp("roundtrip_krr.kcd");
    krr.save_kcd(&kpath).unwrap();
    let kback = KrrModel::load_kcd(&kpath).unwrap();
    assert_eq!(kback.lambda(), 0.5);
    assert_eq!(
        bits(&kback.predict(&queries)),
        bits(&krr.predict(&queries)),
        "krr roundtrip must be bitwise"
    );
    let replicated_bytes = std::fs::read(&path).unwrap();

    // Sharded extraction: reassembling the training matrix from its
    // block-cyclic cells then saving must produce the same file bytes.
    let mut grids: Vec<(usize, usize)> = (2..=8).flat_map(factorizations).collect();
    let env_pr = testkit::env_grid_rows();
    if env_pr > 1 {
        grids.push((env_pr, 2));
    }
    for (pr, pc) in grids {
        for rb in [1, 4] {
            let cells = shard_cells(&ds.a, pr, pc, rb);
            let assembled = assemble_cells(ds.m(), ds.n(), pr, pc, rb, &cells).unwrap();
            let save_ds = Dataset {
                name: ds.name.clone(),
                a: assembled,
                y: ds.y.clone(),
                task: ds.task,
            };
            let sharded = SvmModel::from_dual(&save_ds, &alpha, kernel);
            let spath = tmp("sharded_svm.kcd");
            sharded.save_kcd(&spath).unwrap();
            assert_eq!(
                std::fs::read(&spath).unwrap(),
                replicated_bytes,
                "sharded save at grid {pr}x{pc} rb={rb} must be byte-identical"
            );
        }
    }
}

/// Compaction edge cases: all-zero α, bound α, K-RR exemption, and the
/// compacted ≡ uncompacted bitwise identity.
#[test]
fn prop_support_vector_compaction_edges() {
    let ds = gen_dense_classification(24, 8, 0.02, 41);
    let queries = gen_dense_classification(7, 8, 0.02, 42).a;
    let kernel = Kernel::paper_rbf();

    // All-zero α: the model is empty but must save, load, and predict
    // zeros — never panic.
    let empty = SvmModel::from_dual(&ds, &vec![0.0; ds.m()], kernel);
    assert_eq!(empty.n_support(), 0);
    let path = tmp("empty_svm.kcd");
    empty.save_kcd(&path).unwrap();
    let back = SvmModel::load_kcd(&path).unwrap();
    assert_eq!(back.n_support(), 0);
    for opts in [
        PredictOptions::default(),
        PredictOptions {
            threads: 3,
            cache_rows: 4,
            batch: 2,
        },
    ] {
        let got = back.predict_batch(&queries, &opts, &mut Ledger::new());
        assert_eq!(got, vec![0.0; queries.nrows()], "empty model predicts zeros");
    }

    // Bound-α rows (α = C) are support vectors and must be retained.
    let mut alpha = vec![0.0; ds.m()];
    alpha[3] = 1.0;
    alpha[17] = 1.0;
    let bound = SvmModel::from_dual(&ds, &alpha, kernel);
    assert_eq!(bound.n_support(), 2, "bound alpha rows must be retained");

    // K-RR is never compacted, in memory or through a save.
    let sparse_alpha = synth_alpha(ds.m());
    let krr = KrrModel::from_dual(&ds, &sparse_alpha, kernel, 1.0);
    assert_eq!(krr.train_matrix().nrows(), ds.m());
    let kpath = tmp("uncompacted_krr.kcd");
    krr.save_kcd(&kpath).unwrap();
    assert_eq!(
        KrrModel::load_kcd(&kpath).unwrap().train_matrix().nrows(),
        ds.m(),
        "krr saves must retain every training row"
    );

    // Compacted ≡ uncompacted bitwise: dropping α = 0 rows removes
    // exactly the `f += 0 · k` terms, which cannot change the bits
    // (+0.0 + ±0.0 = +0.0, and every partial sum is reproduced).
    let compacted = SvmModel::from_dual(&ds, &sparse_alpha, kernel);
    assert!(compacted.n_support() < ds.m(), "alpha must have zeros");
    let full_coef: Vec<f64> = sparse_alpha
        .iter()
        .zip(&ds.y)
        .map(|(&a, &y)| a * y)
        .collect();
    for threads in [1, 4] {
        let opts = PredictOptions {
            threads,
            cache_rows: 0,
            batch: 0,
        };
        let mut uncompacted = Predictor::new(&ds.a, &full_coef, kernel, &queries, &opts);
        let stream: Vec<usize> = (0..queries.nrows()).collect();
        let full = uncompacted.predict_stream(&stream, 0, &mut Ledger::new());
        let got = compacted.predict_batch(&queries, &opts, &mut Ledger::new());
        assert_eq!(
            bits(&got),
            bits(&full),
            "compacted vs uncompacted t={threads}"
        );
    }
}

/// Corrupt model files are hard errors naming the offending field.
#[test]
fn prop_model_corruption_is_a_named_error() {
    let ds = gen_dense_classification(12, 6, 0.02, 51);
    let model = SvmModel::from_dual(&ds, &synth_alpha(ds.m()), Kernel::paper_rbf());
    let path = tmp("corrupt_base.kcd");
    model.save_kcd(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    let load = |bytes: &[u8]| {
        let p = tmp("corrupt_case.kcd");
        std::fs::write(&p, bytes).unwrap();
        LoadedModel::load(&p).map(|_| ()).unwrap_err().to_string()
    };

    // Header-region truncation: the cursor names the field it was
    // reading and says "truncated".
    for cut in [4, 11, 20, 55] {
        let err = load(&good[..cut]);
        assert!(err.contains("model."), "cut at {cut}: {err}");
        assert!(err.contains("truncated"), "cut at {cut}: {err}");
    }
    // Body truncation: caught up front as a header-promise lie naming
    // the length field, before any per-entry parsing.
    let err = load(&good[..good.len() - 3]);
    assert!(err.contains("model.nnz"), "{err}");
    // Version bump.
    let mut v = good.clone();
    v[8] = 9;
    let err = load(&v);
    assert!(err.contains("model.version"), "{err}");
    // Unknown kind tag.
    let mut k = good.clone();
    k[12] = 7;
    let err = load(&k);
    assert!(err.contains("model.kind"), "{err}");
    // Header lies: inflate nnz (offset 60 = magic 8 + version 4 + kind 4
    // + kernel tag 4 + 3 kernel/λ f64s + rows 8 + cols 8).
    let mut n = good.clone();
    n[60] = n[60].wrapping_add(1);
    let err = load(&n);
    assert!(err.contains("model.nnz"), "{err}");
    // The pristine bytes still load, so every failure above is the
    // mutation's doing.
    let p = tmp("corrupt_case.kcd");
    std::fs::write(&p, &good).unwrap();
    assert_eq!(LoadedModel::load(&p).unwrap().kind(), ModelKind::Svm);
}

/// Request parsing feeds the predictor exactly the reference bits:
/// dedup maps repeats onto one query row, and scoring the parsed set
/// matches scoring the rows directly.
#[test]
fn prop_parsed_requests_score_like_raw_rows() {
    let ds = gen_dense_classification(20, 5, 0.02, 61);
    let model = SvmModel::from_dual(&ds, &synth_alpha(ds.m()), Kernel::paper_rbf());
    let text = "1:0.5 3:-1.25\n2:2.0\n1:0.5 3:-1.25\n# note\n\n5:0.75\n";
    let reqs = parse_requests(text, 5).unwrap();
    assert_eq!(reqs.len(), 4);
    assert_eq!(reqs.unique(), 3);
    let raw = Csr::from_triplets(
        3,
        5,
        &[(0, 0, 0.5), (0, 2, -1.25), (1, 1, 2.0), (2, 4, 0.75)],
    );
    let reference = model.decision_function(&raw);
    let expected: Vec<f64> = reqs.stream.iter().map(|&r| reference[r]).collect();
    let opts = PredictOptions {
        threads: 2,
        cache_rows: 4,
        batch: 2,
    };
    let mut p = Predictor::new(
        model.support_vectors(),
        model.coefficients(),
        model.kernel(),
        &reqs.queries,
        &opts,
    );
    let got = p.predict_stream(&reqs.stream, opts.batch, &mut Ledger::new());
    assert_eq!(bits(&got), bits(&expected));
}

/// CLI end to end, honoring the CI matrix knobs: train with --save
/// (threads from THREADS, storage from GRID_STORAGE on a GRIDx2 grid),
/// predict from the saved file, and match the plain 1D run's response
/// bits (the grid contract: GRxPC ≡ 1D over pc ranks).
#[test]
fn cli_save_predict_roundtrip_under_env_matrix() {
    let argv = |s: &str| -> Vec<String> { s.split_whitespace().map(String::from).collect() };
    let base_model = tmp("cli_base.kcd");
    let reqf = tmp("cli_req.txt");
    std::fs::write(&reqf, "1:0.5 2:-0.75\n3:1.0\n1:0.5 2:-0.75\n").unwrap();
    let t = testkit::env_threads();
    let base = kcd::cli::run(argv(&format!(
        "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 160 --s 8 --p 2 \
         --threads {t} --save {}",
        base_model.display()
    )))
    .unwrap();
    assert!(base.contains("model saved"), "{base}");
    let responses = |out: &str| -> Vec<String> {
        out.lines()
            .filter(|l| l.starts_with("+1 ") || l.starts_with("-1 "))
            .map(String::from)
            .collect()
    };
    let pred = kcd::cli::run(argv(&format!(
        "predict --model {} --requests {}",
        base_model.display(),
        reqf.display()
    )))
    .unwrap();
    let base_resp = responses(&pred);
    assert_eq!(base_resp.len(), 3, "{pred}");
    assert_eq!(base_resp[0], base_resp[2], "duplicate requests score identically");

    // The matrix point: a GRIDx2 grid over 2·GRID ranks with the lane's
    // storage mode must save a model that serves the same bits.
    let pr = testkit::env_grid_rows();
    let storage = testkit::env_grid_storage();
    let grid_model = tmp("cli_grid.kcd");
    let out = kcd::cli::run(argv(&format!(
        "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 160 --s 8 \
         --p {} --grid {pr}x2 --grid-storage {} --threads {t} --save {}",
        pr * 2,
        storage.name(),
        grid_model.display()
    )))
    .unwrap();
    assert!(out.contains("model saved"), "{out}");
    let pred2 = kcd::cli::run(argv(&format!(
        "predict --model {} --requests {}",
        grid_model.display(),
        reqf.display()
    )))
    .unwrap();
    assert_eq!(
        base_resp,
        responses(&pred2),
        "grid save must serve the 1D bits\n{pred}\n{pred2}"
    );
}
