//! Property suites for the paper's mathematical claims, run at the
//! integration level (heavier cases than the in-module properties).

use kcd::costmodel::Ledger;
use kcd::data::{gen_dense_classification, gen_dense_regression};
use kcd::kernelfn::Kernel;
use kcd::solvers::{
    bdcd, bdcd_sstep, dcd, dcd_sstep, krr_exact, KrrParams, LocalGram, SvmParams, SvmVariant,
};
use kcd::testkit;

fn kernels() -> [Kernel; 5] {
    [
        Kernel::Linear,
        Kernel::Poly { c: 0.0, d: 3 },
        Kernel::Poly { c: 1.0, d: 2 },
        Kernel::Rbf { sigma: 1.0 },
        Kernel::Rbf { sigma: 0.25 },
    ]
}

/// §5.1 equivalence claim, wide sweep: random m, n, C, kernel, s, H,
/// L1/L2 — s-step DCD final solution equals DCD's.
#[test]
fn prop_dcd_sstep_equivalence_wide() {
    testkit::check("wide dcd equivalence", 20, |g| {
        let m = g.size(4, 80);
        let n = g.size(1, 24);
        let h = g.size(8, 300);
        let s = *g.choose(&[2, 3, 5, 8, 17, 32, 64, 256]);
        let kernel = *g.choose(&kernels());
        let variant = *g.choose(&[SvmVariant::L1, SvmVariant::L2]);
        let c = g.f64_range(0.05, 8.0);
        let ds = gen_dense_classification(m, n, 0.1, g.seed);
        let p = SvmParams {
            c,
            variant,
            h,
            seed: g.seed ^ 0xF00D,
        };
        let mut o1 = LocalGram::new(ds.a.clone(), kernel);
        let mut o2 = LocalGram::new(ds.a.clone(), kernel);
        let a = dcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
        let b = dcd_sstep(&mut o2, &ds.y, &p, s, &mut Ledger::new(), None);
        testkit::assert_close(&b, &a, 1e-8, "wide dcd");
    });
}

/// Same for BDCD / s-step BDCD over random block sizes.
#[test]
fn prop_bdcd_sstep_equivalence_wide() {
    testkit::check("wide bdcd equivalence", 16, |g| {
        let m = g.size(5, 60);
        let n = g.size(1, 16);
        let b = g.size(1, m.min(12));
        let h = g.size(5, 150);
        let s = *g.choose(&[2, 4, 7, 16, 33, 128]);
        let kernel = *g.choose(&kernels());
        let lambda = g.f64_range(0.1, 10.0);
        let ds = gen_dense_regression(m, n, 0.2, g.seed);
        let p = KrrParams {
            lambda,
            b,
            h,
            seed: g.seed ^ 0xBEEF,
        };
        let mut o1 = LocalGram::new(ds.a.clone(), kernel);
        let mut o2 = LocalGram::new(ds.a.clone(), kernel);
        let a = bdcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
        let bb = bdcd_sstep(&mut o2, &ds.y, &p, s, &mut Ledger::new(), None);
        testkit::assert_close(&bb, &a, 1e-8, "wide bdcd");
    });
}

/// BDCD converges to the closed form for random well-conditioned
/// problems (λ not too small).
#[test]
fn prop_bdcd_converges_to_closed_form() {
    testkit::check("bdcd → α*", 8, |g| {
        let m = g.size(10, 50);
        let n = g.size(2, 10);
        let b = g.size(2, m / 2);
        let kernel = *g.choose(&[Kernel::Linear, Kernel::paper_rbf()]);
        let lambda = g.f64_range(0.5, 4.0);
        let ds = gen_dense_regression(m, n, 0.1, g.seed);
        let p = KrrParams {
            lambda,
            b,
            h: 1500,
            seed: g.seed,
        };
        let mut o1 = LocalGram::new(ds.a.clone(), kernel);
        let mut o2 = LocalGram::new(ds.a.clone(), kernel);
        let alpha = bdcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
        let astar = krr_exact(&mut o2, &ds.y, lambda);
        let err = kcd::dense::rel_err(&alpha, &astar);
        assert!(err < 1e-5, "rel err {err} (m={m} b={b} λ={lambda})");
    });
}

/// DCD monotonically decreases the dual objective (coordinate descent on
/// a convex problem can never increase it).
#[test]
fn prop_dcd_objective_monotone() {
    use kcd::solvers::objective::SvmObjective;
    testkit::check("dcd monotone", 6, |g| {
        let m = g.size(10, 40);
        let n = g.size(2, 10);
        let variant = *g.choose(&[SvmVariant::L1, SvmVariant::L2]);
        let kernel = *g.choose(&[Kernel::Linear, Kernel::paper_rbf()]);
        let ds = gen_dense_classification(m, n, 0.1, g.seed);
        let c = g.f64_range(0.2, 4.0);
        let mut oracle = LocalGram::new(ds.a.clone(), kernel);
        let obj = SvmObjective::new(&mut oracle, &ds.y, c, variant);
        let mut last = 0.0; // objective at α = 0
        let mut violations = 0u32;
        let mut cb = |_k: usize, a: &[f64]| {
            let v = obj.dual_min_value(a);
            if v > last + 1e-9 {
                violations += 1;
            }
            last = v;
        };
        let p = SvmParams {
            c,
            variant,
            h: 200,
            seed: g.seed,
        };
        let mut o = LocalGram::new(ds.a.clone(), kernel);
        dcd(&mut o, &ds.y, &p, &mut Ledger::new(), Some(&mut cb));
        assert_eq!(violations, 0, "objective increased {violations} times");
    });
}

/// Failure injection: solvers must reject invalid configurations loudly.
#[test]
fn invalid_configurations_panic() {
    let ds = gen_dense_regression(10, 4, 0.1, 3);
    let panics = |f: Box<dyn FnOnce() + std::panic::UnwindSafe>| {
        std::panic::catch_unwind(f).is_err()
    };
    // b > m
    {
        let a = ds.a.clone();
        let y = ds.y.clone();
        assert!(panics(Box::new(move || {
            let mut o = LocalGram::new(a, Kernel::Linear);
            let p = KrrParams {
                lambda: 1.0,
                b: 11,
                h: 1,
                seed: 0,
            };
            bdcd(&mut o, &y, &p, &mut Ledger::new(), None);
        })));
    }
    // y length mismatch
    {
        let a = ds.a.clone();
        assert!(panics(Box::new(move || {
            let mut o = LocalGram::new(a, Kernel::Linear);
            let p = SvmParams::default();
            dcd(&mut o, &[1.0, -1.0], &p, &mut Ledger::new(), None);
        })));
    }
    // s = 0
    {
        let a = ds.a.clone();
        let y = ds.y.clone();
        assert!(panics(Box::new(move || {
            let mut o = LocalGram::new(a, Kernel::Linear);
            let p = SvmParams::default();
            dcd_sstep(&mut o, &y, &p, 0, &mut Ledger::new(), None);
        })));
    }
}
