//! Bitwise-equality property suite for the intra-rank threaded product
//! stage (`parallel::ParallelProduct`), covering the acceptance matrix:
//! cached × uncached × thread counts {1, 2, 3, 8} × product backends
//! (`CsrProduct` dense/sparse, `LowRankProduct`) × `DistGram` rank
//! counts, with duplicate-heavy with-replacement samples — plus solver-
//! level dcd/bdcd s-step equivalence with `threads > 1`.
//!
//! The `THREADS` environment variable (CI matrix lane) is folded into
//! every thread-count sweep via `testkit::env_threads`, so the suite
//! also runs at the lane's parallelism level.

use kcd::comm::{run_ranks, AllreduceAlgo, Communicator};
use kcd::costmodel::Ledger;
use kcd::data::{gen_dense_classification, gen_uniform_sparse, Dataset, SynthParams, Task};
use kcd::dense::Mat;
use kcd::gram::{CsrProduct, LowRankProduct, ProductStage};
use kcd::kernelfn::Kernel;
use kcd::parallel::ParallelProduct;
use kcd::rng::Pcg;
use kcd::solvers::{
    bdcd, bdcd_sstep, dcd, dcd_sstep, DistGram, GramOracle, KrrParams, LocalGram, NystromGram,
    SvmParams, SvmVariant,
};
use kcd::testkit;

/// The acceptance thread counts, plus the CI lane's `THREADS` value.
fn thread_counts() -> Vec<usize> {
    let mut ts = vec![1usize, 2, 3, 8];
    let env = testkit::env_threads();
    if !ts.contains(&env) {
        ts.push(env);
    }
    ts
}

/// Duplicate-heavy with-replacement sample stream: indices concentrate
/// on the lower half of `[0, m)`, so calls repeat rows both within a
/// block (intra-call dedup) and across calls (cache hits).
fn dup_stream(m: usize, calls: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Pcg::seeded(seed);
    (0..calls)
        .map(|_| {
            let k = rng.gen_range(1, 9);
            (0..k).map(|_| rng.gen_below(m / 2 + 1)).collect()
        })
        .collect()
}

fn dense_ds() -> Dataset {
    gen_dense_classification(32, 10, 0.0, 42)
}

fn sparse_ds() -> Dataset {
    gen_uniform_sparse(
        SynthParams {
            m: 30,
            n: 140,
            density: 0.05,
            seed: 7,
        },
        Task::Classification,
    )
}

/// Raw product stages: every thread count must replay the serial bits,
/// for the CSR product on both density paths and the low-rank product.
#[test]
fn prop_product_stages_bitwise_invariant_in_thread_count() {
    fn check<P: ProductStage + Clone + Send>(name: &str, inner: P) {
        let m = inner.m();
        let samples = dup_stream(m, 6, 0x51);
        let mut serial = inner.clone();
        for t in thread_counts() {
            let mut par = ParallelProduct::new(inner.clone(), t);
            for sample in &samples {
                let mut q_ref = Mat::zeros(sample.len(), m);
                let cost_ref = serial.compute(sample, &mut q_ref);
                let mut q = Mat::zeros(sample.len(), m);
                let cost = par.compute(sample, &mut q);
                assert_eq!(
                    q.data(),
                    q_ref.data(),
                    "{name} t={t}: block must be bitwise identical"
                );
                assert_eq!(cost.rows_charged, cost_ref.rows_charged, "{name} t={t}");
            }
        }
    }

    check("csr-dense", CsrProduct::new(dense_ds().a));
    check("csr-sparse", CsrProduct::new(sparse_ds().a));

    // Low-rank factors with a deterministic spectrum.
    let (m, l) = (28usize, 9usize);
    let mut rng = Pcg::seeded(33);
    let cw = Mat::from_fn(m, l, |_, _| rng.next_gaussian());
    let ct = Mat::from_fn(l, m, |_, _| rng.next_gaussian());
    check("low-rank", LowRankProduct::new(cw, ct));
}

/// Engine level: `LocalGram` and `NystromGram` blocks are bitwise
/// identical across thread counts, cache on and off, for every kernel.
#[test]
fn prop_local_oracles_bitwise_invariant_cached_and_uncached() {
    for ds in [dense_ds(), sparse_ds()] {
        let m = ds.m();
        let stream = dup_stream(m, 8, 0xA1);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let run_local = |cache_rows: usize, threads: usize| -> Vec<f64> {
                let mut oracle = LocalGram::with_opts(ds.a.clone(), kernel, cache_rows, threads);
                let mut out = Vec::new();
                for sample in &stream {
                    let mut q = Mat::zeros(sample.len(), m);
                    oracle.gram(sample, &mut q, &mut Ledger::new());
                    out.extend_from_slice(q.data());
                }
                out
            };
            let reference = run_local(0, 1);
            for t in thread_counts() {
                for cache_rows in [0usize, 6] {
                    assert_eq!(
                        run_local(cache_rows, t),
                        reference,
                        "{} {kernel:?} t={t} cache={cache_rows}",
                        ds.name
                    );
                }
            }
        }
    }

    // Nyström: the threaded low-rank product through the cached engine.
    let ds = dense_ds();
    let stream = dup_stream(ds.m(), 6, 0xB2);
    let kernel = Kernel::paper_rbf();
    let run_ny = |cache_rows: usize, threads: usize| -> Vec<f64> {
        let mut oracle = NystromGram::with_opts(&ds.a, kernel, 12, 1e-10, 4, cache_rows, threads);
        let mut out = Vec::new();
        for sample in &stream {
            let mut q = Mat::zeros(sample.len(), ds.m());
            oracle.gram(sample, &mut q, &mut Ledger::new());
            out.extend_from_slice(q.data());
        }
        out
    };
    let reference = run_ny(0, 1);
    for t in thread_counts() {
        for cache_rows in [0usize, 5] {
            assert_eq!(run_ny(cache_rows, t), reference, "nystrom t={t} cache={cache_rows}");
        }
    }
}

/// Distributed level: for each rank count (pof2 and not), every
/// (threads, cache) combination replays the bits of that rank count's
/// serial uncached run, and all ranks agree.
#[test]
fn prop_dist_gram_bitwise_invariant_across_ranks_and_threads() {
    let ds = gen_dense_classification(24, 16, 0.0, 5);
    let m = ds.m();
    let stream = dup_stream(m, 6, 0x77);
    let kernel = Kernel::paper_rbf();
    for p in [2usize, 3, 4] {
        let shards = ds.shard_cols(p);
        let run = |cache_rows: usize, threads: usize| -> Vec<f64> {
            let shards = shards.clone();
            let stream = &stream;
            let outs = run_ranks(p, move |c| {
                let shard = shards[c.rank()].clone();
                let mut oracle = DistGram::with_opts(
                    shard,
                    kernel,
                    c,
                    AllreduceAlgo::Rabenseifner,
                    cache_rows,
                    threads,
                );
                let mut out = Vec::new();
                for sample in stream {
                    let mut q = Mat::zeros(sample.len(), m);
                    oracle.gram(sample, &mut q, &mut Ledger::new());
                    out.extend_from_slice(q.data());
                }
                out
            });
            for other in &outs[1..] {
                assert_eq!(&outs[0], other, "p={p}: ranks disagree");
            }
            outs.into_iter().next().unwrap()
        };
        let reference = run(0, 1);
        for t in thread_counts() {
            for cache_rows in [0usize, 5] {
                assert_eq!(
                    run(cache_rows, t),
                    reference,
                    "p={p} t={t} cache={cache_rows}"
                );
            }
        }
    }
}

/// Solver level: dcd/bdcd and their s-step variants return bit-identical
/// α with `threads > 1`, and the s-step ≡ classical equivalence holds on
/// the threaded path.
#[test]
fn prop_solvers_bitwise_identical_with_threads() {
    let svm_ds = dense_ds();
    let krr_ds = gen_uniform_sparse(
        SynthParams {
            m: 26,
            n: 90,
            density: 0.08,
            seed: 13,
        },
        Task::Regression,
    );
    let kernel = Kernel::paper_rbf();
    for t in thread_counts() {
        for cache_rows in [0usize, 8] {
            // --- DCD / s-step DCD ---------------------------------------
            let p = SvmParams {
                c: 1.0,
                variant: SvmVariant::L1,
                h: 120,
                seed: 3,
            };
            let mut serial = LocalGram::new(svm_ds.a.clone(), kernel);
            let mut threaded = LocalGram::with_opts(svm_ds.a.clone(), kernel, cache_rows, t);
            let a_ref = dcd(&mut serial, &svm_ds.y, &p, &mut Ledger::new(), None);
            let a_thr = dcd(&mut threaded, &svm_ds.y, &p, &mut Ledger::new(), None);
            assert_eq!(a_ref, a_thr, "dcd t={t} cache={cache_rows}");

            let mut serial = LocalGram::new(svm_ds.a.clone(), kernel);
            let mut threaded = LocalGram::with_opts(svm_ds.a.clone(), kernel, cache_rows, t);
            let s_ref = dcd_sstep(&mut serial, &svm_ds.y, &p, 8, &mut Ledger::new(), None);
            let s_thr = dcd_sstep(&mut threaded, &svm_ds.y, &p, 8, &mut Ledger::new(), None);
            assert_eq!(s_ref, s_thr, "dcd_sstep t={t} cache={cache_rows}");
            // s-step ≡ classical survives threading.
            for (x, y) in s_thr.iter().zip(&a_thr) {
                assert!(
                    (x - y).abs() < 1e-9,
                    "sstep vs classical under threads t={t}"
                );
            }

            // --- BDCD / s-step BDCD -------------------------------------
            let p = KrrParams {
                lambda: 1.0,
                b: 4,
                h: 80,
                seed: 5,
            };
            let mut serial = LocalGram::new(krr_ds.a.clone(), kernel);
            let mut threaded = LocalGram::with_opts(krr_ds.a.clone(), kernel, cache_rows, t);
            let a_ref = bdcd(&mut serial, &krr_ds.y, &p, &mut Ledger::new(), None);
            let a_thr = bdcd(&mut threaded, &krr_ds.y, &p, &mut Ledger::new(), None);
            assert_eq!(a_ref, a_thr, "bdcd t={t} cache={cache_rows}");

            let mut serial = LocalGram::new(krr_ds.a.clone(), kernel);
            let mut threaded = LocalGram::with_opts(krr_ds.a.clone(), kernel, cache_rows, t);
            let s_ref = bdcd_sstep(&mut serial, &krr_ds.y, &p, 6, &mut Ledger::new(), None);
            let s_thr = bdcd_sstep(&mut threaded, &krr_ds.y, &p, 6, &mut Ledger::new(), None);
            assert_eq!(s_ref, s_thr, "bdcd_sstep t={t} cache={cache_rows}");
        }
    }
}

/// Distributed s-step solve with threads on every rank: the full hybrid
/// path (P ranks × t threads × cache) returns bit-identical α.
#[test]
fn prop_distributed_sstep_solve_bitwise_with_threads() {
    use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
    use kcd::costmodel::MachineProfile;
    let ds = gen_dense_classification(28, 12, 0.05, 55);
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let base = SolverSpec {
        s: 8,
        h: 48,
        seed: 9,
        cache_rows: 0,
        threads: 1,
        grid: None,
        ..Default::default()
    };
    for p in [2usize, 3] {
        let reference = run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &base,
            p,
            AllreduceAlgo::Rabenseifner,
            &machine,
        )
        .alpha;
        for t in [2usize, 8, testkit::env_threads()] {
            for cache_rows in [0usize, 10] {
                let solver = SolverSpec {
                    cache_rows,
                    threads: t,
                    ..base
                };
                let alpha = run_distributed(
                    &ds,
                    Kernel::paper_rbf(),
                    &problem,
                    &solver,
                    p,
                    AllreduceAlgo::Rabenseifner,
                    &machine,
                )
                .alpha;
                assert_eq!(alpha, reference, "p={p} t={t} cache={cache_rows}");
            }
        }
    }
}
