//! Property suite for the cost-model auto-tuner (`kcd::tune`), pinning
//! the acceptance matrix of the tuner's trust story:
//!
//! * **Traffic identity** — the traffic behind every candidate's
//!   prediction is *exactly* the analytic count replica
//!   (`analytic_ledger` / `grid_analytic_ledger`) for its layout: the
//!   tuner adds ranking on top of the cross-validated count model, never
//!   its own arithmetic.
//! * **Measured cross-validation** — replaying tuned candidates on real
//!   ranks reproduces the predicted traffic word for word, for both
//!   problems, 1D and grid layouts, classical and s-step.
//! * **Enumeration-order invariance** — permuting (or duplicating) the
//!   candidate lists never changes the ranking.
//! * **Latency monotonicity** — as the machine's per-message latency α
//!   grows, the chosen `s` is monotonically non-decreasing and the
//!   chosen configuration's latency rounds are non-increasing (the
//!   paper's core claim, now made by the tuner instead of a hand sweep).

use kcd::comm::AllreduceAlgo;
use kcd::coordinator::scaling::{analytic_ledger, grid_analytic_ledger};
use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
use kcd::costmodel::{MachineProfile, Phase};
use kcd::gram::DEFAULT_ROW_BLOCK;
use kcd::kernelfn::Kernel;
use kcd::solvers::SvmVariant;
use kcd::tune::{cross_validate, tune, TuneRequest};

fn svm_problem() -> ProblemSpec {
    ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    }
}

/// Satellite (a): the tuner's traffic prediction for every candidate —
/// including the chosen one — equals the analytic ledger of its layout
/// exactly (u64 counter identity, f64 flop identity: same code path,
/// same bits).
#[test]
fn prop_candidate_traffic_equals_analytic_ledgers_exactly() {
    let ds = kcd::data::gen_dense_classification(24, 16, 0.05, 12);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
    for problem in problems {
        for p in [5usize, 6, 8] {
            let mut req = TuneRequest::new(p, 16);
            req.s_list = vec![4, 8];
            req.t_list = vec![1, 2];
            let machine = MachineProfile::cray_ex();
            let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
            for c in &plan.candidates {
                let direct = if c.pr == 1 {
                    analytic_ledger(
                        &ds,
                        Kernel::paper_rbf(),
                        &problem,
                        c.s,
                        16,
                        p,
                        req.algo,
                        c.overlap,
                    )
                } else {
                    grid_analytic_ledger(
                        &ds,
                        Kernel::paper_rbf(),
                        &problem,
                        c.s,
                        16,
                        c.pr,
                        c.pc,
                        c.row_block,
                        c.storage,
                        &c.schedule,
                        req.seed,
                        req.algo,
                        c.overlap,
                    )
                };
                let tag = format!(
                    "{problem:?} p={p} pr={} pc={} s={} {} rb={} overlap={}",
                    c.pr,
                    c.pc,
                    c.s,
                    c.storage.name(),
                    c.row_block,
                    c.overlap.name()
                );
                assert_eq!(c.ledger.comm, direct.comm, "{tag} total traffic");
                assert_eq!(c.ledger.comm_col, direct.comm_col, "{tag} col traffic");
                assert_eq!(c.ledger.comm_row, direct.comm_row, "{tag} row traffic");
                assert_eq!(c.ledger.comm_exch, direct.comm_exch, "{tag} exch traffic");
                assert_eq!(c.ledger.comm_posted, direct.comm_posted, "{tag} posted traffic");
                assert_eq!(c.ledger.mem_per_rank(), direct.mem_per_rank(), "{tag} mem");
                for ph in Phase::ALL {
                    assert_eq!(
                        c.ledger.flops(ph),
                        direct.flops(ph),
                        "{tag} {} flops",
                        ph.name()
                    );
                    assert_eq!(
                        c.ledger.hidden_flops(ph),
                        direct.hidden_flops(ph),
                        "{tag} {} hidden flops",
                        ph.name()
                    );
                }
                assert_eq!(c.ledger.kernel_calls, direct.kernel_calls, "{tag}");
                assert_eq!(c.ledger.kernel_rows, direct.kernel_rows, "{tag}");
                assert_eq!(c.ledger.iters, direct.iters, "{tag}");
            }
        }
    }
}

/// The acceptance criterion: tuned candidates' traffic predictions are
/// cross-validated **bitwise** against measured ledger counts — real
/// ranks, real messages — for both problems across layouts, s and t.
#[test]
fn prop_tuner_predictions_cross_validate_bitwise_against_measured() {
    let ds = kcd::data::gen_dense_classification(24, 16, 0.05, 12);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 2 }];
    for problem in problems {
        for p in [4usize, 6] {
            let mut req = TuneRequest::new(p, 16);
            req.s_list = vec![4];
            req.t_list = vec![1, 2];
            let machine = MachineProfile::cray_ex();
            let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
            // Replaying every (storage × row_block) variant on real
            // ranks would dominate suite runtime; the default row block
            // covers both storage modes, and one sharded non-default
            // row block pins the rb axis (the scaling suite
            // cross-validates the full matrix analytically).
            for c in plan.candidates.iter().filter(|c| {
                c.row_block == DEFAULT_ROW_BLOCK
                    || (c.storage == kcd::gram::GridStorage::Sharded && c.row_block == 1)
            }) {
                let check =
                    cross_validate(&ds, Kernel::paper_rbf(), &problem, c, &req, &machine);
                assert!(
                    check.traffic_exact(),
                    "{problem:?} p={p} pr={} pc={} t={} s={} {} rb={}: {}",
                    c.pr,
                    c.pc,
                    c.t,
                    c.s,
                    c.storage.name(),
                    c.row_block,
                    check.summary()
                );
                assert!(check.flops_rel_err < 1e-6);
            }
        }
    }
}

/// Satellite (b): the ranking is a pure function of the candidate *set*
/// — permuting and duplicating the request lists changes nothing.
#[test]
fn prop_ranking_invariant_under_enumeration_order() {
    let ds = kcd::data::gen_dense_classification(24, 16, 0.05, 7);
    let machine = MachineProfile::cray_ex();
    let problem = svm_problem();
    let mut fwd = TuneRequest::new(12, 32);
    fwd.s_list = vec![2, 8, 32];
    fwd.t_list = vec![1, 2, 4];
    let mut rev = TuneRequest::new(12, 32);
    rev.s_list = vec![32, 2, 8, 8, 2];
    rev.t_list = vec![4, 2, 1, 4];
    let a = tune(&ds, Kernel::paper_rbf(), &problem, &fwd, &machine);
    let b = tune(&ds, Kernel::paper_rbf(), &problem, &rev, &machine);
    assert_eq!(a.candidates.len(), b.candidates.len());
    for (x, y) in a.candidates.iter().zip(&b.candidates) {
        assert_eq!(
            (x.pr, x.pc, x.t, x.s, x.storage, x.row_block, x.overlap),
            (y.pr, y.pc, y.t, y.s, y.storage, y.row_block, y.overlap),
            "ranking order must not depend on enumeration order"
        );
        assert_eq!(x.predicted.total_secs(), y.predicted.total_secs());
    }
    // The row_block satellite: the enumerated set covers the candidate
    // row blocks {1, 4, 16} on genuine grids, both storage modes, and
    // the (storage, row_block) tie-break keeps equal-time candidates in
    // a deterministic order.
    for rb in kcd::tune::ROW_BLOCK_CANDIDATES {
        assert!(
            a.candidates.iter().any(|c| c.pr > 1 && c.row_block == rb),
            "row_block {rb} must be enumerated"
        );
    }
    use kcd::gram::GridStorage;
    for storage in [GridStorage::Replicated, GridStorage::Sharded] {
        assert!(a.candidates.iter().any(|c| c.pr > 1 && c.storage == storage));
    }
}

/// Satellite (c): raising the per-message latency α (via the strict
/// `MachineProfile::parse` override path) makes the chosen `s`
/// monotonically non-decreasing, driving it to the largest candidate in
/// the α → large limit — and the chosen configuration's latency rounds
/// are non-increasing at *every* rank count (a model-free consequence
/// of ranking by `f + α·g`).
#[test]
fn prop_chosen_s_monotone_in_latency() {
    let ds = kcd::data::gen_dense_classification(24, 16, 0.05, 21);
    let problem = svm_problem();
    let alphas = ["1e-9", "1e-7", "1e-6", "1e-5", "1e-4", "1e-3", "1e-2"];
    // P = 2: the candidate space is effectively one layout family per
    // (t, s) (the 2×1 grid dominates 1D at P = 2 — same compute,
    // strictly less traffic), so the classic monotone-selection argument
    // applies to s directly.
    let mut req = TuneRequest::new(2, 64);
    req.s_max = 64;
    req.t_list = vec![1];
    let mut last_s = 0usize;
    let mut chosen = Vec::new();
    for alpha in alphas {
        let machine = MachineProfile::parse(&format!("cray-ex:alpha={alpha}")).unwrap();
        let best = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine)
            .best()
            .clone();
        assert!(
            best.s >= last_s,
            "alpha={alpha}: chosen s {} fell below {last_s} (chosen so far: {chosen:?})",
            best.s
        );
        last_s = best.s;
        chosen.push((alpha, best.s));
    }
    assert_eq!(last_s, 64, "alpha → large must drive s to its bound: {chosen:?}");

    // Rounds monotonicity holds for any candidate space — exercise the
    // full factorization lattice of P = 12.
    let mut req12 = TuneRequest::new(12, 64);
    req12.s_max = 64;
    req12.t_list = vec![1];
    let mut last_rounds = u64::MAX;
    for alpha in alphas {
        let machine = MachineProfile::parse(&format!("cray-ex:alpha={alpha}")).unwrap();
        let best = tune(&ds, Kernel::paper_rbf(), &problem, &req12, &machine)
            .best()
            .clone();
        assert!(
            best.ledger.comm.rounds <= last_rounds,
            "alpha={alpha}: rounds {} rose above {last_rounds}",
            best.ledger.comm.rounds
        );
        last_rounds = best.ledger.comm.rounds;
    }
}

/// End-to-end handoff: running the tuner's chosen spec through
/// `run_distributed` reproduces the predicted traffic and returns the
/// same α as the reference 1D solve at `pc` ranks (the grid determinism
/// contract carried through the tuner).
#[test]
fn tuned_spec_runs_and_replays_reference_bits() {
    let ds = kcd::data::gen_dense_classification(24, 16, 0.05, 33);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let mut req = TuneRequest::new(6, 16);
    req.s_list = vec![4];
    req.t_list = vec![1, 2];
    let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
    let best = plan.best();
    let spec = SolverSpec::from_candidate(best, plan.h, req.seed, 0);
    let res = run_distributed(
        &ds,
        Kernel::paper_rbf(),
        &problem,
        &spec,
        best.ranks(),
        req.algo,
        &machine,
    );
    assert_eq!(res.critical.comm.words, best.ledger.comm.words);
    assert_eq!(res.critical.comm.rounds, best.ledger.comm.rounds);
    // Grid determinism: the tuned layout replays the 1D bits over pc.
    let reference = run_distributed(
        &ds,
        Kernel::paper_rbf(),
        &problem,
        &SolverSpec {
            grid: None,
            threads: 1,
            ..spec
        },
        best.pc,
        req.algo,
        &machine,
    );
    assert_eq!(res.alpha, reference.alpha, "tuned layout must replay 1D@pc bits");
}
