//! Property suite for the nnz-balanced sampled-row partition
//! (`parallel::partition_by_weight` + `ProductStage::sample_cost`).
//!
//! The bitwise-determinism contract says the threaded product's row
//! split is a pure *layout* decision: every output row is computed
//! independently with a fixed summation order, so ANY partition of the
//! sampled rows — row-count or nnz-balanced — must reproduce the serial
//! bits exactly. These tests pin that claim on a deliberately skewed
//! matrix (a few dense head rows, a long sparse tail) where the
//! weighted and uniform splits genuinely differ, for every worker count
//! the solve paths use, and check that the weighted split actually
//! improves the load balance it exists for.

use kcd::comm::{run_ranks, AllreduceAlgo, Communicator};
use kcd::costmodel::Ledger;
use kcd::data::{Dataset, Task};
use kcd::dense::Mat;
use kcd::gram::{CsrProduct, GridStorage, ProductStage};
use kcd::kernelfn::Kernel;
use kcd::parallel::{partition_bounds, partition_by_weight};
use kcd::rng::Pcg;
use kcd::solvers::{GramOracle, GridGram, LocalGram};
use kcd::sparse::Csr;

/// A skewed CSR matrix: `heavy` dense rows over all `n` columns, then a
/// sparse tail (a handful of entries per row). Row costs then span two
/// orders of magnitude, so row-count and nnz-balanced splits disagree.
fn skewed(m: usize, n: usize, heavy: usize, seed: u64) -> Csr {
    let mut rng = Pcg::seeded(seed);
    let mut trips = Vec::new();
    for i in 0..heavy {
        for j in 0..n {
            trips.push((i, j, rng.next_gaussian()));
        }
    }
    for i in heavy..m {
        for _ in 0..4 {
            trips.push((i, rng.gen_below(n), rng.next_gaussian()));
        }
    }
    Csr::from_triplets(m, n, &trips)
}

/// The product must expose nnz weights on the sparse (transpose) path,
/// and the weighted split must differ from the row-count split on the
/// skewed sample — otherwise the bitwise-equality tests below would be
/// comparing identical layouts and prove nothing.
#[test]
fn weighted_layout_differs_from_uniform_on_skew() {
    let a = skewed(96, 400, 3, 5);
    let product = CsrProduct::new(a);
    // Head rows first: their weights dwarf the tail's.
    let sample: Vec<usize> = (0..48).collect();
    let w = product
        .sample_cost(&sample)
        .expect("sparse path must expose nnz weights");
    assert_eq!(w.len(), sample.len());
    assert!(w.iter().all(|&x| x > 0), "weights must be positive: {w:?}");
    let mut differs = 0;
    for parts in 2..=8 {
        if partition_by_weight(&w, parts) != partition_bounds(w.len(), parts) {
            differs += 1;
        }
    }
    assert!(differs > 0, "skewed weights never changed a split: {w:?}");
}

/// The load-balance claim itself: on the skewed sample, the weighted
/// split's max per-part weight is strictly below the row-count split's
/// for every worker count in the solve range.
#[test]
fn weighted_split_strictly_improves_skewed_max_load() {
    let a = skewed(96, 400, 3, 7);
    let product = CsrProduct::new(a);
    let sample: Vec<usize> = (0..48).collect();
    let w = product.sample_cost(&sample).expect("sparse path");
    let max_load = |bounds: &[usize]| -> u64 {
        bounds
            .windows(2)
            .map(|r| w[r[0]..r[1]].iter().sum::<u64>())
            .max()
            .unwrap()
    };
    for parts in 2..=8 {
        let weighted = max_load(&partition_by_weight(&w, parts));
        let uniform = max_load(&partition_bounds(w.len(), parts));
        assert!(
            weighted < uniform,
            "parts={parts}: weighted max load {weighted} must beat uniform {uniform}"
        );
    }
}

/// Bitwise solve equality through the serial full oracle: every worker
/// count (and hence every nnz-balanced layout) replays the t=1 bits on
/// the skewed matrix, across a stream of random samples with repeats.
#[test]
fn local_gram_is_bitwise_invariant_across_thread_counts() {
    let a = skewed(120, 500, 4, 11);
    let stream: Vec<Vec<usize>> = {
        let mut rng = Pcg::seeded(23);
        (0..12)
            .map(|_| {
                let k = rng.gen_range(1, 9);
                (0..k).map(|_| rng.gen_below(120)).collect()
            })
            .collect()
    };
    let run = |threads: usize| -> Vec<f64> {
        let mut oracle = LocalGram::with_opts(a.clone(), Kernel::paper_rbf(), 0, threads);
        let mut out = Vec::new();
        for sample in &stream {
            let mut q = Mat::zeros(sample.len(), 120);
            oracle.gram(sample, &mut q, &mut Ledger::new());
            out.extend_from_slice(q.data());
        }
        out
    };
    let reference = run(1);
    for threads in 2..=8 {
        let got = run(threads);
        assert_eq!(got.len(), reference.len());
        for (i, (x, y)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "t={threads}: entry {i} diverged from serial"
            );
        }
    }
}

/// The same invariance through the grid oracle's sharded storage, where
/// the weights come from the per-call fragment slot (`FragmentSlot::
/// weigh`) instead of a resident shard: a threaded 2x2 sharded grid on
/// the skewed matrix replays the all-serial grid bits.
#[test]
fn sharded_grid_is_bitwise_invariant_across_thread_counts() {
    let a = skewed(64, 320, 3, 31);
    let stream: Vec<Vec<usize>> = {
        let mut rng = Pcg::seeded(41);
        (0..6)
            .map(|_| (0..6).map(|_| rng.gen_below(64)).collect())
            .collect()
    };
    let (pr, pc) = (2usize, 2usize);
    let run = |threads: usize| -> Vec<Vec<f64>> {
        let stream = stream.clone();
        let a = a.clone();
        run_ranks(pr * pc, move |c| {
            let shards = Dataset {
                name: "skewed".to_string(),
                a: a.clone(),
                y: vec![1.0; 64],
                task: Task::Classification,
            }
            .shard_cols(pc);
            let shard = shards[c.rank() % pc].clone();
            let mut grid = GridGram::with_opts(
                shard,
                Kernel::paper_rbf(),
                c,
                AllreduceAlgo::Rabenseifner,
                pr,
                pc,
                4,
                GridStorage::Sharded,
                0,
                threads,
            );
            let mut out = Vec::new();
            for sample in &stream {
                let mut q = Mat::zeros(sample.len(), 64);
                grid.gram(sample, &mut q, &mut Ledger::new());
                out.extend_from_slice(q.data());
            }
            out
        })
    };
    let reference = run(1);
    for threads in [2usize, 3, 4] {
        let got = run(threads);
        for (rank, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(g.len(), r.len());
            for (x, y) in g.iter().zip(r) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "t={threads} rank={rank} diverged from serial grid"
                );
            }
        }
    }
}
