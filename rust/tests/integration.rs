//! Cross-module integration tests: whole-stack flows that unit tests
//! can't see — dataset I/O → distributed solver → objective, PJRT
//! artifacts → solver ≡ native, CLI → engine, config → run.

use kcd::comm::AllreduceAlgo;
use kcd::coordinator::figures::{max_series_deviation, svm_gap_series};
use kcd::coordinator::scaling::{analytic_ledger, sweep, Engine, SweepConfig};
use kcd::coordinator::{run_distributed, run_serial, Config, ProblemSpec, SolverSpec};
use kcd::costmodel::{Ledger, MachineProfile, Phase};
use kcd::data::{paper_dataset, read_libsvm_str, write_libsvm, Task};
use kcd::kernelfn::Kernel;
use kcd::solvers::objective::SvmObjective;
use kcd::solvers::{bdcd_sstep, krr_exact, KrrParams, LocalGram, SvmVariant};

fn have_artifacts() -> bool {
    kcd::runtime::PjrtRuntime::default_dir()
        .join("manifest.json")
        .exists()
}

/// LIBSVM file → parse → distributed s-step train → model quality.
#[test]
fn libsvm_roundtrip_through_distributed_solver() {
    let ds = kcd::data::gen_dense_classification(60, 10, 0.05, 404);
    let dir = std::env::temp_dir().join("kcd_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("it.libsvm");
    write_libsvm(&ds, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let back = read_libsvm_str(&text, "it", Task::Classification, Some(10)).unwrap();
    assert_eq!(back.m(), 60);

    let machine = MachineProfile::cray_ex();
    let res = run_distributed(
        &back,
        Kernel::paper_rbf(),
        &ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        },
        &SolverSpec {
            s: 8,
            h: 600,
            seed: 5,
            cache_rows: 0,
            threads: 1,
            grid: None,
            ..Default::default()
        },
        4,
        AllreduceAlgo::Rabenseifner,
        &machine,
    );
    let mut oracle = LocalGram::new(back.a.clone(), Kernel::paper_rbf());
    let obj = SvmObjective::new(&mut oracle, &back.y, 1.0, SvmVariant::L1);
    assert!(obj.train_accuracy(&res.alpha) > 0.85);
    assert!(obj.duality_gap(&res.alpha) < 60.0 * 0.5); // well below the α=0 gap (C·m)
    std::fs::remove_file(&path).ok();
}

/// PJRT-backed solver run must equal the native run (f32 tolerance) and
/// the s-step/classical equivalence must hold across the PJRT path too.
#[test]
fn pjrt_solver_equals_native_solver() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    use kcd::solvers::{dcd_sstep, SvmParams};
    let mut rng = kcd::rng::Pcg::seeded(77);
    let a = kcd::dense::Mat::from_fn(256, 64, |_, _| 0.15 * rng.next_gaussian());
    let y: Vec<f64> = (0..256)
        .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
        .collect();
    let params = SvmParams {
        c: 1.0,
        variant: SvmVariant::L2,
        h: 256,
        seed: 12,
    };
    let rt = kcd::runtime::PjrtRuntime::open(&kcd::runtime::PjrtRuntime::default_dir()).unwrap();
    let mut pjrt = kcd::runtime::PjrtGram::new(rt, &a, Kernel::paper_rbf()).unwrap();
    let alpha_pjrt = dcd_sstep(&mut pjrt, &y, &params, 16, &mut Ledger::new(), None);

    let csr = kcd::sparse::Csr::from_dense(&a);
    let mut native = LocalGram::new(csr, Kernel::paper_rbf());
    let alpha_native = dcd_sstep(&mut native, &y, &params, 16, &mut Ledger::new(), None);
    let dev = kcd::dense::rel_err(&alpha_pjrt, &alpha_native);
    assert!(dev < 5e-4, "PJRT vs native deviation {dev}");
}

/// The three allreduce algorithms must all produce the same model.
#[test]
fn solver_result_is_algorithm_invariant() {
    let ds = kcd::data::gen_dense_regression(30, 6, 0.1, 505);
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Krr { lambda: 1.5, b: 3 };
    let solver = SolverSpec {
        s: 4,
        h: 60,
        seed: 3,
        cache_rows: 0,
        threads: 1,
        grid: None,
        ..Default::default()
    };
    let reference = run_serial(&ds, Kernel::paper_poly(), &problem, &solver, &machine).alpha;
    for algo in [
        AllreduceAlgo::Rabenseifner,
        AllreduceAlgo::RecursiveDoubling,
        AllreduceAlgo::Linear,
    ] {
        for p in [2, 5, 8] {
            let res = run_distributed(&ds, Kernel::paper_poly(), &problem, &solver, p, algo, &machine);
            let dev = kcd::dense::rel_err(&res.alpha, &reference);
            assert!(dev < 1e-9, "{algo:?} p={p}: deviation {dev}");
        }
    }
}

/// Figure-series generation through the public API stays consistent with
/// the distributed engine's final solution.
#[test]
fn gap_series_final_point_matches_distributed_final_gap() {
    let ds = paper_dataset("duke").unwrap().generate();
    let kernel = Kernel::paper_rbf();
    let series = svm_gap_series(&ds, kernel, SvmVariant::L1, 1.0, 128, 8, 99, 128);
    let machine = MachineProfile::cray_ex();
    let res = run_distributed(
        &ds,
        kernel,
        &ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        },
        &SolverSpec {
            s: 8,
            h: 128,
            seed: 99,
            cache_rows: 0,
            threads: 1,
            grid: None,
            ..Default::default()
        },
        4,
        AllreduceAlgo::Rabenseifner,
        &machine,
    );
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let obj = SvmObjective::new(&mut oracle, &ds.y, 1.0, SvmVariant::L1);
    let gap = obj.duality_gap(&res.alpha);
    let (k, series_gap) = *series.last().unwrap();
    assert_eq!(k, 128);
    assert!((gap - series_gap).abs() < 1e-9 * gap.abs().max(1.0));
}

/// Config file drives the same run as explicit flags (CLI integration).
#[test]
fn config_file_drives_cli_run() {
    let dir = std::env::temp_dir().join("kcd_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        "dataset = \"diabetes\"\nscale = 0.08\nkernel = \"rbf\"\nh = 120\ns = 8\np = 2\n\
         threads = 2\n",
    )
    .unwrap();
    let out = kcd::cli::run(vec![
        "train-svm".into(),
        "--config".into(),
        cfg_path.to_str().unwrap().into(),
    ])
    .unwrap();
    assert!(out.contains("duality gap"), "{out}");
    assert!(out.contains("s=8"), "{out}");
    // The intra-rank thread count flows from the config file too.
    assert!(out.contains("t=2"), "{out}");
    // Flag overrides file.
    let out2 = kcd::cli::run(vec![
        "train-svm".into(),
        "--config".into(),
        cfg_path.to_str().unwrap().into(),
        "--s".into(),
        "16".into(),
    ])
    .unwrap();
    assert!(out2.contains("s=16"), "{out2}");
    std::fs::remove_file(&cfg_path).ok();
}

/// Full sweep pipeline: measured and projected engines give consistent
/// projections at the same P (they already agree on counts; this checks
/// the end-to-end sweep path wiring, including best-s selection).
#[test]
fn sweep_engines_agree_at_overlapping_p() {
    let ds = kcd::data::gen_dense_classification(32, 16, 0.05, 606);
    let machine = MachineProfile::cray_ex();
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let base = SweepConfig {
        p_list: vec![4],
        s_list: vec![4, 8],
        t_list: vec![1],
        pr: 1,
        h: 32,
        seed: 77,
        algo: AllreduceAlgo::Rabenseifner,
        measured_limit: 8, // forces measured
        auto_tune: false,
        ..Default::default()
    };
    let measured = sweep(&ds, Kernel::paper_rbf(), &problem, &base, &machine);
    let projected_cfg = SweepConfig {
        measured_limit: 0, // forces projected
        ..base
    };
    let projected = sweep(&ds, Kernel::paper_rbf(), &problem, &projected_cfg, &machine);
    assert_eq!(measured[0].engine, Engine::Measured);
    assert_eq!(projected[0].engine, Engine::Projected);
    let a = measured[0].classical.total_secs();
    let b = projected[0].classical.total_secs();
    assert!((a - b).abs() < 1e-9 * a.max(b), "engines diverge: {a} vs {b}");
    assert_eq!(measured[0].best_s, projected[0].best_s);
}

/// Storage claim of Theorem 2: the s-step working set grows by s·b·m
/// words (the gram buffer) — verify the solver only allocates that much
/// by running a case where s·b·m is large relative to m².
#[test]
fn sstep_memory_is_sbm_not_m2() {
    // Indirect check: the solver works at s·b close to m (buffer s·b×m)
    // and with s·b ≫ b (the paper's large-s regime).
    let ds = kcd::data::gen_dense_regression(64, 8, 0.1, 707);
    let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
    let p = KrrParams {
        lambda: 1.0,
        b: 2,
        h: 96,
        seed: 1,
    };
    let mut o2 = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
    let a1 = bdcd_sstep(&mut oracle, &ds.y, &p, 96, &mut Ledger::new(), None);
    let astar = krr_exact(&mut o2, &ds.y, 1.0);
    assert!(kcd::dense::rel_err(&a1, &astar).is_finite());
}

/// The analytic engine respects load imbalance: projected kernel time at
/// fixed P must be larger for the power-law dataset than for a uniform
/// one with identical (m, n, nnz).
#[test]
fn projection_sees_load_imbalance() {
    let news = paper_dataset("news20").unwrap().generate_scaled(0.02);
    // Uniform twin with the same shape and total nnz.
    let density = news.a.nnz() as f64 / (news.m() as f64 * news.n() as f64);
    let uniform = kcd::data::gen_uniform_sparse(
        kcd::data::SynthParams {
            m: news.m(),
            n: news.n(),
            density,
            seed: 1,
        },
        Task::Classification,
    );
    let problem = ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    };
    let l_news = analytic_ledger(
        &news,
        Kernel::Linear,
        &problem,
        8,
        64,
        256,
        AllreduceAlgo::Rabenseifner,
        kcd::gram::OverlapMode::Off,
    );
    let l_uni = analytic_ledger(
        &uniform,
        Kernel::Linear,
        &problem,
        8,
        64,
        256,
        AllreduceAlgo::Rabenseifner,
        kcd::gram::OverlapMode::Off,
    );
    assert!(
        l_news.flops(Phase::KernelCompute) > 1.3 * l_uni.flops(Phase::KernelCompute),
        "critical-path kernel flops must reflect imbalance: {} vs {}",
        l_news.flops(Phase::KernelCompute),
        l_uni.flops(Phase::KernelCompute)
    );
}
