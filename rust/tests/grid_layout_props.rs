//! Property suite for the 2D process-grid gram layout
//! (`gram::Layout::Grid`, `solvers::GridGram`), pinning the acceptance
//! matrix of the grid determinism contract (see `crate::gram`):
//!
//! * **1D ≡ 2D bitwise** — for every `(pr, pc)` factorization of
//!   `P ∈ {2, …, 12}`, a `Grid{pr, pc}` solve over `P` ranks returns α
//!   bit-identical to the 1D column-shard solve over `pc` ranks (the
//!   grid keeps the 1D path's `pc` feature shards and reduce tree and
//!   adds row parallelism around them; `Grid{1, P}` *is* the 1D path).
//!   Crossed with cache on/off and threads {1, 4} on a sub-matrix, plus
//!   the CI lane's `THREADS` value.
//! * **Row-block invariance** — the block-cyclic block size changes
//!   ownership, traffic and wall time, never a bit of the result.
//! * **Ledger cross-validation** — the column-subcommunicator (reduce)
//!   traffic matches the message-free `allreduce_counts_per_rank`
//!   replica over `pc` ranks, rank by rank, and the row allgather
//!   matches `allgatherv_counts_per_rank`; the reduce payload therefore
//!   scales with `pc` (not `P`).

use kcd::comm::{run_ranks, AllreduceAlgo, CommStats, Communicator};
use kcd::coordinator::scaling::{allgatherv_counts_per_rank, allreduce_counts_per_rank};
use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
use kcd::costmodel::{Ledger, MachineProfile};
use kcd::data::{gen_dense_classification, gen_uniform_sparse, Dataset, SynthParams, Task};
use kcd::dense::Mat;
use kcd::gram::block_cyclic_rows;
use kcd::kernelfn::Kernel;
use kcd::rng::Pcg;
use kcd::solvers::{GramOracle, GridGram, SvmVariant};
use kcd::testkit;

/// Every (pr, pc) with pr·pc == p, in deterministic order.
fn factorizations(p: usize) -> Vec<(usize, usize)> {
    (1..=p).filter(|pr| p % pr == 0).map(|pr| (pr, p / pr)).collect()
}

fn svm_problem() -> ProblemSpec {
    ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    }
}

/// Solver-level α of a 1D run at `p` ranks (serial for p = 1).
fn alpha_1d(ds: &Dataset, problem: &ProblemSpec, solver: &SolverSpec, p: usize) -> Vec<f64> {
    run_distributed(
        ds,
        Kernel::paper_rbf(),
        problem,
        solver,
        p,
        AllreduceAlgo::Rabenseifner,
        &MachineProfile::cray_ex(),
    )
    .alpha
}

/// The headline acceptance property: every factorization of every
/// `P ∈ {2, …, 12}` replays the 1D bits of its `pc`, for both problems.
#[test]
fn prop_grid_solve_bitwise_equals_1d_over_pc_for_all_factorizations() {
    let ds = gen_dense_classification(24, 16, 0.05, 55);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 2 }];
    for problem in problems {
        let base = SolverSpec {
            s: 4,
            h: 16,
            seed: 9,
            cache_rows: 0,
            threads: 1,
            grid: None,
        };
        // Memoize the 1D reference per pc (factorizations share them).
        let mut refs: Vec<Option<Vec<f64>>> = vec![None; 13];
        for p in 2..=12usize {
            for (pr, pc) in factorizations(p) {
                if refs[pc].is_none() {
                    refs[pc] = Some(alpha_1d(&ds, &problem, &base, pc));
                }
                let reference = refs[pc].as_ref().unwrap();
                let grid_solver = SolverSpec {
                    grid: Some((pr, pc)),
                    ..base
                };
                let alpha = alpha_1d(&ds, &problem, &grid_solver, p);
                assert_eq!(
                    &alpha, reference,
                    "{problem:?} Grid{{{pr},{pc}}} must replay 1D@{pc} bits"
                );
            }
        }
    }
}

/// Cache and threads compose with the grid bitwise, including the CI
/// lane's THREADS value — on a representative factorization sub-matrix
/// (the full cross-product would dominate suite runtime).
#[test]
fn prop_grid_solve_bitwise_with_cache_and_threads() {
    let ds = gen_dense_classification(24, 16, 0.05, 55);
    let problem = svm_problem();
    let base = SolverSpec {
        s: 8,
        h: 24,
        seed: 11,
        cache_rows: 0,
        threads: 1,
        grid: None,
    };
    let mut thread_counts = vec![1usize, 4];
    let env = testkit::env_threads();
    if !thread_counts.contains(&env) {
        thread_counts.push(env);
    }
    // The CI GRID lane injects a row-group count the hard-coded list
    // below does not cover (GRID=4 → (4, 2)): fold the env-driven
    // factorization into the sub-matrix so that lane genuinely extends
    // coverage (GRID=1 degenerates to the 1D path over 2 ranks, which
    // is covered anyway).
    let mut factorizations = vec![(2usize, 2usize), (3, 2), (2, 3), (6, 2), (4, 3)];
    let env_pr = testkit::env_grid_rows();
    if !factorizations.contains(&(env_pr, 2)) {
        factorizations.push((env_pr, 2));
    }
    for (pr, pc) in factorizations {
        let reference = alpha_1d(&ds, &problem, &base, pc);
        for &threads in &thread_counts {
            for cache_rows in [0usize, 6] {
                let solver = SolverSpec {
                    cache_rows,
                    threads,
                    grid: Some((pr, pc)),
                    ..base
                };
                let alpha = alpha_1d(&ds, &problem, &solver, pr * pc);
                assert_eq!(
                    alpha, reference,
                    "Grid{{{pr},{pc}}} t={threads} cache={cache_rows}"
                );
            }
        }
    }
}

/// The sparse product path (transpose kernel) honors the same contract.
#[test]
fn prop_grid_solve_bitwise_on_sparse_data() {
    let ds = gen_uniform_sparse(
        SynthParams {
            m: 30,
            n: 200,
            density: 0.05,
            seed: 9,
        },
        Task::Classification,
    );
    let base = SolverSpec {
        s: 4,
        h: 16,
        seed: 3,
        cache_rows: 4,
        threads: 1,
        grid: None,
    };
    let problem = svm_problem();
    for (pr, pc) in [(2usize, 2usize), (3, 2), (2, 4), (5, 2)] {
        let reference = alpha_1d(&ds, &problem, &base, pc);
        let solver = SolverSpec {
            grid: Some((pr, pc)),
            ..base
        };
        let alpha = alpha_1d(&ds, &problem, &solver, pr * pc);
        assert_eq!(alpha, reference, "sparse Grid{{{pr},{pc}}}");
    }
}

/// The block-cyclic block size is a pure wall-time/traffic knob: gram
/// blocks are bitwise invariant across row_block values (element bits
/// never depend on which row group owns a column).
#[test]
fn prop_grid_blocks_bitwise_invariant_in_row_block() {
    let ds = gen_dense_classification(24, 16, 0.0, 5);
    let m = ds.m();
    let kernel = Kernel::paper_rbf();
    let stream: Vec<Vec<usize>> = {
        let mut rng = Pcg::seeded(0x91);
        (0..6)
            .map(|_| {
                let k = rng.gen_range(1, 5);
                (0..k).map(|_| rng.gen_below(m)).collect()
            })
            .collect()
    };
    let (pr, pc) = (3usize, 2usize);
    let shards = ds.shard_cols(pc);
    let run = |row_block: usize| -> Vec<f64> {
        let shards = shards.clone();
        let stream = &stream;
        let outs = run_ranks(pr * pc, move |c| {
            let shard = shards[c.rank() % pc].clone();
            let mut grid = GridGram::with_opts(
                shard,
                kernel,
                c,
                AllreduceAlgo::Rabenseifner,
                pr,
                pc,
                row_block,
                0,
                1,
            );
            let mut out = Vec::new();
            for sample in stream {
                let mut q = Mat::zeros(sample.len(), m);
                grid.gram(sample, &mut q, &mut Ledger::new());
                out.extend_from_slice(q.data());
            }
            out
        });
        for other in &outs[1..] {
            assert_eq!(&outs[0], other, "ranks disagree");
        }
        outs.into_iter().next().unwrap()
    };
    let reference = run(1);
    for row_block in [2usize, 3, 4, 7] {
        assert_eq!(run(row_block), reference, "row_block={row_block}");
    }
}

/// Ledger cross-validation: per-rank column-subcomm traffic matches the
/// message-free allreduce replica over pc ranks at the grid's reduced
/// payload, and the row allgather matches the ring replica — so the
/// analytic ledger's "reduce traffic scales with pc" story is pinned to
/// real messages.
#[test]
fn prop_grid_subcomm_traffic_matches_count_replicas() {
    let ds = gen_dense_classification(24, 16, 0.0, 7);
    let m = ds.m();
    // Linear kernel: simplest epilogue, but the construction-time norms
    // allreduce still runs (it does for every kernel), so the expected
    // column traffic includes it.
    let kernel = Kernel::Linear;
    let row_block = 2usize;
    // Distinct-row samples: with the cache off every sampled row is a
    // miss, so each call's reduce payload is exactly k·|owned|.
    let samples = [vec![0usize, 5, 9], vec![1usize, 2], vec![20usize, 3, 7, 11]];
    for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::RecursiveDoubling] {
        for (pr, pc) in [(2usize, 2usize), (2, 3), (3, 2), (4, 2)] {
            let shards = ds.shard_cols(pc);
            let owned_len: Vec<usize> = (0..pr)
                .map(|g| block_cyclic_rows(m, pr, g, row_block).len())
                .collect();
            let stats = run_ranks(pr * pc, |c| {
                let shard = shards[c.rank() % pc].clone();
                let mut grid =
                    GridGram::with_opts(shard, kernel, c, algo, pr, pc, row_block, 0, 1);
                for sample in &samples {
                    let mut q = Mat::zeros(sample.len(), m);
                    grid.gram(sample, &mut q, &mut Ledger::new());
                }
                (grid.col_stats(), grid.row_stats(), grid.comm_stats())
            });
            for (rank, (col, row, total)) in stats.iter().enumerate() {
                let (i, j) = (rank / pc, rank % pc);
                // Column subcomm: one m-word norms allreduce plus one
                // k·|owned_i|-word allreduce per gram call, at column
                // rank j.
                let mut expect_words = allreduce_counts_per_rank(m, pc, algo)[j].0;
                let mut expect_rounds = allreduce_counts_per_rank(m, pc, algo)[j].1;
                for sample in &samples {
                    let counts =
                        allreduce_counts_per_rank(sample.len() * owned_len[i], pc, algo);
                    expect_words += counts[j].0;
                    expect_rounds += counts[j].1;
                }
                assert_eq!(col.words, expect_words, "{algo:?} {pr}x{pc} rank {rank} col");
                assert_eq!(col.rounds, expect_rounds, "{algo:?} {pr}x{pc} rank {rank}");
                assert_eq!(col.allreduces, 1 + samples.len() as u64);
                // Row subcomm: one ring allgatherv per gram call at row
                // rank i, with per-group counts k·|owned_g|.
                let mut expect_row_words = 0u64;
                let mut expect_row_rounds = 0u64;
                for sample in &samples {
                    let counts: Vec<usize> =
                        owned_len.iter().map(|&w| sample.len() * w).collect();
                    let ring = allgatherv_counts_per_rank(&counts);
                    expect_row_words += ring[i].0;
                    expect_row_rounds += ring[i].1;
                }
                assert_eq!(row.words, expect_row_words, "{algo:?} {pr}x{pc} rank {rank} row");
                assert_eq!(row.rounds, expect_row_rounds, "{algo:?} {pr}x{pc} rank {rank}");
                // The oracle's total is the sequential-stage sum.
                assert_eq!(*total, col.plus(*row), "{pr}x{pc} rank {rank} total");
            }
        }
    }
}

/// Measured end to end: at fixed P, growing pr (shrinking pc) must
/// strictly shrink the words the reduce collective moves — the grid's
/// reason to exist — while α stays within tolerance of the serial solve.
#[test]
fn prop_reduce_traffic_shrinks_as_rows_grow() {
    let ds = gen_dense_classification(32, 16, 0.05, 21);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let base = SolverSpec {
        s: 4,
        h: 16,
        seed: 13,
        cache_rows: 0,
        threads: 1,
        grid: None,
    };
    let serial = run_distributed(
        &ds,
        Kernel::paper_rbf(),
        &problem,
        &base,
        1,
        AllreduceAlgo::Rabenseifner,
        &machine,
    )
    .alpha;
    let p = 8usize;
    let mut col_words = Vec::new();
    for pr in [1usize, 2, 4] {
        let solver = SolverSpec {
            grid: Some((pr, p / pr)),
            ..base
        };
        let res = run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &solver,
            p,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        testkit::assert_close(&res.alpha, &serial, 1e-9, &format!("pr={pr}"));
        col_words.push(res.critical.comm_col.words);
        // The ledger splits the grid traffic by subcommunicator.
        assert_eq!(
            res.critical.comm_col.words + res.critical.comm_row.words,
            res.critical.comm.words,
            "pr={pr}: col+row must compose the total"
        );
        if pr == 1 {
            assert_eq!(res.critical.comm_row.words, 0, "pr=1 has no allgather");
        }
    }
    assert!(
        col_words[0] > col_words[1] && col_words[1] > col_words[2],
        "reduce words must shrink as pr grows: {col_words:?}"
    );
}

/// Grid runs also leave the gram-row cache effective: hits save measured
/// words on both subcommunicators' critical path, bit-identically.
#[test]
fn prop_grid_cache_saves_measured_words_bitwise() {
    let ds = gen_dense_classification(24, 12, 0.05, 33);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let run = |cache_rows: usize| {
        run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &SolverSpec {
                s: 8,
                h: 48,
                seed: 7,
                cache_rows,
                threads: 1,
                grid: Some((2, 3)),
            },
            6,
            AllreduceAlgo::Rabenseifner,
            &machine,
        )
    };
    let plain = run(0);
    let cached = run(16);
    assert_eq!(plain.alpha, cached.alpha, "cache must be bitwise-transparent");
    assert!(cached.critical.cache.hits > 0);
    assert!(
        cached.critical.comm.words < plain.critical.comm.words,
        "cached grid run must send fewer words: {} !< {}",
        cached.critical.comm.words,
        plain.critical.comm.words
    );
}

/// CommStats helper used by the traffic test.
#[test]
fn comm_stats_plus_composes() {
    let a = CommStats {
        msgs: 1,
        words: 2,
        rounds: 3,
        allreduces: 4,
    };
    assert_eq!(a.plus(CommStats::default()), a);
}
