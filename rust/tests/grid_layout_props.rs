//! Property suite for the 2D process-grid gram layout
//! (`gram::Layout::Grid`, `solvers::GridGram`), pinning the acceptance
//! matrix of the grid determinism contract (see `crate::gram`):
//!
//! * **1D ≡ 2D bitwise** — for every `(pr, pc)` factorization of
//!   `P ∈ {2, …, 12}`, a `Grid{pr, pc}` solve over `P` ranks returns α
//!   bit-identical to the 1D column-shard solve over `pc` ranks (the
//!   grid keeps the 1D path's `pc` feature shards and reduce tree and
//!   adds row parallelism around them; `Grid{1, P}` *is* the 1D path).
//!   Crossed with cache on/off and threads {1, 4} on a sub-matrix, plus
//!   the CI lane's `THREADS` value.
//! * **Row-block invariance** — the block-cyclic block size changes
//!   ownership, traffic and wall time, never a bit of the result.
//! * **Ledger cross-validation** — the column-subcommunicator (reduce)
//!   traffic matches the message-free `allreduce_counts_per_rank`
//!   replica over `pc` ranks, rank by rank, and the row allgather
//!   matches `allgatherv_counts_per_rank`; the reduce payload therefore
//!   scales with `pc` (not `P`).
//! * **Sharded storage (`GridStorage::Sharded`)** — sharded ≡
//!   replicated ≡ 1D@pc bitwise across the full factorization matrix ×
//!   cache × threads; the fragment-exchange traffic matches its ring
//!   replica rank by rank; and the per-rank memory model shrinks with
//!   `pr` (the layout's reason to exist), identically in the measured
//!   and analytic engines.
//! * **Overlapped communication (`OverlapMode`)** — the nonblocking
//!   exchange/pipeline overlaps replay the blocking bits exactly:
//!   every `(pr, pc)` factorization of `P ∈ {2, …, 12}` × storage ×
//!   applicable overlap mode equals the 1D@pc reference, and overlap
//!   composes bitwise with cache and threads on the sub-matrix (plus
//!   the CI lane's `OVERLAP` value via `testkit::env_overlap`).

use kcd::comm::{run_ranks, AllreduceAlgo, CommStats, Communicator};
use kcd::coordinator::scaling::{allgatherv_counts_per_rank, allreduce_counts_per_rank};
use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
use kcd::costmodel::{Ledger, MachineProfile};
use kcd::data::{gen_dense_classification, gen_uniform_sparse, Dataset, SynthParams, Task};
use kcd::dense::Mat;
use kcd::gram::{block_cyclic_rows, GridStorage, OverlapMode};
use kcd::kernelfn::Kernel;
use kcd::rng::Pcg;
use kcd::solvers::{GramOracle, GridGram, SvmVariant};
use kcd::testkit;

/// Every (pr, pc) with pr·pc == p, in deterministic order.
fn factorizations(p: usize) -> Vec<(usize, usize)> {
    (1..=p).filter(|pr| p % pr == 0).map(|pr| (pr, p / pr)).collect()
}

fn svm_problem() -> ProblemSpec {
    ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    }
}

/// Solver-level α of a 1D run at `p` ranks (serial for p = 1).
fn alpha_1d(ds: &Dataset, problem: &ProblemSpec, solver: &SolverSpec, p: usize) -> Vec<f64> {
    run_distributed(
        ds,
        Kernel::paper_rbf(),
        problem,
        solver,
        p,
        AllreduceAlgo::Rabenseifner,
        &MachineProfile::cray_ex(),
    )
    .alpha
}

/// The headline acceptance property: every factorization of every
/// `P ∈ {2, …, 12}` replays the 1D bits of its `pc`, for both problems
/// and **both storage modes** — the sharded cells' fragment exchange
/// must be bitwise-invisible (sharded ≡ replicated ≡ 1D@pc).
#[test]
fn prop_grid_solve_bitwise_equals_1d_over_pc_for_all_factorizations() {
    let ds = gen_dense_classification(24, 16, 0.05, 55);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 2 }];
    for problem in problems {
        let base = SolverSpec {
            s: 4,
            h: 16,
            seed: 9,
            cache_rows: 0,
            threads: 1,
            grid: None,
            ..Default::default()
        };
        // Memoize the 1D reference per pc (factorizations share them).
        let mut refs: Vec<Option<Vec<f64>>> = vec![None; 13];
        for p in 2..=12usize {
            for (pr, pc) in factorizations(p) {
                if refs[pc].is_none() {
                    refs[pc] = Some(alpha_1d(&ds, &problem, &base, pc));
                }
                let reference = refs[pc].as_ref().unwrap();
                for storage in [GridStorage::Replicated, GridStorage::Sharded] {
                    let grid_solver = SolverSpec {
                        grid: Some((pr, pc)),
                        grid_storage: storage,
                        ..base
                    };
                    let alpha = alpha_1d(&ds, &problem, &grid_solver, p);
                    assert_eq!(
                        &alpha,
                        reference,
                        "{problem:?} Grid{{{pr},{pc}}} {} must replay 1D@{pc} bits",
                        storage.name()
                    );
                }
            }
        }
    }
}

/// The overlap acceptance property: for every `(pr, pc)` factorization
/// of every `P ∈ {2, …, 12}`, both storage modes and both problems, the
/// nonblocking overlaps replay the 1D@pc reference bit for bit — the
/// posted fragment rings (`Exchange`, sharded cells) and the pipelined
/// s-step gram reduce (`Pipeline`) are pure wall-time knobs. Inert
/// combinations (exchange on replicated cells) are skipped here; the
/// CLI suite pins that they run and stay bitwise-identical too.
#[test]
fn prop_overlapped_solves_bitwise_equal_blocking_for_all_factorizations() {
    let ds = gen_dense_classification(24, 16, 0.05, 55);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 2 }];
    for problem in problems {
        let base = SolverSpec {
            s: 4,
            h: 16,
            seed: 9,
            cache_rows: 0,
            threads: 1,
            grid: None,
            ..Default::default()
        };
        // Memoize the blocking 1D reference per pc, exactly like the
        // blocking headline property above.
        let mut refs: Vec<Option<Vec<f64>>> = vec![None; 13];
        for p in 2..=12usize {
            for (pr, pc) in factorizations(p) {
                if refs[pc].is_none() {
                    refs[pc] = Some(alpha_1d(&ds, &problem, &base, pc));
                }
                let reference = refs[pc].as_ref().unwrap();
                for storage in [GridStorage::Replicated, GridStorage::Sharded] {
                    let overlaps: &[OverlapMode] = match storage {
                        GridStorage::Replicated => &[OverlapMode::Pipeline],
                        GridStorage::Sharded => {
                            &[OverlapMode::Exchange, OverlapMode::Pipeline]
                        }
                    };
                    for &overlap in overlaps {
                        let solver = SolverSpec {
                            grid: Some((pr, pc)),
                            grid_storage: storage,
                            overlap,
                            ..base
                        };
                        let alpha = alpha_1d(&ds, &problem, &solver, p);
                        assert_eq!(
                            &alpha,
                            reference,
                            "{problem:?} Grid{{{pr},{pc}}} {} {} must replay 1D@{pc} bits",
                            storage.name(),
                            overlap.name()
                        );
                    }
                }
            }
        }
    }
}

/// Cache, threads and overlap compose with the grid bitwise, including
/// the CI lane's THREADS/OVERLAP values — on a representative
/// factorization sub-matrix (the full cross-product would dominate
/// suite runtime).
#[test]
fn prop_grid_solve_bitwise_with_cache_and_threads() {
    let ds = gen_dense_classification(24, 16, 0.05, 55);
    let problem = svm_problem();
    let base = SolverSpec {
        s: 8,
        h: 24,
        seed: 11,
        cache_rows: 0,
        threads: 1,
        grid: None,
        ..Default::default()
    };
    let mut thread_counts = vec![1usize, 4];
    let env = testkit::env_threads();
    if !thread_counts.contains(&env) {
        thread_counts.push(env);
    }
    // The CI GRID lane injects a row-group count the hard-coded list
    // below does not cover (GRID=4 → (4, 2)): fold the env-driven
    // factorization into the sub-matrix so that lane genuinely extends
    // coverage (GRID=1 degenerates to the 1D path over 2 ranks, which
    // is covered anyway).
    let mut factorizations = vec![(2usize, 2usize), (3, 2), (2, 3), (6, 2), (4, 3)];
    let env_pr = testkit::env_grid_rows();
    if !factorizations.contains(&(env_pr, 2)) {
        factorizations.push((env_pr, 2));
    }
    // Storage composes with cache and threads bitwise too; the CI
    // GRID_STORAGE lane re-runs the whole sub-matrix sharded.
    let mut storages = vec![GridStorage::Replicated, GridStorage::Sharded];
    let env_storage = testkit::env_grid_storage();
    if !storages.contains(&env_storage) {
        storages.push(env_storage);
    }
    // Overlap composes with everything above bitwise as well — the
    // OVERLAP CI lane's mode is always one of the three, so the full
    // mode set already folds `testkit::env_overlap()` in.
    let overlaps = OverlapMode::all();
    assert!(overlaps.contains(&testkit::env_overlap()));
    for (pr, pc) in factorizations {
        let reference = alpha_1d(&ds, &problem, &base, pc);
        for &storage in &storages {
            for &threads in &thread_counts {
                for cache_rows in [0usize, 6] {
                    for overlap in overlaps {
                        let solver = SolverSpec {
                            cache_rows,
                            threads,
                            grid: Some((pr, pc)),
                            grid_storage: storage,
                            overlap,
                            ..base
                        };
                        let alpha = alpha_1d(&ds, &problem, &solver, pr * pc);
                        assert_eq!(
                            alpha,
                            reference,
                            "Grid{{{pr},{pc}}} {} t={threads} cache={cache_rows} overlap={}",
                            storage.name(),
                            overlap.name()
                        );
                    }
                }
            }
        }
    }
}

/// The sparse product path (transpose kernel) honors the same contract.
#[test]
fn prop_grid_solve_bitwise_on_sparse_data() {
    let ds = gen_uniform_sparse(
        SynthParams {
            m: 30,
            n: 200,
            density: 0.05,
            seed: 9,
        },
        Task::Classification,
    );
    let base = SolverSpec {
        s: 4,
        h: 16,
        seed: 3,
        cache_rows: 4,
        threads: 1,
        grid: None,
        ..Default::default()
    };
    let problem = svm_problem();
    for (pr, pc) in [(2usize, 2usize), (3, 2), (2, 4), (5, 2)] {
        let reference = alpha_1d(&ds, &problem, &base, pc);
        for storage in [GridStorage::Replicated, GridStorage::Sharded] {
            let solver = SolverSpec {
                grid: Some((pr, pc)),
                grid_storage: storage,
                ..base
            };
            let alpha = alpha_1d(&ds, &problem, &solver, pr * pc);
            assert_eq!(
                alpha,
                reference,
                "sparse Grid{{{pr},{pc}}} {}",
                storage.name()
            );
        }
    }
}

/// The block-cyclic block size is a pure wall-time/traffic knob: gram
/// blocks are bitwise invariant across row_block values (element bits
/// never depend on which row group owns a column).
#[test]
fn prop_grid_blocks_bitwise_invariant_in_row_block() {
    let ds = gen_dense_classification(24, 16, 0.0, 5);
    let m = ds.m();
    let kernel = Kernel::paper_rbf();
    let stream: Vec<Vec<usize>> = {
        let mut rng = Pcg::seeded(0x91);
        (0..6)
            .map(|_| {
                let k = rng.gen_range(1, 5);
                (0..k).map(|_| rng.gen_below(m)).collect()
            })
            .collect()
    };
    let (pr, pc) = (3usize, 2usize);
    let shards = ds.shard_cols(pc);
    let run = |row_block: usize| -> Vec<f64> {
        let shards = shards.clone();
        let stream = &stream;
        let outs = run_ranks(pr * pc, move |c| {
            let shard = shards[c.rank() % pc].clone();
            let mut grid = GridGram::with_opts(
                shard,
                kernel,
                c,
                AllreduceAlgo::Rabenseifner,
                pr,
                pc,
                row_block,
                GridStorage::Replicated,
                0,
                1,
            );
            let mut out = Vec::new();
            for sample in stream {
                let mut q = Mat::zeros(sample.len(), m);
                grid.gram(sample, &mut q, &mut Ledger::new());
                out.extend_from_slice(q.data());
            }
            out
        });
        for other in &outs[1..] {
            assert_eq!(&outs[0], other, "ranks disagree");
        }
        outs.into_iter().next().unwrap()
    };
    let reference = run(1);
    for row_block in [2usize, 3, 4, 7] {
        assert_eq!(run(row_block), reference, "row_block={row_block}");
    }
}

/// Ledger cross-validation: per-rank column-subcomm traffic matches the
/// message-free allreduce replica over pc ranks at the grid's reduced
/// payload, and the row allgather matches the ring replica — so the
/// analytic ledger's "reduce traffic scales with pc" story is pinned to
/// real messages.
#[test]
fn prop_grid_subcomm_traffic_matches_count_replicas() {
    let ds = gen_dense_classification(24, 16, 0.0, 7);
    let m = ds.m();
    // Linear kernel: simplest epilogue, but the construction-time norms
    // allreduce still runs (it does for every kernel), so the expected
    // column traffic includes it.
    let kernel = Kernel::Linear;
    let row_block = 2usize;
    // Distinct-row samples: with the cache off every sampled row is a
    // miss, so each call's reduce payload is exactly k·|owned|.
    let samples = [vec![0usize, 5, 9], vec![1usize, 2], vec![20usize, 3, 7, 11]];
    for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::RecursiveDoubling] {
        for (pr, pc) in [(2usize, 2usize), (2, 3), (3, 2), (4, 2)] {
            let shards = ds.shard_cols(pc);
            let owned_len: Vec<usize> = (0..pr)
                .map(|g| block_cyclic_rows(m, pr, g, row_block).len())
                .collect();
            let stats = run_ranks(pr * pc, |c| {
                let shard = shards[c.rank() % pc].clone();
                let mut grid =
                    GridGram::with_opts(
                        shard,
                        kernel,
                        c,
                        algo,
                        pr,
                        pc,
                        row_block,
                        GridStorage::Replicated,
                        0,
                        1,
                    );
                for sample in &samples {
                    let mut q = Mat::zeros(sample.len(), m);
                    grid.gram(sample, &mut q, &mut Ledger::new());
                }
                (grid.col_stats(), grid.row_stats(), grid.comm_stats())
            });
            for (rank, (col, row, total)) in stats.iter().enumerate() {
                let (i, j) = (rank / pc, rank % pc);
                // Column subcomm: one m-word norms allreduce plus one
                // k·|owned_i|-word allreduce per gram call, at column
                // rank j.
                let mut expect_words = allreduce_counts_per_rank(m, pc, algo)[j].0;
                let mut expect_rounds = allreduce_counts_per_rank(m, pc, algo)[j].1;
                for sample in &samples {
                    let counts =
                        allreduce_counts_per_rank(sample.len() * owned_len[i], pc, algo);
                    expect_words += counts[j].0;
                    expect_rounds += counts[j].1;
                }
                assert_eq!(col.words, expect_words, "{algo:?} {pr}x{pc} rank {rank} col");
                assert_eq!(col.rounds, expect_rounds, "{algo:?} {pr}x{pc} rank {rank}");
                assert_eq!(col.allreduces, 1 + samples.len() as u64);
                // Row subcomm: one ring allgatherv per gram call at row
                // rank i, with per-group counts k·|owned_g|.
                let mut expect_row_words = 0u64;
                let mut expect_row_rounds = 0u64;
                for sample in &samples {
                    let counts: Vec<usize> =
                        owned_len.iter().map(|&w| sample.len() * w).collect();
                    let ring = allgatherv_counts_per_rank(&counts);
                    expect_row_words += ring[i].0;
                    expect_row_rounds += ring[i].1;
                }
                assert_eq!(row.words, expect_row_words, "{algo:?} {pr}x{pc} rank {rank} row");
                assert_eq!(row.rounds, expect_row_rounds, "{algo:?} {pr}x{pc} rank {rank}");
                // The oracle's total is the sequential-stage sum.
                assert_eq!(*total, col.plus(*row), "{pr}x{pc} rank {rank} total");
            }
        }
    }
}

/// Measured end to end: at fixed P, growing pr (shrinking pc) must
/// strictly shrink the words the reduce collective moves — the grid's
/// reason to exist — while α stays within tolerance of the serial solve.
#[test]
fn prop_reduce_traffic_shrinks_as_rows_grow() {
    let ds = gen_dense_classification(32, 16, 0.05, 21);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let base = SolverSpec {
        s: 4,
        h: 16,
        seed: 13,
        cache_rows: 0,
        threads: 1,
        grid: None,
        ..Default::default()
    };
    let serial = run_distributed(
        &ds,
        Kernel::paper_rbf(),
        &problem,
        &base,
        1,
        AllreduceAlgo::Rabenseifner,
        &machine,
    )
    .alpha;
    let p = 8usize;
    let mut col_words = Vec::new();
    for pr in [1usize, 2, 4] {
        let solver = SolverSpec {
            grid: Some((pr, p / pr)),
            ..base
        };
        let res = run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &solver,
            p,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        testkit::assert_close(&res.alpha, &serial, 1e-9, &format!("pr={pr}"));
        col_words.push(res.critical.comm_col.words);
        // The ledger splits the grid traffic by subcommunicator.
        assert_eq!(
            res.critical.comm_col.words + res.critical.comm_row.words,
            res.critical.comm.words,
            "pr={pr}: col+row must compose the total"
        );
        if pr == 1 {
            assert_eq!(res.critical.comm_row.words, 0, "pr=1 has no allgather");
        }
    }
    assert!(
        col_words[0] > col_words[1] && col_words[1] > col_words[2],
        "reduce words must shrink as pr grows: {col_words:?}"
    );
}

/// Grid runs also leave the gram-row cache effective: hits save measured
/// words on both subcommunicators' critical path, bit-identically.
#[test]
fn prop_grid_cache_saves_measured_words_bitwise() {
    let ds = gen_dense_classification(24, 12, 0.05, 33);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let run = |cache_rows: usize| {
        run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &problem,
            &SolverSpec {
                s: 8,
                h: 48,
                seed: 7,
                cache_rows,
                threads: 1,
                grid: Some((2, 3)),
                ..Default::default()
            },
            6,
            AllreduceAlgo::Rabenseifner,
            &machine,
        )
    };
    let plain = run(0);
    let cached = run(16);
    assert_eq!(plain.alpha, cached.alpha, "cache must be bitwise-transparent");
    assert!(cached.critical.cache.hits > 0);
    assert!(
        cached.critical.comm.words < plain.critical.comm.words,
        "cached grid run must send fewer words: {} !< {}",
        cached.critical.comm.words,
        plain.critical.comm.words
    );
}

/// Rank-by-rank fragment-exchange traffic replica: the sharded cells'
/// measured exchange counters (setup ring + one ring per gram call)
/// must equal the message-free `allgatherv_counts_per_rank` composition
/// exactly — per rank, not just on the max — with per-group counts
/// `2·Σ nnz` of each call's deduplicated sampled rows in that cell's
/// feature shard.
#[test]
fn prop_sharded_exchange_traffic_matches_ring_replica_per_rank() {
    let ds = gen_uniform_sparse(
        SynthParams {
            m: 24,
            n: 60,
            density: 0.2,
            seed: 13,
        },
        Task::Classification,
    );
    let m = ds.m();
    let kernel = Kernel::Linear;
    let row_block = 2usize;
    // Duplicate-bearing samples: the exchange must dedup before ringing.
    let samples = [vec![0usize, 5, 5, 9], vec![1usize, 2], vec![20usize, 3, 7, 3, 11]];
    for (pr, pc) in [(2usize, 2usize), (3, 2), (2, 3), (4, 1), (1, 4)] {
        let shards = ds.shard_cols(pc);
        let owned_rows: Vec<Vec<usize>> = (0..pr)
            .map(|g| block_cyclic_rows(m, pr, g, row_block))
            .collect();
        let owned_len: Vec<usize> = owned_rows.iter().map(|o| o.len()).collect();
        let stats = run_ranks(pr * pc, |c| {
            let shard = shards[c.rank() % pc].clone();
            let mut grid = GridGram::with_opts(
                shard,
                kernel,
                c,
                AllreduceAlgo::Rabenseifner,
                pr,
                pc,
                row_block,
                GridStorage::Sharded,
                0,
                1,
            );
            for sample in &samples {
                let mut q = Mat::zeros(sample.len(), m);
                grid.gram(sample, &mut q, &mut Ledger::new());
            }
            (
                grid.exch_stats(),
                grid.col_stats(),
                grid.row_stats(),
                grid.comm_stats(),
                grid.resident_nnz(),
            )
        });
        // Pin the memory model's data source to the engine's reality: a
        // sharded cell's resident entries are exactly its grid cell's
        // nnz (the number `mem_words_per_rank` counts via
        // `grid_cell_nnz`).
        let cell_nnz = kcd::coordinator::scaling::grid_cell_nnz(&ds.a, pr, pc, row_block);
        for (rank, (exch, col, row, total, resident)) in stats.iter().enumerate() {
            let (i, j) = (rank / pc, rank % pc);
            assert_eq!(
                *resident, cell_nnz[i][j],
                "{pr}x{pc} rank {rank}: sharded residency must equal its cell nnz"
            );
            // Setup ring: (norm, nnz) pairs, counts 2·|owned_g|.
            let setup_counts: Vec<usize> = owned_len.iter().map(|&w| 2 * w).collect();
            let ring = allgatherv_counts_per_rank(&setup_counts);
            let (mut expect_words, mut expect_rounds) = ring[i];
            // One ring per gram call with dedup'd per-group nnz counts.
            for sample in &samples {
                let mut uniq = sample.clone();
                uniq.sort_unstable();
                uniq.dedup();
                let counts: Vec<usize> = (0..pr)
                    .map(|g| {
                        uniq.iter()
                            .filter(|&&t| (t / row_block) % pr == g)
                            .map(|&t| 2 * shards[j].row_nnz(t))
                            .sum()
                    })
                    .collect();
                let ring = allgatherv_counts_per_rank(&counts);
                expect_words += ring[i].0;
                expect_rounds += ring[i].1;
            }
            assert_eq!(exch.words, expect_words, "{pr}x{pc} rank {rank} exch words");
            assert_eq!(exch.rounds, expect_rounds, "{pr}x{pc} rank {rank} exch rounds");
            assert_eq!(exch.msgs, expect_rounds, "ring sends once per round");
            assert_eq!(exch.allreduces, 0, "the exchange is not an allreduce");
            // The oracle's total composes all three sequential stages.
            assert_eq!(*total, col.plus(*row).plus(*exch), "{pr}x{pc} rank {rank}");
            if pr == 1 {
                assert_eq!(exch.words, 0, "single-group exchange is free");
                assert_eq!(exch.rounds, 0);
            }
        }
    }
}

/// The memory model behind the sharded storage's reason to exist: at a
/// fixed feature-shard count `pc`, growing `pr` strictly shrinks a
/// sharded cell's per-rank footprint (replicated cells stay flat — they
/// hold the full shard regardless of `pr`), sharded is strictly below
/// replicated on every genuine grid, and the measured engine reports
/// exactly the same number as the analytic one.
#[test]
fn prop_sharded_mem_shrinks_with_pr_and_matches_measured() {
    let ds = gen_dense_classification(48, 16, 0.05, 21);
    let problem = svm_problem();
    let machine = MachineProfile::cray_ex();
    let pc = 2usize;
    let mut sharded_mem = Vec::new();
    let mut replicated_mem = Vec::new();
    for pr in [1usize, 2, 4] {
        let mut mems = [0u64; 2];
        for (slot, storage) in [GridStorage::Replicated, GridStorage::Sharded]
            .into_iter()
            .enumerate()
        {
            let solver = SolverSpec {
                s: 4,
                h: 8,
                seed: 3,
                cache_rows: 0,
                threads: 1,
                grid: Some((pr, pc)),
                grid_storage: storage,
                ..Default::default()
            };
            let res = run_distributed(
                &ds,
                Kernel::paper_rbf(),
                &problem,
                &solver,
                pr * pc,
                AllreduceAlgo::Rabenseifner,
                &machine,
            );
            let analytic = kcd::coordinator::scaling::grid_analytic_ledger(
                &ds,
                Kernel::paper_rbf(),
                &problem,
                4,
                8,
                pr,
                pc,
                solver.row_block,
                storage,
                &kcd::schedule::ScheduleSpec::default(),
                3,
                AllreduceAlgo::Rabenseifner,
                OverlapMode::Off,
            );
            assert_eq!(
                res.critical.mem_per_rank(),
                analytic.mem_per_rank(),
                "pr={pr} {}: measured and analytic memory must agree",
                storage.name()
            );
            mems[slot] = res.critical.mem_per_rank();
        }
        replicated_mem.push(mems[0]);
        sharded_mem.push(mems[1]);
        if pr > 1 {
            assert!(
                mems[1] < mems[0],
                "pr={pr}: sharded {} must undercut replicated {}",
                mems[1],
                mems[0]
            );
        }
    }
    assert!(
        sharded_mem[0] > sharded_mem[1] && sharded_mem[1] > sharded_mem[2],
        "sharded per-rank memory must shrink as pr grows: {sharded_mem:?}"
    );
    // Replicated cells keep the full m×(n/pc) shard regardless of pr —
    // a hard floor no pr can shave — while sharded cells drop below it
    // once pr bites.
    let shard_floor = 2 * ds.a.max_shard_nnz(pc) as u64;
    for (idx, &mem) in replicated_mem.iter().enumerate() {
        assert!(
            mem >= shard_floor,
            "replicated mem {mem} at index {idx} fell below the full-shard floor {shard_floor}"
        );
    }
    assert!(
        sharded_mem[2] < shard_floor,
        "sharded at pr=4 ({}) must undercut the replicated full-shard floor {shard_floor}",
        sharded_mem[2]
    );
}

/// CommStats helper used by the traffic test.
#[test]
fn comm_stats_plus_composes() {
    let a = CommStats {
        msgs: 1,
        words: 2,
        rounds: 3,
        allreduces: 4,
    };
    assert_eq!(a.plus(CommStats::default()), a);
}
