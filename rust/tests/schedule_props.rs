//! Property suite for the coordinate-schedule subsystem
//! (`kcd::schedule`), pinning the acceptance matrix of the schedule
//! determinism contract (see the module docs):
//!
//! * **Uniform ≡ pre-schedule bitwise** — the `Uniform` schedule
//!   replays the raw `SVM_COORD_STREAM` / `KRR_COORD_STREAM` PCG draws
//!   bit for bit, so every legacy entry point (`dcd`, `dcd_sstep`,
//!   `bdcd`, `bdcd_sstep`) equals its `*_with_schedule` form under an
//!   explicitly-built `Uniform` — the default schedule changes nothing.
//! * **Bitwise invariance to engine knobs** — for a *fixed*
//!   `ScheduleSpec`, the solve is bitwise-invariant to threads, cache
//!   capacity, `row_block`, grid storage mode and overlap mode, for
//!   every schedule kind (the locality-aware shadow LRU reads its own
//!   `shadow_rows`, never the engine), plus the CI lane's `SCHEDULE`
//!   value via `testkit::env_schedule`.
//! * **Locality beats uniform where it aims to** — on a repeat-heavy
//!   cached sharded-grid workload the locality-aware schedule delivers
//!   a strictly higher measured kernel-row cache hit rate AND strictly
//!   fewer measured fragment-exchange words than uniform sampling.
//! * **Analytic ≡ measured for every schedule** — the analytic grid
//!   ledger replays the schedule's exact sample stream, so its
//!   exchange/traffic counters equal measured `CommStats` for the
//!   non-uniform kinds too.
//! * **Shadow ≡ real cache** — the locality-aware schedule's shadow
//!   LRU tracks the real engine cache's residency row for row when
//!   both are sized equally (`cache_resident` probe).

use kcd::comm::AllreduceAlgo;
use kcd::coordinator::scaling::grid_analytic_ledger;
use kcd::coordinator::{run_distributed, ProblemSpec, SolverSpec};
use kcd::costmodel::{Ledger, MachineProfile};
use kcd::data::gen_dense_classification;
use kcd::dense::Mat;
use kcd::gram::{GridStorage, OverlapMode};
use kcd::kernelfn::Kernel;
use kcd::rng::Pcg;
use kcd::schedule::{
    build_schedule, call_samples, packed_row_costs, LocalityAware, Schedule, ScheduleKind,
    ScheduleSpec, Uniform,
};
use kcd::solvers::{
    bdcd, bdcd_sstep, bdcd_sstep_with_schedule, bdcd_with_schedule, dcd, dcd_sstep,
    dcd_sstep_with_schedule, dcd_with_schedule, GramOracle, KrrParams, LocalGram, SvmParams,
    SvmVariant, KRR_COORD_STREAM, SVM_COORD_STREAM,
};
use kcd::testkit;

fn svm_problem() -> ProblemSpec {
    ProblemSpec::Svm {
        c: 1.0,
        variant: SvmVariant::L1,
    }
}

/// A locality-aware spec shaped like the tuner's sharded-grid
/// candidates: shadow sized to the cache under test, grid-shaped
/// exchange balancing.
fn locality_spec(shadow: usize, groups: usize, group_block: usize) -> ScheduleSpec {
    let mut spec = ScheduleSpec::of(ScheduleKind::LocalityAware);
    spec.shadow_rows = shadow;
    spec.pool = 4;
    spec.groups = groups;
    spec.group_block = group_block;
    spec
}

/// The schedule specs the invariance matrix sweeps: one per kind (the
/// locality spec with non-trivial grouping), plus the CI lane's
/// `SCHEDULE` value so the `SCHEDULE=locality` lane genuinely extends
/// coverage.
fn spec_matrix() -> Vec<ScheduleSpec> {
    let mut specs = vec![
        ScheduleSpec::default(),
        ScheduleSpec::of(ScheduleKind::ShuffledEpochs),
        locality_spec(16, 2, 4),
    ];
    let env = testkit::env_schedule();
    if !specs.contains(&env) {
        specs.push(env);
    }
    specs
}

/// The Uniform schedule IS the pre-schedule sampler: the legacy entry
/// points equal their `*_with_schedule` forms under an explicit
/// `Uniform`, and `call_samples` replays the raw PCG draw sequence the
/// solvers consumed before schedules existed — both coordinate streams
/// (`b = 1` single draws and `b > 1` without-replacement blocks).
#[test]
fn uniform_schedule_is_bitwise_identical_to_pre_schedule_solves() {
    let ds = gen_dense_classification(26, 8, 0.1, 41);
    let m = ds.m();

    // Raw stream replay, independent of any solver.
    let uniform = ScheduleSpec::default();
    for (stream, b) in [(SVM_COORD_STREAM, 1usize), (KRR_COORD_STREAM, 4)] {
        let (s, h) = (5usize, 23usize);
        let calls = call_samples(&uniform, m, 0xFEED, stream, s, h, b, &[]);
        let mut rng = Pcg::new(0xFEED, stream);
        let mut done = 0usize;
        for call in &calls {
            let s_now = s.min(h - done);
            assert_eq!(call.len(), s_now * b);
            let expect: Vec<usize> = if b == 1 {
                (0..s_now).map(|_| rng.gen_below(m)).collect()
            } else {
                (0..s_now)
                    .flat_map(|_| rng.sample_without_replacement(m, b))
                    .collect()
            };
            assert_eq!(call, &expect, "stream {stream:#x}");
            done += s_now;
        }
        assert_eq!(done, h);
    }

    // Solver-level equality: legacy wrapper ≡ explicit Uniform schedule.
    let svm = SvmParams {
        c: 1.0,
        variant: SvmVariant::L1,
        h: 48,
        seed: 3,
    };
    let krr = KrrParams {
        lambda: 1.0,
        b: 3,
        h: 24,
        seed: 3,
    };
    let oracle = || LocalGram::with_cache(ds.a.clone(), Kernel::paper_rbf(), 8);
    let legacy = dcd(&mut oracle(), &ds.y, &svm, &mut Ledger::new(), None);
    let mut sched = Uniform::new(m, svm.seed, SVM_COORD_STREAM);
    let explicit = dcd_with_schedule(
        &mut oracle(),
        &ds.y,
        &svm,
        &mut sched,
        &mut Ledger::new(),
        None,
    );
    assert_eq!(legacy, explicit, "dcd");

    let legacy = dcd_sstep(&mut oracle(), &ds.y, &svm, 6, &mut Ledger::new(), None);
    let mut sched = Uniform::new(m, svm.seed, SVM_COORD_STREAM);
    let explicit = dcd_sstep_with_schedule(
        &mut oracle(),
        &ds.y,
        &svm,
        6,
        &mut sched,
        &mut Ledger::new(),
        None,
    );
    assert_eq!(legacy, explicit, "dcd_sstep");

    let legacy = bdcd(&mut oracle(), &ds.y, &krr, &mut Ledger::new(), None);
    let mut sched = Uniform::new(m, krr.seed, KRR_COORD_STREAM);
    let explicit = bdcd_with_schedule(
        &mut oracle(),
        &ds.y,
        &krr,
        &mut sched,
        &mut Ledger::new(),
        None,
    );
    assert_eq!(legacy, explicit, "bdcd");

    let legacy = bdcd_sstep(&mut oracle(), &ds.y, &krr, 4, &mut Ledger::new(), None);
    let mut sched = Uniform::new(m, krr.seed, KRR_COORD_STREAM);
    let explicit = bdcd_sstep_with_schedule(
        &mut oracle(),
        &ds.y,
        &krr,
        4,
        &mut sched,
        &mut Ledger::new(),
        None,
    );
    assert_eq!(legacy, explicit, "bdcd_sstep");
}

/// The headline determinism contract: for a fixed `ScheduleSpec` the
/// solve is bitwise-invariant to every engine knob — threads, cache
/// capacity, `row_block`, grid storage and overlap mode — for every
/// schedule kind. The locality-aware spec keeps its own `shadow_rows`
/// and `group_block`, so varying the *engine's* cache and row block
/// must not move a bit.
#[test]
fn prop_solves_are_bitwise_invariant_to_engine_knobs_for_every_schedule() {
    let ds = gen_dense_classification(18, 6, 0.1, 55);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
    let machine = MachineProfile::cray_ex();
    for spec in spec_matrix() {
        for problem in &problems {
            let base = SolverSpec {
                s: 5,
                h: 24,
                seed: 9,
                schedule: spec,
                ..Default::default()
            };
            // Serial knobs: threads and cache capacity.
            let reference = run_distributed(
                &ds,
                Kernel::paper_rbf(),
                problem,
                &base,
                1,
                AllreduceAlgo::Rabenseifner,
                &machine,
            )
            .alpha;
            for (threads, cache_rows) in [(3usize, 16usize), (testkit::env_threads(), 8)] {
                let solver = SolverSpec {
                    threads,
                    cache_rows,
                    ..base
                };
                let alpha = run_distributed(
                    &ds,
                    Kernel::paper_rbf(),
                    problem,
                    &solver,
                    1,
                    AllreduceAlgo::Rabenseifner,
                    &machine,
                )
                .alpha;
                assert_eq!(
                    alpha,
                    reference,
                    "{} {}: t={threads} cache={cache_rows}",
                    spec.label(),
                    problem.name()
                );
            }
            // Grid knobs: the 2x2 grid over 4 ranks must replay the 1D
            // solve over pc = 2 ranks for both storage modes, several
            // row blocks and every applicable overlap mode.
            let ref_1d = run_distributed(
                &ds,
                Kernel::paper_rbf(),
                problem,
                &base,
                2,
                AllreduceAlgo::Rabenseifner,
                &machine,
            )
            .alpha;
            for storage in [GridStorage::Replicated, GridStorage::Sharded] {
                for row_block in [2usize, 5] {
                    for overlap in [OverlapMode::Off, OverlapMode::Exchange, OverlapMode::Pipeline]
                    {
                        let solver = SolverSpec {
                            grid: Some((2, 2)),
                            grid_storage: storage,
                            row_block,
                            overlap,
                            cache_rows: 16,
                            threads: 2,
                            ..base
                        };
                        let alpha = run_distributed(
                            &ds,
                            Kernel::paper_rbf(),
                            problem,
                            &solver,
                            4,
                            AllreduceAlgo::Rabenseifner,
                            &machine,
                        )
                        .alpha;
                        assert_eq!(
                            alpha,
                            ref_1d,
                            "{} {}: {} rb={row_block} overlap={}",
                            spec.label(),
                            problem.name(),
                            storage.name(),
                            overlap.name()
                        );
                    }
                }
            }
        }
    }
}

/// The perf acceptance criterion: on a repeat-heavy cached sharded 2x2
/// workload the locality-aware schedule is *strictly* better than
/// uniform sampling on both counters it optimizes — measured kernel-row
/// cache hit rate up, measured fragment-exchange words down. (The
/// shadow is sized to the real cache, pool 4, groups matching `pr`,
/// exactly like the tuner's sharded-grid candidates.)
#[test]
fn locality_schedule_beats_uniform_on_repeat_heavy_sharded_cached_grid() {
    let ds = gen_dense_classification(64, 12, 0.1, 23);
    let machine = MachineProfile::cray_ex();
    let (row_block, cache_rows) = (4usize, 16usize);
    let run = |schedule: ScheduleSpec| {
        let solver = SolverSpec {
            s: 8,
            h: 256,
            seed: 5,
            cache_rows,
            grid: Some((2, 2)),
            grid_storage: GridStorage::Sharded,
            row_block,
            schedule,
            ..Default::default()
        };
        let out = run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &svm_problem(),
            &solver,
            4,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        assert!(out.alpha.iter().all(|a| a.is_finite()));
        // The sample stream (and so every hit/miss decision) is
        // replicated across ranks; exchange words are summed because
        // the ring's per-rank share varies with group ownership.
        for l in &out.per_rank {
            assert_eq!(l.cache.hits, out.per_rank[0].cache.hits);
            assert_eq!(l.cache.misses, out.per_rank[0].cache.misses);
        }
        let words: u64 = out.per_rank.iter().map(|l| l.comm_exch.words).sum();
        (out.critical.cache, words)
    };
    let (uni_cache, uni_words) = run(ScheduleSpec::default());
    let (loc_cache, loc_words) = run(locality_spec(cache_rows, 2, row_block));
    assert!(
        loc_cache.hit_rate() > uni_cache.hit_rate(),
        "locality must strictly raise the cache hit rate: {:.3} vs {:.3}",
        loc_cache.hit_rate(),
        uni_cache.hit_rate()
    );
    assert!(
        loc_words < uni_words,
        "locality must strictly cut exchange words: {loc_words} vs {uni_words}"
    );
    // Sanity on the magnitude: uniform's hit rate on a 16-row cache
    // over 64 rows hovers near 1/4; greedy best-of-4 selection should
    // clear it by a wide margin, not by luck of a tie-break.
    assert!(
        loc_cache.hit_rate() - uni_cache.hit_rate() > 0.1,
        "expected a decisive gap, got {:.3} vs {:.3}",
        loc_cache.hit_rate(),
        uni_cache.hit_rate()
    );
}

/// The analytic grid ledger replays the *schedule's* sample stream, so
/// its traffic counters must equal measured execution for the
/// non-uniform kinds too (uniform is pinned in
/// `coordinator::scaling::tests`): total/col/row/exchange words and
/// rounds, exchange msgs, kernel call/row counts and the memory model,
/// for both problems on a sharded grid.
#[test]
fn analytic_replicas_match_measured_for_non_uniform_schedules() {
    let machine = MachineProfile::cray_ex();
    let ds = gen_dense_classification(24, 16, 0.05, 12);
    let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
    let row_block = 3usize;
    let specs = [
        ScheduleSpec::of(ScheduleKind::ShuffledEpochs),
        locality_spec(16, 2, row_block),
    ];
    for spec in specs {
        for problem in &problems {
            for (pr, pc) in [(2usize, 2usize), (2, 3)] {
                for s in [1usize, 4] {
                    let h = 16;
                    let solver = SolverSpec {
                        s,
                        h,
                        seed: 77,
                        grid: Some((pr, pc)),
                        grid_storage: GridStorage::Sharded,
                        row_block,
                        schedule: spec,
                        ..Default::default()
                    };
                    let measured = run_distributed(
                        &ds,
                        Kernel::paper_rbf(),
                        problem,
                        &solver,
                        pr * pc,
                        AllreduceAlgo::Rabenseifner,
                        &machine,
                    )
                    .critical;
                    let analytic = grid_analytic_ledger(
                        &ds,
                        Kernel::paper_rbf(),
                        problem,
                        s,
                        h,
                        pr,
                        pc,
                        row_block,
                        GridStorage::Sharded,
                        &spec,
                        77,
                        AllreduceAlgo::Rabenseifner,
                        OverlapMode::Off,
                    );
                    let tag = format!("{} {} {pr}x{pc} s={s}", spec.label(), problem.name());
                    for (which, a, m) in [
                        ("total", analytic.comm, measured.comm),
                        ("col", analytic.comm_col, measured.comm_col),
                        ("row", analytic.comm_row, measured.comm_row),
                        ("exch", analytic.comm_exch, measured.comm_exch),
                    ] {
                        assert_eq!(a.words, m.words, "{tag} {which} words");
                        assert_eq!(a.rounds, m.rounds, "{tag} {which} rounds");
                    }
                    assert_eq!(
                        analytic.comm_exch.msgs, measured.comm_exch.msgs,
                        "{tag} exch msgs"
                    );
                    assert_eq!(analytic.kernel_calls, measured.kernel_calls, "{tag}");
                    assert_eq!(analytic.kernel_rows, measured.kernel_rows, "{tag}");
                    assert_eq!(analytic.mem_per_rank(), measured.mem_per_rank(), "{tag}");
                    assert!(analytic.comm_exch.words > 0, "{tag}");
                }
            }
        }
    }
}

/// The locality-aware shadow LRU replays the real `RowCache`'s
/// classify/commit semantics exactly: drive a cached `LocalGram` with
/// the schedule's own stream and, after every call, the shadow's
/// residency must equal the engine's (`cache_resident`) for all rows —
/// including with-replacement repeats and within-call duplicates.
#[test]
fn shadow_lru_tracks_real_cache_residency_row_for_row() {
    let ds = gen_dense_classification(40, 8, 0.1, 66);
    let m = ds.m();
    for capacity in [4usize, 8, 16] {
        let mut spec = locality_spec(capacity, 0, 4);
        spec.pool = 3;
        let mut sched = LocalityAware::new(m, 0xCAFE, SVM_COORD_STREAM, &spec, &[]);
        let mut oracle = LocalGram::with_cache(ds.a.clone(), Kernel::paper_rbf(), capacity);
        let mut sample = Vec::new();
        let mut q = Mat::zeros(4, m);
        let mut ledger = Ledger::new();
        for call in 0..48 {
            sched.next_call(4, 1, &mut sample);
            oracle.gram(&sample, &mut q, &mut ledger);
            for row in 0..m {
                assert_eq!(
                    sched.shadow_resident(row),
                    oracle.cache_resident(row),
                    "capacity={capacity} call={call} row={row}"
                );
            }
        }
        assert!(ledger.cache.hits > 0, "capacity={capacity}: stream must re-hit");
    }
}

/// `call_samples` is the single replay primitive the analytic ledgers
/// build on: replaying it twice (or via `build_schedule` driven by
/// hand) yields identical streams, every call has the exact `s_now · b`
/// shape, all indices are in range, and within-block draws are
/// distinct for every schedule kind.
#[test]
fn call_samples_replays_exactly_and_respects_block_shape() {
    let ds = gen_dense_classification(30, 6, 0.1, 19);
    let m = ds.m();
    let row_cost = packed_row_costs(&ds.a);
    assert_eq!(row_cost.len(), m);
    for spec in spec_matrix() {
        for (stream, b) in [(SVM_COORD_STREAM, 1usize), (KRR_COORD_STREAM, 3)] {
            let (s, h) = (4usize, 18usize);
            let a = call_samples(&spec, m, 7, stream, s, h, b, &row_cost);
            let bb = call_samples(&spec, m, 7, stream, s, h, b, &row_cost);
            assert_eq!(a, bb, "{}: replay must be bitwise", spec.label());
            // Hand-driven schedule sees the identical stream.
            let mut sched = build_schedule(&spec, m, 7, stream, &row_cost);
            let mut buf = Vec::new();
            let mut done = 0usize;
            for call in &a {
                let s_now = s.min(h - done);
                sched.next_call(s_now, b, &mut buf);
                assert_eq!(&buf, call, "{}", spec.label());
                assert_eq!(call.len(), s_now * b);
                for block in call.chunks(b) {
                    for (i, &t) in block.iter().enumerate() {
                        assert!(t < m);
                        if b > 1 {
                            assert!(
                                !block[..i].contains(&t),
                                "{}: within-block duplicate",
                                spec.label()
                            );
                        }
                    }
                }
                done += s_now;
            }
            assert_eq!(done, h);
        }
    }
}
