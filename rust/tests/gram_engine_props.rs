//! Property suites for the staged gram engine: oracle equivalence
//! against direct kernel evaluation, and the cache-determinism contract
//! (cache on ⇒ bitwise-identical blocks and solver outputs).

use kcd::comm::{run_ranks, AllreduceAlgo, Communicator};
use kcd::costmodel::Ledger;
use kcd::data::{gen_dense_classification, gen_uniform_sparse, Dataset, SynthParams, Task};
use kcd::dense::Mat;
use kcd::kernelfn::Kernel;
use kcd::solvers::{
    bdcd, bdcd_sstep, dcd, dcd_sstep, DistGram, GramOracle, KrrParams, LocalGram, SvmParams,
    SvmVariant,
};

fn kernels() -> [Kernel; 3] {
    [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()]
}

/// Definition-based reference: `K(a_{S_r}, a_i)` from dense rows.
fn direct_block(d: &Mat, kernel: Kernel, sample: &[usize]) -> Mat {
    let m = d.nrows();
    let mut q = Mat::zeros(sample.len(), m);
    for (r, &sr) in sample.iter().enumerate() {
        for i in 0..m {
            let dot = kcd::dense::dot(d.row(sr), d.row(i));
            let na = kcd::dense::dot(d.row(sr), d.row(sr));
            let nb = kcd::dense::dot(d.row(i), d.row(i));
            q[(r, i)] = kernel.apply_scalar(dot, na, nb);
        }
    }
    q
}

/// A deterministic with-replacement sample stream (DCD's access pattern,
/// which is what makes the cache hit).
fn sample_stream(m: usize, calls: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = kcd::rng::Pcg::seeded(seed);
    (0..calls)
        .map(|_| {
            let k = rng.gen_range(1, 6);
            (0..k).map(|_| rng.gen_below(m)).collect()
        })
        .collect()
}

/// Run the engine (local or distributed) over a sample stream, returning
/// the concatenated blocks.
fn run_engine(
    ds: &Dataset,
    kernel: Kernel,
    p: usize,
    cache_rows: usize,
    stream: &[Vec<usize>],
) -> Vec<f64> {
    let m = ds.m();
    if p == 1 {
        let mut oracle = LocalGram::with_cache(ds.a.clone(), kernel, cache_rows);
        let mut out = Vec::new();
        for sample in stream {
            let mut q = Mat::zeros(sample.len(), m);
            oracle.gram(sample, &mut q, &mut Ledger::new());
            out.extend_from_slice(q.data());
        }
        return out;
    }
    let shards = ds.shard_cols(p);
    let outs = run_ranks(p, move |c| {
        let shard = shards[c.rank()].clone();
        let mut oracle =
            DistGram::with_cache(shard, kernel, c, AllreduceAlgo::Rabenseifner, cache_rows);
        let mut out = Vec::new();
        for sample in stream {
            let mut q = Mat::zeros(sample.len(), m);
            oracle.gram(sample, &mut q, &mut Ledger::new());
            out.extend_from_slice(q.data());
        }
        out
    });
    // All ranks hold the replicated block; they must agree bitwise.
    for other in &outs[1..] {
        assert_eq!(&outs[0], other, "ranks disagree");
    }
    outs.into_iter().next().unwrap()
}

/// Oracle equivalence: cached and uncached engines, all three kernels,
/// p ∈ {1, 2, 4}, sparse and dense data — cached ≡ uncached bitwise, and
/// both match direct kernel evaluation (bitwise at p = 1, where the
/// summation order is identical; within 1e-9 across ranks, where the
/// allreduce regroups the partial sums).
#[test]
fn prop_engine_matches_direct_evaluation_cached_and_uncached() {
    let dense = gen_dense_classification(24, 10, 0.0, 42);
    let sparse = gen_uniform_sparse(
        SynthParams {
            m: 26,
            n: 120,
            density: 0.05,
            seed: 7,
        },
        Task::Classification,
    );
    for ds in [&dense, &sparse] {
        let d = ds.a.to_dense();
        let stream = sample_stream(ds.m(), 10, 0xCAFE);
        for kernel in kernels() {
            let reference: Vec<f64> = stream
                .iter()
                .flat_map(|s| direct_block(&d, kernel, s).data().to_vec())
                .collect();
            for p in [1usize, 2, 4] {
                let plain = run_engine(ds, kernel, p, 0, &stream);
                let cached = run_engine(ds, kernel, p, 8, &stream);
                assert_eq!(
                    plain, cached,
                    "{} {kernel:?} p={p}: cache must be bitwise-transparent",
                    ds.name
                );
                for (got, want) in plain.iter().zip(&reference) {
                    if p == 1 {
                        assert_eq!(got, want, "{} {kernel:?} p=1 bitwise", ds.name);
                    } else {
                        assert!(
                            (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                            "{} {kernel:?} p={p}: {got} vs {want}",
                            ds.name
                        );
                    }
                }
            }
        }
    }
}

/// Solver-level determinism: `dcd`/`dcd_sstep` and `bdcd`/`bdcd_sstep`
/// return identical α with the cache on vs off — for every kernel, both
/// SVM variants, and cache sizes that do and don't fit the working set.
#[test]
fn prop_solvers_identical_with_cache_on_and_off() {
    let svm_ds = gen_dense_classification(30, 8, 0.1, 505);
    let krr_ds = {
        let mut ds = gen_uniform_sparse(
            SynthParams {
                m: 28,
                n: 90,
                density: 0.08,
                seed: 13,
            },
            Task::Regression,
        );
        // Regression labels from the generator are already real-valued.
        ds.name = "sparse-krr".into();
        ds
    };
    for kernel in kernels() {
        for cache_rows in [4usize, 64] {
            // --- DCD / s-step DCD ---------------------------------------
            for variant in [SvmVariant::L1, SvmVariant::L2] {
                let p = SvmParams {
                    c: 1.0,
                    variant,
                    h: 150,
                    seed: 3,
                };
                let mut plain = LocalGram::new(svm_ds.a.clone(), kernel);
                let mut cached = LocalGram::with_cache(svm_ds.a.clone(), kernel, cache_rows);
                let a1 = dcd(&mut plain, &svm_ds.y, &p, &mut Ledger::new(), None);
                let a2 = dcd(&mut cached, &svm_ds.y, &p, &mut Ledger::new(), None);
                assert_eq!(a1, a2, "dcd {kernel:?} {variant:?} cache={cache_rows}");

                let mut plain = LocalGram::new(svm_ds.a.clone(), kernel);
                let mut cached = LocalGram::with_cache(svm_ds.a.clone(), kernel, cache_rows);
                let s1 = dcd_sstep(&mut plain, &svm_ds.y, &p, 8, &mut Ledger::new(), None);
                let s2 = dcd_sstep(&mut cached, &svm_ds.y, &p, 8, &mut Ledger::new(), None);
                assert_eq!(s1, s2, "dcd_sstep {kernel:?} {variant:?}");
                // And the s-step ≡ classical equivalence survives caching.
                for (x, y) in s2.iter().zip(&a2) {
                    assert!((x - y).abs() < 1e-9, "sstep vs classical under cache");
                }
            }

            // --- BDCD / s-step BDCD -------------------------------------
            let p = KrrParams {
                lambda: 1.0,
                b: 4,
                h: 80,
                seed: 5,
            };
            let mut plain = LocalGram::new(krr_ds.a.clone(), kernel);
            let mut cached = LocalGram::with_cache(krr_ds.a.clone(), kernel, cache_rows);
            let a1 = bdcd(&mut plain, &krr_ds.y, &p, &mut Ledger::new(), None);
            let a2 = bdcd(&mut cached, &krr_ds.y, &p, &mut Ledger::new(), None);
            assert_eq!(a1, a2, "bdcd {kernel:?} cache={cache_rows}");

            let mut plain = LocalGram::new(krr_ds.a.clone(), kernel);
            let mut cached = LocalGram::with_cache(krr_ds.a.clone(), kernel, cache_rows);
            let s1 = bdcd_sstep(&mut plain, &krr_ds.y, &p, 6, &mut Ledger::new(), None);
            let s2 = bdcd_sstep(&mut cached, &krr_ds.y, &p, 6, &mut Ledger::new(), None);
            assert_eq!(s1, s2, "bdcd_sstep {kernel:?} cache={cache_rows}");
        }
    }
}

/// Cache hits must actually occur under a DCD-like access stream (the
/// saving is real, not vacuous) and hit counts must be deterministic
/// across reruns.
#[test]
fn prop_cache_hits_are_real_and_deterministic() {
    let ds = gen_dense_classification(20, 6, 0.0, 99);
    let stream = sample_stream(20, 30, 0xBEEF);
    let run = || {
        let mut oracle = LocalGram::with_cache(ds.a.clone(), Kernel::paper_rbf(), 10);
        let mut ledger = Ledger::new();
        for sample in &stream {
            let mut q = Mat::zeros(sample.len(), 20);
            oracle.gram(sample, &mut q, &mut ledger);
        }
        (ledger.cache.hits, ledger.cache.misses)
    };
    let (h1, m1) = run();
    let (h2, m2) = run();
    assert_eq!((h1, m1), (h2, m2));
    assert!(h1 > 0, "expected hits under with-replacement sampling");
    assert!(m1 > 0);
}
