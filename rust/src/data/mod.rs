//! Datasets: LIBSVM-format I/O, synthetic generators, and the registry of
//! paper benchmark datasets.
//!
//! The paper evaluates on LIBSVM-repository datasets (Tables 2–3). This
//! offline image has none of them, so the registry generates synthetic
//! stand-ins matched to each dataset's published shape (`m`, `n`), density
//! and nonzero distribution (see DESIGN.md §substitutions). Real LIBSVM
//! files are fully supported: `Dataset::read_libsvm` parses the standard
//! `label idx:val ...` format and any registry entry can be overridden
//! with a file on disk.

#![forbid(unsafe_code)]

mod libsvm;
mod registry;
mod synth;

pub use libsvm::{read_libsvm, read_libsvm_str, write_libsvm};
pub use registry::{paper_dataset, paper_datasets, DatasetSpec};
pub use synth::{
    gen_dense_classification, gen_dense_regression, gen_powerlaw_sparse, gen_uniform_sparse,
    SynthParams,
};

use crate::sparse::Csr;

/// Learning task the labels encode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Binary classification, labels in `{-1, +1}`.
    Classification,
    /// Regression, real labels.
    Regression,
}

/// A dataset: sparse feature matrix (samples × features) plus labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name (registry key or file stem).
    pub name: String,
    /// `m × n` feature matrix in CSR.
    pub a: Csr,
    /// Length-`m` labels.
    pub y: Vec<f64>,
    /// Whether the labels encode classification or regression.
    pub task: Task,
}

impl Dataset {
    /// Number of samples.
    pub fn m(&self) -> usize {
        self.a.nrows()
    }

    /// Number of features.
    pub fn n(&self) -> usize {
        self.a.ncols()
    }

    /// Validate the invariants tests rely on.
    pub fn validate(&self) -> Result<(), String> {
        if self.y.len() != self.a.nrows() {
            return Err(format!(
                "labels ({}) != rows ({})",
                self.y.len(),
                self.a.nrows()
            ));
        }
        if self.task == Task::Classification
            && !self.y.iter().all(|&v| v == 1.0 || v == -1.0)
        {
            return Err("classification labels must be ±1".into());
        }
        if !self.y.iter().all(|v| v.is_finite()) {
            return Err("non-finite label".into());
        }
        Ok(())
    }

    /// Per-rank column shards in 1D-column layout (the paper's data
    /// partitioning: each MPI process stores ≈ `n/P` features).
    pub fn shard_cols(&self, p: usize) -> Vec<Csr> {
        self.a.partition_cols(p)
    }

    /// Load-imbalance factor across `p` column shards: max over ranks of
    /// `nnz_p / (nnz/P)`. 1.0 = perfectly balanced; news20-like datasets
    /// are far above 1 (Section 5.2.3).
    pub fn imbalance(&self, p: usize) -> f64 {
        let shards = self.shard_cols(p);
        let total: usize = shards.iter().map(|s| s.nnz()).sum();
        if total == 0 {
            return 1.0;
        }
        let avg = total as f64 / p as f64;
        shards
            .iter()
            .map(|s| s.nnz() as f64 / avg)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_labels() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        let ok = Dataset {
            name: "t".into(),
            a: a.clone(),
            y: vec![1.0, -1.0],
            task: Task::Classification,
        };
        assert!(ok.validate().is_ok());
        let bad = Dataset {
            name: "t".into(),
            a,
            y: vec![1.0, 2.0],
            task: Task::Classification,
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn imbalance_unit_for_uniform() {
        let trips: Vec<(usize, usize, f64)> = (0..8)
            .flat_map(|i| (0..8).map(move |j| (i, j, 1.0)))
            .collect();
        let d = Dataset {
            name: "dense".into(),
            a: Csr::from_triplets(8, 8, &trips),
            y: vec![1.0; 8],
            task: Task::Classification,
        };
        assert!((d.imbalance(4) - 1.0).abs() < 1e-12);
    }
}
