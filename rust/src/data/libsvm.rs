//! LIBSVM sparse text format: `label index:value index:value ...` with
//! 1-based, ascending feature indices. This is the format of every dataset
//! in the paper's Tables 2–3 (all from the LIBSVM repository).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use super::{Dataset, Task};
use crate::sparse::Csr;

/// Parse LIBSVM-format text. `n_features = Some(n)` forces the feature
/// dimension (indices beyond it are an error); `None` infers it from the
/// max index seen.
pub fn read_libsvm_str(
    text: &str,
    name: &str,
    task: Task,
    n_features: Option<usize>,
) -> Result<Dataset, String> {
    let mut y = Vec::new();
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    let mut max_col = 0usize;
    let mut row = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .ok_or_else(|| format!("line {}: empty", lineno + 1))?
            .parse()
            .map_err(|e| format!("line {}: bad label: {e}", lineno + 1))?;
        y.push(label);
        let mut prev_idx = 0usize;
        for tok in parts {
            let (idx_s, val_s) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: token '{tok}' missing ':'", lineno + 1))?;
            let idx: usize = idx_s
                .parse()
                .map_err(|e| format!("line {}: bad index '{idx_s}': {e}", lineno + 1))?;
            let val: f64 = val_s
                .parse()
                .map_err(|e| format!("line {}: bad value '{val_s}': {e}", lineno + 1))?;
            if idx == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
            }
            if idx <= prev_idx {
                return Err(format!(
                    "line {}: indices must be strictly ascending ({idx} after {prev_idx})",
                    lineno + 1
                ));
            }
            prev_idx = idx;
            max_col = max_col.max(idx);
            if val != 0.0 {
                triplets.push((row, idx - 1, val));
            }
        }
        row += 1;
    }
    let n = match n_features {
        Some(n) => {
            if max_col > n {
                return Err(format!("feature index {max_col} exceeds declared n = {n}"));
            }
            n
        }
        None => max_col,
    };
    let a = Csr::from_triplets(row, n, &triplets);
    let ds = Dataset {
        name: name.to_string(),
        a,
        y,
        task,
    };
    // Classification files use arbitrary label pairs (e.g. 0/1, 1/2);
    // normalize the two most common encodings to ±1.
    let ds = if task == Task::Classification {
        normalize_binary_labels(ds)?
    } else {
        ds
    };
    ds.validate()?;
    Ok(ds)
}

fn normalize_binary_labels(mut ds: Dataset) -> Result<Dataset, String> {
    let mut classes: Vec<f64> = Vec::new();
    for &v in &ds.y {
        if !classes.iter().any(|&c| c == v) {
            classes.push(v);
        }
    }
    match classes.len() {
        1 | 2 => {
            classes.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // Map smaller class to -1, larger to +1 (no-op for ±1 input).
            let lo = classes[0];
            for v in &mut ds.y {
                *v = if *v == lo { -1.0 } else { 1.0 };
            }
            Ok(ds)
        }
        k => Err(format!("expected binary labels, found {k} classes")),
    }
}

/// Read a LIBSVM file from disk.
pub fn read_libsvm(
    path: &Path,
    task: Task,
    n_features: Option<usize>,
) -> Result<Dataset, String> {
    let file = std::fs::File::open(path).map_err(|e| format!("open {path:?}: {e}"))?;
    let mut text = String::new();
    let mut reader = std::io::BufReader::new(file);
    // Read fully; datasets of interest fit in memory by construction.
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => text.push_str(&line),
            Err(e) => return Err(format!("read {path:?}: {e}")),
        }
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    read_libsvm_str(&text, &name, task, n_features)
}

/// Write a dataset in LIBSVM format (1-based indices, `%.17g`-style
/// round-trippable floats).
pub fn write_libsvm(ds: &Dataset, path: &Path) -> Result<(), String> {
    let file = std::fs::File::create(path).map_err(|e| format!("create {path:?}: {e}"))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.m() {
        write!(w, "{}", ds.y[i]).map_err(|e| e.to_string())?;
        for (j, v) in ds.a.row_iter(i) {
            write!(w, " {}:{}", j + 1, v).map_err(|e| e.to_string())?;
        }
        writeln!(w).map_err(|e| e.to_string())?;
    }
    w.flush().map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:2.0\n-1 2:1.0\n";
        let ds = read_libsvm_str(text, "t", Task::Classification, None).unwrap();
        assert_eq!(ds.m(), 2);
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        let d = ds.a.to_dense();
        assert_eq!(d[(0, 0)], 0.5);
        assert_eq!(d[(0, 2)], 2.0);
        assert_eq!(d[(1, 1)], 1.0);
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        let text = "# header\n\n1 1:1.0  # trailing\n-1 1:2.0\n";
        let ds = read_libsvm_str(text, "t", Task::Classification, None).unwrap();
        assert_eq!(ds.m(), 2);
    }

    #[test]
    fn parse_normalizes_01_labels() {
        let text = "0 1:1\n1 1:2\n";
        let ds = read_libsvm_str(text, "t", Task::Classification, None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(read_libsvm_str("1 0:1.0\n", "t", Task::Classification, None).is_err());
    }

    #[test]
    fn parse_rejects_descending_indices() {
        assert!(read_libsvm_str("1 3:1.0 2:1.0\n", "t", Task::Classification, None).is_err());
    }

    #[test]
    fn parse_rejects_multiclass() {
        let text = "1 1:1\n2 1:1\n3 1:1\n";
        assert!(read_libsvm_str(text, "t", Task::Classification, None).is_err());
    }

    #[test]
    fn parse_respects_declared_n() {
        let ds = read_libsvm_str("1 2:1.0\n-1 1:1.0\n", "t", Task::Classification, Some(10))
            .unwrap();
        assert_eq!(ds.n(), 10);
        assert!(
            read_libsvm_str("1 11:1.0\n", "t", Task::Classification, Some(10)).is_err()
        );
    }

    #[test]
    fn regression_labels_pass_through() {
        let ds = read_libsvm_str("3.25 1:1\n-0.5 2:1\n", "t", Task::Regression, None).unwrap();
        assert_eq!(ds.y, vec![3.25, -0.5]);
    }

    #[test]
    fn write_read_roundtrip() {
        let dir = std::env::temp_dir().join("kcd_test_libsvm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.libsvm");
        let text = "1 1:0.5 3:-2.25\n-1 2:1e-3\n";
        let ds = read_libsvm_str(text, "rt", Task::Classification, None).unwrap();
        write_libsvm(&ds, &path).unwrap();
        let back = read_libsvm(&path, Task::Classification, Some(3)).unwrap();
        assert_eq!(back.y, ds.y);
        assert_eq!(back.a.to_dense().data(), ds.a.to_dense().data());
        std::fs::remove_file(&path).ok();
    }
}
