//! Registry of the paper's benchmark datasets (Tables 2 and 3), each
//! backed by a synthetic stand-in with the published shape and sparsity.
//!
//! Scale notes: the registry generates at the *published* sizes by
//! default, which for news20.binary means ~9.1M nonzeros — generation
//! takes a couple of seconds. Benches that only need the communication/
//! computation *shape* may use `DatasetSpec::scaled(f)` to shrink `m`
//! and `n` proportionally (density preserved), and report the scaling
//! factor alongside results.

use super::synth::{
    gen_dense_classification, gen_dense_regression, gen_powerlaw_sparse, gen_uniform_sparse,
    SynthParams,
};
use super::{Dataset, Task};

/// How the synthetic stand-in is generated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GenKind {
    /// Dense Gaussian features.
    Dense,
    /// Uniformly sparse features at the given density.
    UniformSparse {
        /// Fraction of stored entries.
        density: f64,
    },
    /// Power-law column occupancy (news20-like load imbalance).
    PowerlawSparse {
        /// Fraction of stored entries.
        density: f64,
        /// Power-law exponent of the column-popularity distribution.
        alpha: f64,
    },
}

/// A named dataset specification from the paper.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Registry key (the paper's dataset name).
    pub name: &'static str,
    /// Published sample count.
    pub m: usize,
    /// Published feature count.
    pub n: usize,
    /// Classification or regression.
    pub task: Task,
    /// Which synthetic generator mimics the dataset.
    pub kind: GenKind,
    /// Which paper table the dataset appears in (2 = convergence,
    /// 3 = performance).
    pub table: u8,
}

impl DatasetSpec {
    /// Materialize the dataset (deterministic per name).
    pub fn generate(&self) -> Dataset {
        self.generate_scaled(1.0)
    }

    /// Materialize at `scale ∈ (0, 1]` of the published size (density and
    /// distribution preserved; name suffixed so reports stay honest).
    pub fn generate_scaled(&self, scale: f64) -> Dataset {
        assert!(scale > 0.0 && scale <= 1.0);
        let m = ((self.m as f64 * scale).round() as usize).max(4);
        let n = ((self.n as f64 * scale).round() as usize).max(4);
        let seed = fnv(self.name);
        let mut ds = match self.kind {
            GenKind::Dense => match self.task {
                Task::Classification => gen_dense_classification(m, n, 0.05, seed),
                Task::Regression => gen_dense_regression(m, n, 0.1, seed),
            },
            GenKind::UniformSparse { density } => gen_uniform_sparse(
                SynthParams {
                    m,
                    n,
                    density,
                    seed,
                },
                self.task,
            ),
            GenKind::PowerlawSparse { density, alpha } => gen_powerlaw_sparse(
                SynthParams {
                    m,
                    n,
                    density,
                    seed,
                },
                alpha,
                self.task,
            ),
        };
        ds.name = if scale == 1.0 {
            self.name.to_string()
        } else {
            format!("{}@{scale}", self.name)
        };
        ds
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// All paper datasets (Tables 2 and 3).
///
/// | name        | m      | n         | role |
/// |-------------|--------|-----------|------|
/// | duke        | 44     | 7129      | convergence (K-SVM) + perf |
/// | diabetes    | 768    | 8         | convergence (K-SVM) |
/// | abalone     | 4177   | 8         | convergence (K-RR)  |
/// | bodyfat     | 252    | 14        | convergence (K-RR)  |
/// | colon-cancer| 62     | 2000      | perf (dense)        |
/// | synthetic   | 2000   | 800000    | perf (1% dense, balanced) |
/// | news20      | 19996  | 1355191   | perf (0.03% dense, imbalanced) |
pub fn paper_datasets() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "duke",
            m: 44,
            n: 7129,
            task: Task::Classification,
            kind: GenKind::Dense,
            table: 2,
        },
        DatasetSpec {
            name: "diabetes",
            m: 768,
            n: 8,
            task: Task::Classification,
            kind: GenKind::Dense,
            table: 2,
        },
        DatasetSpec {
            name: "abalone",
            m: 4177,
            n: 8,
            task: Task::Regression,
            kind: GenKind::Dense,
            table: 2,
        },
        DatasetSpec {
            name: "bodyfat",
            m: 252,
            n: 14,
            task: Task::Regression,
            kind: GenKind::Dense,
            table: 2,
        },
        DatasetSpec {
            name: "colon-cancer",
            m: 62,
            n: 2000,
            task: Task::Classification,
            kind: GenKind::Dense,
            table: 3,
        },
        DatasetSpec {
            name: "synthetic",
            m: 2000,
            n: 800_000,
            task: Task::Classification,
            kind: GenKind::UniformSparse { density: 0.01 },
            table: 3,
        },
        DatasetSpec {
            name: "news20",
            m: 19_996,
            n: 1_355_191,
            task: Task::Classification,
            kind: GenKind::PowerlawSparse {
                density: 0.000335, // 9.1M nnz / (19996 × 1355191)
                alpha: 1.05,
            },
            table: 3,
        },
    ]
}

/// Look up a paper dataset by name.
pub fn paper_dataset(name: &str) -> Option<DatasetSpec> {
    paper_datasets().into_iter().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_paper_datasets() {
        let names: Vec<_> = paper_datasets().iter().map(|d| d.name).collect();
        for want in [
            "duke",
            "diabetes",
            "abalone",
            "bodyfat",
            "colon-cancer",
            "synthetic",
            "news20",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn table2_shapes_match_paper() {
        let duke = paper_dataset("duke").unwrap();
        assert_eq!((duke.m, duke.n), (44, 7129));
        let diabetes = paper_dataset("diabetes").unwrap();
        assert_eq!((diabetes.m, diabetes.n), (768, 8));
        let abalone = paper_dataset("abalone").unwrap();
        assert_eq!((abalone.m, abalone.n), (4177, 8));
        assert_eq!(abalone.task, Task::Regression);
        let bodyfat = paper_dataset("bodyfat").unwrap();
        assert_eq!((bodyfat.m, bodyfat.n), (252, 14));
    }

    #[test]
    fn small_datasets_generate_at_full_size() {
        for name in ["duke", "diabetes", "bodyfat", "colon-cancer"] {
            let spec = paper_dataset(name).unwrap();
            let ds = spec.generate();
            ds.validate().unwrap();
            assert_eq!(ds.m(), spec.m, "{name}");
            assert_eq!(ds.n(), spec.n, "{name}");
        }
    }

    #[test]
    fn scaled_generation_shrinks_proportionally() {
        let spec = paper_dataset("synthetic").unwrap();
        let ds = spec.generate_scaled(0.01);
        ds.validate().unwrap();
        assert_eq!(ds.m(), 20);
        assert_eq!(ds.n(), 8000);
        // Density preserved within tolerance.
        assert!((ds.a.density() - 0.01).abs() < 0.005, "{}", ds.a.density());
        assert!(ds.name.contains('@'));
    }

    #[test]
    fn news20_standin_is_imbalanced_synthetic_is_not() {
        let news = paper_dataset("news20").unwrap().generate_scaled(0.02);
        let synth = paper_dataset("synthetic").unwrap().generate_scaled(0.02);
        assert!(
            news.imbalance(8) > synth.imbalance(8),
            "news20 {} vs synthetic {}",
            news.imbalance(8),
            synth.imbalance(8)
        );
    }
}
