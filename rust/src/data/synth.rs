//! Synthetic dataset generators.
//!
//! Three families, matching the three data regimes in the paper's
//! evaluation:
//!
//! * dense (duke/colon-like microarray data: tiny `m`, large `n`),
//! * uniformly sparse (the paper's own "synthetic" dataset: perfectly
//!   load-balanced nonzeros),
//! * power-law sparse (news20.binary-like: highly non-uniform column
//!   occupancy, which produces the load imbalance studied in §5.2.3).
//!
//! Classification sets are generated from a planted hyperplane (or a
//! planted nonlinear score for kernel cases) with controllable label
//! noise so accuracy is a meaningful end-to-end signal; regression sets
//! use a planted linear model plus Gaussian noise.

use super::{Dataset, Task};
use crate::rng::Pcg;
use crate::sparse::Csr;

/// Parameters shared by the sparse generators.
#[derive(Clone, Copy, Debug)]
pub struct SynthParams {
    /// Sample count.
    pub m: usize,
    /// Feature count.
    pub n: usize,
    /// Target fraction of nonzeros.
    pub density: f64,
    /// Generator seed.
    pub seed: u64,
}

/// Dense binary classification from a planted unit-normal hyperplane with
/// margin `label_noise` flip probability.
pub fn gen_dense_classification(m: usize, n: usize, label_noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed, 101);
    let w: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    let wn = crate::dense::nrm2(&w);
    let mut trips = Vec::with_capacity(m * n);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let mut score = 0.0;
        for j in 0..n {
            let v = rng.next_gaussian();
            score += v * w[j];
            trips.push((i, j, v));
        }
        let mut label = if score / wn >= 0.0 { 1.0 } else { -1.0 };
        if rng.next_f64() < label_noise {
            label = -label;
        }
        y.push(label);
    }
    Dataset {
        name: format!("dense-cls-{m}x{n}"),
        a: Csr::from_triplets(m, n, &trips),
        y,
        task: Task::Classification,
    }
}

/// Dense regression: `y = A x* + ε`, `ε ~ N(0, noise²)`.
pub fn gen_dense_regression(m: usize, n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed, 202);
    let xstar: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
    let mut trips = Vec::with_capacity(m * n);
    let mut y = Vec::with_capacity(m);
    for i in 0..m {
        let mut yi = 0.0;
        for (j, xs) in xstar.iter().enumerate() {
            let v = rng.next_gaussian();
            yi += v * xs;
            trips.push((i, j, v));
        }
        y.push(yi + noise * rng.next_gaussian());
    }
    Dataset {
        name: format!("dense-reg-{m}x{n}"),
        a: Csr::from_triplets(m, n, &trips),
        y,
        task: Task::Regression,
    }
}

/// Uniformly sparse dataset: every row gets exactly `round(density·n)`
/// nonzeros at uniform column positions — the perfectly load-balanced
/// regime of the paper's "synthetic" dataset (Table 3: 2000×800000, 99%
/// sparse ⇒ 8000 nnz/row).
pub fn gen_uniform_sparse(p: SynthParams, task: Task) -> Dataset {
    let mut rng = Pcg::new(p.seed, 303);
    let nnz_per_row = ((p.density * p.n as f64).round() as usize).clamp(1, p.n);
    let mut trips = Vec::with_capacity(p.m * nnz_per_row);
    for i in 0..p.m {
        let cols = rng.sample_without_replacement(p.n, nnz_per_row);
        for j in cols {
            trips.push((i, j, rng.next_gaussian()));
        }
    }
    let a = Csr::from_triplets(p.m, p.n, &trips);
    let y = plant_labels(&a, task, &mut rng);
    Dataset {
        name: format!("uniform-sparse-{}x{}", p.m, p.n),
        a,
        y,
        task,
    }
}

/// Power-law sparse dataset (news20-like): column popularity follows a
/// Zipf distribution, so a few "hot" feature columns hold most nonzeros
/// and 1D-column shards are badly imbalanced — reproducing the §5.2.3
/// load-imbalance regime. Row occupancy also varies (documents differ in
/// length).
pub fn gen_powerlaw_sparse(p: SynthParams, zipf_alpha: f64, task: Task) -> Dataset {
    let mut rng = Pcg::new(p.seed, 404);
    let target_nnz = (p.density * p.m as f64 * p.n as f64).round() as usize;
    // Zipf column weights; cumulative table for sampling.
    let mut cum = Vec::with_capacity(p.n);
    let mut acc = 0.0;
    for j in 0..p.n {
        acc += 1.0 / ((j + 1) as f64).powf(zipf_alpha);
        cum.push(acc);
    }
    let total = acc;
    // Row lengths ~ geometric-ish around the mean.
    let mean_row = (target_nnz as f64 / p.m as f64).max(1.0);
    let mut trips = Vec::with_capacity(target_nnz + p.m);
    for i in 0..p.m {
        let len = ((mean_row * (0.25 + 1.5 * rng.next_f64())).round() as usize).max(1);
        let mut seen = std::collections::HashSet::with_capacity(len * 2);
        for _ in 0..len {
            let u = rng.next_f64() * total;
            let j = cum.partition_point(|&c| c < u).min(p.n - 1);
            if seen.insert(j) {
                // tf-idf-ish positive weights.
                trips.push((i, j, 0.1 + rng.next_f64()));
            }
        }
    }
    let a = Csr::from_triplets(p.m, p.n, &trips);
    let y = plant_labels(&a, task, &mut rng);
    Dataset {
        name: format!("powerlaw-sparse-{}x{}", p.m, p.n),
        a,
        y,
        task,
    }
}

/// Plant labels from a sparse random hyperplane (classification) or a
/// sparse linear model + noise (regression).
fn plant_labels(a: &Csr, task: Task, rng: &mut Pcg) -> Vec<f64> {
    let n = a.ncols();
    // Sparse weight vector over the (hot) first columns to keep scores
    // non-degenerate for power-law data.
    let k = n.min(2048);
    let mut w = vec![0.0; n];
    for wj in w.iter_mut().take(k) {
        *wj = rng.next_gaussian();
    }
    let mut score = vec![0.0; a.nrows()];
    a.spmv(&w, &mut score);
    match task {
        Task::Classification => score
            .iter()
            .map(|&s| {
                let mut l = if s >= 0.0 { 1.0 } else { -1.0 };
                if rng.next_f64() < 0.05 {
                    l = -l;
                }
                l
            })
            .collect(),
        Task::Regression => {
            let scale = crate::util::stddev(&score).max(1e-12);
            score
                .iter()
                .map(|&s| s / scale + 0.1 * rng.next_gaussian())
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_classification_shapes_and_balance() {
        let ds = gen_dense_classification(200, 16, 0.05, 7);
        ds.validate().unwrap();
        assert_eq!(ds.m(), 200);
        assert_eq!(ds.n(), 16);
        assert!((ds.a.density() - 1.0).abs() < 1e-6);
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert!(pos > 40 && pos < 160, "classes should be roughly balanced");
    }

    #[test]
    fn dense_regression_snr() {
        let ds = gen_dense_regression(300, 10, 0.01, 11);
        ds.validate().unwrap();
        // Labels should correlate strongly with the planted model; a crude
        // proxy: label variance >> noise variance.
        let var = crate::util::stddev(&ds.y).powi(2);
        assert!(var > 1.0, "labels carry signal, var={var}");
    }

    #[test]
    fn uniform_sparse_density_and_balance() {
        let ds = gen_uniform_sparse(
            SynthParams {
                m: 100,
                n: 1000,
                density: 0.01,
                seed: 3,
            },
            Task::Classification,
        );
        ds.validate().unwrap();
        assert!((ds.a.density() - 0.01).abs() < 0.002);
        // Every row has the same nnz → near-perfect balance.
        assert!(ds.imbalance(4) < 1.15, "imbalance {}", ds.imbalance(4));
    }

    #[test]
    fn powerlaw_is_imbalanced() {
        let ds = gen_powerlaw_sparse(
            SynthParams {
                m: 500,
                n: 5000,
                density: 0.003,
                seed: 5,
            },
            1.1,
            Task::Classification,
        );
        ds.validate().unwrap();
        // The hot columns concentrate in the first shard — imbalance must
        // be well above the uniform case.
        assert!(
            ds.imbalance(8) > 1.5,
            "powerlaw imbalance should be significant, got {}",
            ds.imbalance(8)
        );
    }

    #[test]
    fn generators_are_deterministic() {
        let a = gen_dense_classification(20, 5, 0.0, 42);
        let b = gen_dense_classification(20, 5, 0.0, 42);
        assert_eq!(a.y, b.y);
        assert_eq!(a.a, b.a);
        let c = gen_dense_classification(20, 5, 0.0, 43);
        assert_ne!(a.a, c.a);
    }
}
