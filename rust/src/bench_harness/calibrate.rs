//! The measured half of `kcd tune --calibrate`: a deterministic
//! microbench suite that pairs wall-clock medians with the analytic
//! counts the cost model charges for the same operations.
//!
//! This module is the designated clock-toucher (`bench_harness` is on
//! detlint's timing allowlist); the least-squares fit that consumes the
//! [`Observation`]s lives in [`crate::tune::calibrate`] and is pure.
//!
//! What is sampled:
//!
//! * **γ (seconds/flop)** — [`CsrProduct`] sampled-gram kernels over
//!   synthetic sparse and dense matrices at several sizes, the exact
//!   stage the solve spends its compute phase in. Flop counts come from
//!   the stage's own [`ProductCost`] charge, so the fit regresses
//!   measured seconds against the very numbers the tuner will multiply
//!   by γ.
//! * **α, β (seconds/round, seconds/word)** — loopback
//!   [`allreduce_sum`] collectives over 4 thread ranks at several
//!   payloads, with words/rounds deltas read from the rank's own
//!   [`CommStats`] counters and the critical path taken as the max over
//!   ranks. On a single box this calibrates the shared-memory
//!   transport; on a cluster the same suite run under a real transport
//!   would calibrate the wire. Either way the *counts* are identical —
//!   that is the point of fitting against the model's own accounting.
//!
//! Determinism note: the datasets, samples and payloads are fixed
//! (seeded PCG, no ambient entropy); only the measured seconds vary run
//! to run, and the fit's hard errors catch a suite too noisy to use.

use std::time::Instant;

use crate::comm::{allreduce_sum, run_ranks, AllreduceAlgo, CommStats, Communicator};
use crate::data::{gen_dense_classification, gen_uniform_sparse, SynthParams, Task};
use crate::dense::Mat;
use crate::gram::{CsrProduct, ProductStage};
use crate::rng::Pcg;
use crate::tune::calibrate::Observation;

/// Ranks in the loopback collective suite (a fixed, side-effect-free
/// choice: the Hockney counts scale out of it, so the fit does not care).
const COMM_RANKS: usize = 4;

/// Run the full calibration suite. `quick` shrinks sizes and iteration
/// counts for the CI smoke lane (`kcd tune --calibrate --quick`) at the
/// price of a noisier fit.
pub fn run_suite(quick: bool) -> Vec<Observation> {
    let mut obs = gram_observations(quick);
    obs.extend(comm_observations(quick));
    obs
}

/// γ observations: time the CSR sampled-gram product on synthetic
/// matrices covering both compute paths (transpose walk for sparse,
/// blocked scatter-dot for dense).
fn gram_observations(quick: bool) -> Vec<Observation> {
    struct Case {
        name: &'static str,
        m: usize,
        n: usize,
        /// `None` → dense data (blocked path), `Some(d)` → uniform
        /// sparse at density `d` (transpose path).
        density: Option<f64>,
        k: usize,
        iters: usize,
    }
    let cases = if quick {
        vec![
            Case { name: "gram/sparse-wide", m: 400, n: 1600, density: Some(0.01), k: 16, iters: 8 },
            Case { name: "gram/sparse-mid", m: 300, n: 800, density: Some(0.05), k: 16, iters: 8 },
            Case { name: "gram/dense", m: 200, n: 64, density: None, k: 16, iters: 8 },
        ]
    } else {
        vec![
            Case { name: "gram/sparse-wide", m: 1500, n: 6000, density: Some(0.01), k: 32, iters: 40 },
            Case { name: "gram/sparse-mid", m: 800, n: 2000, density: Some(0.05), k: 32, iters: 40 },
            Case { name: "gram/sparse-small", m: 400, n: 1000, density: Some(0.02), k: 8, iters: 80 },
            Case { name: "gram/dense", m: 600, n: 128, density: None, k: 32, iters: 40 },
            Case { name: "gram/dense-small", m: 200, n: 64, density: None, k: 8, iters: 160 },
        ]
    };
    let mut out = Vec::with_capacity(cases.len());
    for case in cases {
        let ds = match case.density {
            Some(d) => gen_uniform_sparse(
                SynthParams { m: case.m, n: case.n, density: d, seed: 42 },
                Task::Classification,
            ),
            None => gen_dense_classification(case.m, case.n, 0.0, 42),
        };
        let mut rng = Pcg::seeded(7);
        let sample = rng.sample_without_replacement(case.m, case.k);
        let mut product = CsrProduct::new(ds.a);
        let mut q = Mat::zeros(case.k, case.m);
        // Warmup builds the scratch and faults the pages in.
        let cost = product.compute(&sample, &mut q);
        let mut samples = Vec::with_capacity(case.iters);
        for _ in 0..case.iters {
            let t0 = Instant::now();
            super::black_box(product.compute(&sample, &mut q));
            samples.push(t0.elapsed().as_secs_f64());
        }
        out.push(Observation {
            name: case.name.to_string(),
            flops: cost.flops,
            words: 0.0,
            rounds: 0.0,
            secs: crate::util::median(&samples),
        });
    }
    out
}

/// α/β observations: barrier-fenced loopback allreduce loops at several
/// payloads; per-iteration counts and seconds are the max over ranks
/// (the critical path, which is what the Hockney terms price).
fn comm_observations(quick: bool) -> Vec<Observation> {
    let payloads: &[usize] = if quick {
        &[32, 1024, 16_384]
    } else {
        &[32, 512, 8_192, 131_072]
    };
    let mut out = Vec::with_capacity(payloads.len());
    for &payload in payloads {
        let iters = if quick { 20 } else { 200 };
        let per_rank = run_ranks(COMM_RANKS, |c| {
            let mut buf = vec![1.0f64; payload];
            // Warm the channels (first send allocates), then fence so
            // every rank starts its timed loop together.
            allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
            c.barrier();
            let before: CommStats = c.stats();
            let t0 = Instant::now();
            for _ in 0..iters {
                allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
            }
            let secs = t0.elapsed().as_secs_f64();
            let after: CommStats = c.stats();
            super::black_box(buf);
            (
                secs / iters as f64,
                (after.words - before.words) as f64 / iters as f64,
                (after.rounds - before.rounds) as f64 / iters as f64,
            )
        });
        let crit = |f: fn(&(f64, f64, f64)) -> f64| {
            per_rank.iter().map(f).fold(0.0f64, f64::max)
        };
        out.push(Observation {
            name: format!("comm/allreduce-{payload}w"),
            flops: 0.0,
            words: crit(|r| r.1),
            rounds: crit(|r| r.2),
            secs: crit(|r| r.0),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The quick suite exercises every coefficient's count column and
    /// produces finite positive timings — the shape the fit requires.
    /// (A full fit on loopback timings is exercised by the CI
    /// calibrate-smoke step, not a unit test: unit tests must not
    /// depend on machine speed.)
    #[test]
    fn quick_suite_exercises_every_term() {
        let obs = run_suite(true);
        assert!(obs.len() >= 3, "need at least 3 observations, got {}", obs.len());
        assert!(obs.iter().any(|o| o.flops > 0.0), "no flops column");
        assert!(obs.iter().any(|o| o.words > 0.0), "no words column");
        assert!(obs.iter().any(|o| o.rounds > 0.0), "no rounds column");
        for o in &obs {
            assert!(
                o.secs.is_finite() && o.secs > 0.0,
                "{}: bad seconds {}",
                o.name,
                o.secs
            );
            assert!(o.flops >= 0.0 && o.words >= 0.0 && o.rounds >= 0.0, "{}", o.name);
        }
    }

    /// The analytic counts attached to the observations are fixed by
    /// the suite's seeded datasets — two runs must report identical
    /// count columns (only the seconds may differ).
    #[test]
    fn suite_counts_are_deterministic() {
        let a = run_suite(true);
        let b = run_suite(true);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.flops.to_bits(), y.flops.to_bits(), "{}", x.name);
            assert_eq!(x.words.to_bits(), y.words.to_bits(), "{}", x.name);
            assert_eq!(x.rounds.to_bits(), y.rounds.to_bits(), "{}", x.name);
        }
    }
}
