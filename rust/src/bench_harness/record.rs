//! Machine-readable bench records for the CI perf-tracking lane.
//!
//! Each bench that opts in pushes one [`BenchRecord`] per measured
//! configuration into a [`BenchLog`]; at exit the log is written as a
//! `BENCH_<date>.json` artifact when the smoke lane asks for it
//! (`BENCH_SMOKE=1`, or an explicit `KCD_BENCH_JSON=<path>`). The
//! schema is deliberately flat — one array of
//! `{bench, config, wall_secs, flops, words}` objects — so a tracking
//! dashboard can diff artifacts across commits without a parser beyond
//! JSON itself.

use std::time::{SystemTime, UNIX_EPOCH};

/// One measured configuration of one bench.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Bench label, e.g. `"sampled_gram/sparse"`.
    pub bench: String,
    /// Free-form configuration tag, e.g. `"m=2000 n=8000 k=64"`.
    pub config: String,
    /// Median wall-clock seconds per iteration.
    pub wall_secs: f64,
    /// Analytic flop count per iteration (the cost model's count — the
    /// same number the calibration fit regresses against).
    pub flops: f64,
    /// Analytic communication words per iteration (zero for pure
    /// compute benches).
    pub words: f64,
}

/// An append-only collection of [`BenchRecord`]s with a JSON writer.
#[derive(Default)]
pub struct BenchLog {
    records: Vec<BenchRecord>,
}

impl BenchLog {
    /// Empty log.
    pub fn new() -> BenchLog {
        BenchLog::default()
    }

    /// Append one record.
    pub fn push(&mut self, record: BenchRecord) {
        self.records.push(record);
    }

    /// Number of records collected.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Serialize every record as a JSON array (stable field order,
    /// `{:e}` floats so values roundtrip bitwise through a reader).
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "  {{\"bench\": \"{}\", \"config\": \"{}\", \"wall_secs\": {:e}, \
                 \"flops\": {:e}, \"words\": {:e}}}{}\n",
                json_escape(&r.bench),
                json_escape(&r.config),
                r.wall_secs,
                r.flops,
                r.words,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push_str("]\n");
        out
    }

    /// The artifact path: `KCD_BENCH_JSON` verbatim when set, else
    /// `BENCH_<yyyy-mm-dd>.json` (UTC) in the working directory.
    pub fn default_path() -> std::path::PathBuf {
        match std::env::var_os("KCD_BENCH_JSON") {
            Some(p) => std::path::PathBuf::from(p),
            None => {
                let (y, m, d) = today_utc();
                std::path::PathBuf::from(format!("BENCH_{y:04}-{m:02}-{d:02}.json"))
            }
        }
    }

    /// Write the log to `path`.
    pub fn write(&self, path: &std::path::Path) -> Result<(), String> {
        std::fs::write(path, self.to_json())
            .map_err(|e| format!("cannot write bench log '{}': {e}", path.display()))
    }

    /// Write to [`Self::default_path`] when the smoke lane (or an
    /// explicit `KCD_BENCH_JSON`) asks for an artifact; otherwise a
    /// no-op, so plain `cargo bench` leaves no files behind. Prints the
    /// path on success, panics on an I/O failure — in CI a silently
    /// missing artifact would read as "bench lane passed".
    pub fn write_if_enabled(&self) {
        if !(super::smoke_mode() || std::env::var_os("KCD_BENCH_JSON").is_some()) {
            return;
        }
        let path = Self::default_path();
        if let Err(e) = self.write(&path) {
            panic!("{e}");
        }
        println!("wrote {} bench records to {}", self.len(), path.display());
    }
}

/// Escape a string for inclusion inside a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Today's UTC civil date (year, month, day) from the system clock.
fn today_utc() -> (i64, u32, u32) {
    let secs = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0);
    civil_from_days(secs.div_euclid(86_400))
}

/// Days-since-epoch → proleptic Gregorian civil date (Howard Hinnant's
/// `civil_from_days` algorithm, exact over the whole i64 day range we
/// can reach from a `SystemTime`).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097); // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn civil_date_known_values() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
        assert_eq!(civil_from_days(19_723 + 366), (2025, 1, 1)); // 2024 is a leap year
        assert_eq!(civil_from_days(-1), (1969, 12, 31));
    }

    #[test]
    fn json_has_schema_fields_and_escapes() {
        let mut log = BenchLog::new();
        log.push(BenchRecord {
            bench: "gram \"q\"".into(),
            config: "m=10\tn=20".into(),
            wall_secs: 0.5,
            flops: 1e9,
            words: 0.0,
        });
        log.push(BenchRecord {
            bench: "comm".into(),
            config: "p=4".into(),
            wall_secs: 1e-3,
            flops: 0.0,
            words: 4096.0,
        });
        let json = log.to_json();
        for field in ["\"bench\"", "\"config\"", "\"wall_secs\"", "\"flops\"", "\"words\""] {
            assert!(json.contains(field), "missing {field} in {json}");
        }
        assert!(json.contains("gram \\\"q\\\""));
        assert!(json.contains("m=10\\tn=20"));
        assert!(json.trim_start().starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        // One comma between the two records, none after the last.
        assert_eq!(json.matches("},").count(), 1);
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let mut log = BenchLog::new();
        log.push(BenchRecord {
            bench: "b".into(),
            config: "c".into(),
            wall_secs: 2.0,
            flops: 3.0,
            words: 4.0,
        });
        let path = std::env::temp_dir().join("kcd_bench_record_roundtrip.json");
        log.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, log.to_json());
        std::fs::remove_file(&path).ok();
    }
}
