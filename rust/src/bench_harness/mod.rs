//! A small criterion-like measurement harness (criterion is unavailable
//! in the offline build).
//!
//! Benches (`benches/*.rs`, `harness = false`) use [`bench`] for
//! wall-clock micro/meso benchmarks: warmup, then adaptive iteration
//! until a time budget is met, reporting median / mean ± stddev of
//! per-iteration times. A `--quick` CLI flag (or `KCD_BENCH_QUICK=1`)
//! shrinks budgets so `cargo bench` stays fast in CI.

#![forbid(unsafe_code)]

use std::time::Instant;

use crate::util::{fmt_secs, mean, median, stddev};

pub mod calibrate;
mod record;

pub use record::{BenchLog, BenchRecord};

/// Measurement settings.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget per benchmark.
    pub budget_secs: f64,
    /// Minimum timed samples.
    pub min_samples: usize,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if quick_mode() {
            BenchConfig {
                budget_secs: 0.2,
                min_samples: 3,
                warmup: 1,
            }
        } else {
            BenchConfig {
                budget_secs: 2.0,
                min_samples: 10,
                warmup: 3,
            }
        }
    }
}

/// True when `KCD_BENCH_QUICK=1` or `--quick` is on the command line.
/// [`smoke_mode`] implies quick: the CI smoke lane wants small budgets
/// *and* the JSON artifact, without setting two variables.
pub fn quick_mode() -> bool {
    std::env::var_os("KCD_BENCH_QUICK").is_some_and(|v| v == "1")
        || std::env::args().any(|a| a == "--quick")
        || smoke_mode()
}

/// True when `BENCH_SMOKE=1`: the CI perf-tracking lane. Benches then
/// run a bounded subset at quick budgets and write their records to a
/// `BENCH_<date>.json` artifact ([`BenchLog::write_if_enabled`]).
pub fn smoke_mode() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some_and(|v| v == "1")
}

/// One benchmark's statistics (seconds per iteration).
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall-clock samples, in seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Median seconds per iteration.
    pub fn median(&self) -> f64 {
        median(&self.samples)
    }

    /// Mean seconds per iteration.
    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    /// Sample standard deviation of the per-iteration seconds.
    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }

    /// One-line report.
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12} ± {:>10}  (n={})",
            self.name,
            fmt_secs(self.median()),
            fmt_secs(self.mean()),
            fmt_secs(self.stddev()),
            self.samples.len()
        )
    }
}

/// Measure `f` (one logical iteration per call) under `cfg`, printing the
/// result line. The closure's return value is black-boxed to keep the
/// optimizer honest.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup {
        black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < cfg.min_samples
        || (start.elapsed().as_secs_f64() < cfg.budget_secs && samples.len() < 10_000)
    {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        samples,
    };
    println!("{}", result.line());
    result
}

/// Optimizer barrier (std::hint::black_box re-export point).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench-section heading.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_min_samples() {
        let cfg = BenchConfig {
            budget_secs: 0.0,
            min_samples: 5,
            warmup: 1,
        };
        let mut n = 0u64;
        let r = bench("noop", &cfg, || {
            n += 1;
            n
        });
        assert!(r.samples.len() >= 5);
        assert!(r.median() >= 0.0);
        assert!(n >= 6); // warmup + samples
    }

    #[test]
    fn result_line_contains_name() {
        let r = BenchResult {
            name: "abc".into(),
            samples: vec![1e-3, 2e-3, 3e-3],
        };
        assert!(r.line().contains("abc"));
        assert_eq!(r.median(), 2e-3);
    }
}
