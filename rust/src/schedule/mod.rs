//! Pluggable, seeded, bitwise-deterministic coordinate schedules.
//!
//! The paper samples coordinates uniformly at random, so every s-step
//! gram call touches an essentially fresh set of kernel rows — the
//! kernel-row LRU cache, the sharded grid's fragment exchange and the
//! overlap credit all leave traffic on the table that a smarter (still
//! fully deterministic) schedule can recover. This module owns that
//! policy: the solvers draw every coordinate through a [`Schedule`]
//! instead of calling `Pcg` directly.
//!
//! Three implementations:
//!
//! * [`Uniform`] — bitwise-identical replay of the pre-schedule
//!   `SVM_COORD_STREAM` / `KRR_COORD_STREAM` sampling (the default):
//!   `b = 1` blocks consume exactly one `gen_below(m)` draw each, and
//!   `b > 1` blocks are one `sample_without_replacement(m, b)` each —
//!   precisely what `dcd`/`dcd_sstep` and `bdcd`/`bdcd_sstep` drew
//!   before schedules existed, so every existing property suite and
//!   analytic replica passes unchanged.
//! * [`ShuffledEpochs`] — Fisher–Yates epoch permutations: each epoch
//!   visits every coordinate exactly once in a freshly shuffled order,
//!   blocks taking consecutive permutation entries (the large-scale
//!   block-coordinate-descent regime of arXiv:1602.05310).
//! * [`LocalityAware`] — the headline: every block is chosen greedily
//!   from a seeded candidate pool to (a) maximize overlap with the
//!   kernel-row LRU's contents via a deterministic *shadow* of the
//!   `RowCache` hit/miss/commit semantics, (b) minimize sharded
//!   fragment-exchange words, scoring rows with the same packed
//!   `2·Σnnz` counts the analytic exchange replica moves and balancing
//!   the per-row-group ring critical path, and (c) under overlapped
//!   communication, order the selected blocks so the largest posted
//!   transfers sit under the largest hidden-compute windows.
//!
//! ### Determinism contract
//!
//! A schedule's output stream is a pure function of its
//! [`ScheduleSpec`], `(seed, stream)`, `m`, its row-cost table and the
//! sequence of `next_call(count, b)` shapes — never of engine state.
//! In particular the [`LocalityAware`] shadow LRU has its *own*
//! capacity ([`ScheduleSpec::shadow_rows`]) rather than reading the
//! real cache, so for a fixed spec the solve stays bitwise-invariant
//! to threads, engine cache capacity, `row_block`, storage mode and
//! overlap mode — the same contract every other engine knob obeys.
//! [`ScheduleKind::Uniform`] is additionally bitwise-identical to
//! every pre-schedule solve. The analytic traffic replicas
//! ([`crate::coordinator::scaling::gram_call_samples`]) replay the
//! exact same streams via [`call_samples`], cross-validated against
//! measured `CommStats` rank by rank.

#![forbid(unsafe_code)]

use std::cmp::Reverse;

use crate::rng::Pcg;
use crate::sparse::Csr;

/// Which coordinate schedule a solver runs ([`ScheduleSpec::kind`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// The paper's uniform sampling, bitwise-identical to the
    /// pre-schedule coordinate streams (the default).
    Uniform,
    /// Fisher–Yates epoch permutations: every coordinate exactly once
    /// per epoch, in a freshly shuffled order.
    ShuffledEpochs,
    /// Greedy cache-affine, exchange-minimizing, overlap-ordering
    /// selection from a seeded candidate pool.
    LocalityAware,
}

impl ScheduleKind {
    /// All kinds, in ranking order (`Uniform` first — the tuner's
    /// tie-break prefers the paper's schedule on equal cost).
    pub const ALL: [ScheduleKind; 3] = [
        ScheduleKind::Uniform,
        ScheduleKind::ShuffledEpochs,
        ScheduleKind::LocalityAware,
    ];

    /// CLI / report name (`uniform` / `shuffle` / `locality`).
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Uniform => "uniform",
            ScheduleKind::ShuffledEpochs => "shuffle",
            ScheduleKind::LocalityAware => "locality",
        }
    }

    /// Parse a CLI name (inverse of [`Self::name`]).
    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "uniform" => Some(ScheduleKind::Uniform),
            "shuffle" => Some(ScheduleKind::ShuffledEpochs),
            "locality" => Some(ScheduleKind::LocalityAware),
            _ => None,
        }
    }
}

/// Full schedule configuration — the *fixed point* of the determinism
/// contract: two solves with equal specs (and equal seed/problem) are
/// bitwise identical regardless of every engine knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduleSpec {
    /// Which policy draws the coordinates.
    pub kind: ScheduleKind,
    /// Capacity of the [`LocalityAware`] shadow LRU (rows). Set it to
    /// the engine's `cache_rows` to track the real cache exactly; it is
    /// a spec field (not read from the engine) so the stream cannot
    /// depend on engine configuration.
    pub shadow_rows: usize,
    /// Candidate blocks drawn per selected block (`>= 1`); `1` makes
    /// [`LocalityAware`] selection-free (pure uniform draws).
    pub pool: usize,
    /// Row-group count of the exchange-balance score (`0` disables it).
    /// Mirrors the sharded grid's `pr`: rows are grouped block-cyclically
    /// and per-call exchange words are balanced across groups to
    /// minimize the fragment ring's critical path.
    pub groups: usize,
    /// Block-cyclic block size of the group map (the grid's `row_block`).
    pub group_block: usize,
    /// Emit each call's selected blocks largest-transfer-first, so under
    /// `OverlapMode::{Exchange, Pipeline}` every posted transfer fits
    /// under its predecessor block's (at least as large) compute window.
    pub overlap_order: bool,
}

impl Default for ScheduleSpec {
    fn default() -> Self {
        ScheduleSpec {
            kind: ScheduleKind::Uniform,
            shadow_rows: 64,
            pool: 4,
            groups: 0,
            group_block: crate::gram::DEFAULT_ROW_BLOCK,
            overlap_order: false,
        }
    }
}

impl ScheduleSpec {
    /// Spec of the given kind with default locality parameters.
    pub fn of(kind: ScheduleKind) -> Self {
        ScheduleSpec {
            kind,
            ..ScheduleSpec::default()
        }
    }

    /// Compact report tag: the kind name, plus the locality parameters
    /// when they matter (`locality[shadow=64,pool=4,groups=2]`).
    pub fn label(&self) -> String {
        match self.kind {
            ScheduleKind::LocalityAware => format!(
                "locality[shadow={},pool={},groups={}]",
                self.shadow_rows, self.pool, self.groups
            ),
            kind => kind.name().to_string(),
        }
    }
}

/// A deterministic coordinate source for the (s-step) solvers.
///
/// One *gram call* is `count` blocks of `b` coordinates each
/// (`count = s_now` outer-block steps, `b = 1` for DCD / the K-RR block
/// size for BDCD), emitted flat — `count·b` indices appended to `out`
/// in block order. Coordinates within one block are distinct;
/// duplicates across blocks of a call are allowed (the solvers'
/// gradient-correction terms and the engine's in-call dedup both handle
/// them, exactly as under uniform sampling).
pub trait Schedule {
    /// Number of coordinates (kernel matrix rows) being scheduled.
    fn m(&self) -> usize;

    /// Clear `out` and fill it with the next gram call's `count·b`
    /// coordinates.
    fn next_call(&mut self, count: usize, b: usize, out: &mut Vec<usize>);
}

/// The paper's uniform sampling — bitwise replay of the pre-schedule
/// coordinate streams (see the module docs).
pub struct Uniform {
    m: usize,
    rng: Pcg,
}

impl Uniform {
    /// Seeded on the same `(seed, stream)` pair the solvers used before
    /// schedules existed, so the draw sequence is bit-for-bit identical.
    pub fn new(m: usize, seed: u64, stream: u64) -> Self {
        Uniform {
            m,
            rng: Pcg::new(seed, stream),
        }
    }
}

impl Schedule for Uniform {
    fn m(&self) -> usize {
        self.m
    }

    fn next_call(&mut self, count: usize, b: usize, out: &mut Vec<usize>) {
        out.clear();
        for _ in 0..count {
            if b == 1 {
                // Exactly the one `gen_below(m)` draw `dcd` made per
                // iteration (no allocation, same bits).
                out.push(self.rng.gen_below(self.m));
            } else {
                out.extend(self.rng.sample_without_replacement(self.m, b));
            }
        }
    }
}

/// Fisher–Yates epoch permutations (arXiv:1602.05310's regime): each
/// epoch is one shuffled pass over all `m` coordinates; blocks take
/// `b` consecutive permutation entries. A partial tail (fewer than `b`
/// entries left) is discarded and a fresh epoch shuffled, so every
/// block stays distinct-within-block.
pub struct ShuffledEpochs {
    m: usize,
    rng: Pcg,
    perm: Vec<usize>,
    cursor: usize,
}

impl ShuffledEpochs {
    /// Seeded like [`Uniform::new`]; the first epoch is shuffled lazily
    /// on the first draw.
    pub fn new(m: usize, seed: u64, stream: u64) -> Self {
        ShuffledEpochs {
            m,
            rng: Pcg::new(seed, stream),
            perm: Vec::new(),
            cursor: 0,
        }
    }

    fn refill(&mut self) {
        if self.perm.is_empty() {
            self.perm = (0..self.m).collect();
        }
        self.rng.shuffle(&mut self.perm);
        self.cursor = 0;
    }
}

impl Schedule for ShuffledEpochs {
    fn m(&self) -> usize {
        self.m
    }

    fn next_call(&mut self, count: usize, b: usize, out: &mut Vec<usize>) {
        assert!(
            b <= self.m,
            "shuffled-epoch blocks of {b} need at least {b} coordinates, have {}",
            self.m
        );
        out.clear();
        for _ in 0..count {
            if self.cursor + b > self.perm.len() {
                self.refill();
            }
            out.extend_from_slice(&self.perm[self.cursor..self.cursor + b]);
            self.cursor += b;
        }
    }
}

/// Greedy locality-aware selection (see the module docs for the three
/// objectives). Every `next_call` draws `count·pool` candidate blocks
/// uniformly (so the RNG consumption is shape-determined, never
/// state-dependent), then greedily keeps the `count` best.
pub struct LocalityAware {
    m: usize,
    rng: Pcg,
    spec: ScheduleSpec,
    /// Shadow LRU of kernel-row residency, front = least recent — a
    /// deterministic replay of `RowCache`'s classify/commit semantics
    /// with its own capacity (`spec.shadow_rows`).
    shadow: Vec<usize>,
    /// Per-row exchange cost (packed-fragment words, `2·nnz`); empty ⇒
    /// unit cost per row.
    row_cost: Vec<u64>,
}

impl LocalityAware {
    /// Seeded like [`Uniform::new`]. `row_cost` is the per-row
    /// fragment-exchange word count ([`packed_row_costs`]; pass `&[]`
    /// for unit costs).
    pub fn new(m: usize, seed: u64, stream: u64, spec: &ScheduleSpec, row_cost: &[u64]) -> Self {
        assert!(
            row_cost.is_empty() || row_cost.len() == m,
            "row-cost table length {} must match m = {m}",
            row_cost.len()
        );
        LocalityAware {
            m,
            rng: Pcg::new(seed, stream),
            spec: *spec,
            shadow: Vec::new(),
            row_cost: row_cost.to_vec(),
        }
    }

    /// Whether `row` is currently resident in the shadow LRU (read-only;
    /// used by the property suites to pin shadow ≡ real cache).
    pub fn shadow_resident(&self, row: usize) -> bool {
        self.shadow.contains(&row)
    }

    fn cost_of(&self, row: usize) -> u64 {
        if self.row_cost.is_empty() {
            1
        } else {
            self.row_cost[row]
        }
    }

    fn owner_of(&self, row: usize) -> usize {
        (row / self.spec.group_block.max(1)) % self.spec.groups.max(1)
    }

    /// Score one candidate block against the shadow and the coordinates
    /// already selected this call: `(warm, miss_cost, per-group added
    /// exchange words)`. Warm coordinates (shadow-resident, already
    /// selected this call, or repeated earlier in this block) are served
    /// from cache and exchange nothing — the same in-call dedup the
    /// engine's classify stage performs.
    fn score(&self, block: &[usize], selected: &[usize], group_add: &mut [u64]) -> (usize, u64) {
        for g in group_add.iter_mut() {
            *g = 0;
        }
        let mut warm = 0usize;
        let mut miss_cost = 0u64;
        for (i, &t) in block.iter().enumerate() {
            let dup_in_block = block[..i].contains(&t);
            if dup_in_block || self.shadow.contains(&t) || selected.contains(&t) {
                warm += 1;
            } else {
                let c = self.cost_of(t);
                miss_cost += c;
                if !group_add.is_empty() {
                    group_add[self.owner_of(t)] += c;
                }
            }
        }
        (warm, miss_cost)
    }

    /// Replay the engine's classify/commit semantics over one emitted
    /// call: hits touch to most-recent, first-occurrence misses are
    /// committed in order afterwards, each insert evicting the
    /// least-recent row at capacity.
    fn commit(&mut self, call: &[usize]) {
        if self.spec.shadow_rows == 0 {
            return;
        }
        let mut pending: Vec<usize> = Vec::new();
        for &t in call {
            if let Some(pos) = self.shadow.iter().position(|&r| r == t) {
                self.shadow.remove(pos);
                self.shadow.push(t);
            } else if !pending.contains(&t) {
                pending.push(t);
            }
        }
        for t in pending {
            if self.shadow.len() == self.spec.shadow_rows {
                self.shadow.remove(0);
            }
            self.shadow.push(t);
        }
    }
}

impl Schedule for LocalityAware {
    fn m(&self) -> usize {
        self.m
    }

    fn next_call(&mut self, count: usize, b: usize, out: &mut Vec<usize>) {
        out.clear();
        let pool = self.spec.pool.max(1);
        let npool = count * pool;
        let cands: Vec<Vec<usize>> = (0..npool)
            .map(|_| self.rng.sample_without_replacement(self.m, b))
            .collect();

        let groups = if self.spec.groups > 1 {
            self.spec.groups
        } else {
            0
        };
        let mut group_words = vec![0u64; groups];
        let mut group_add = vec![0u64; groups];
        let mut total_words = 0u64;
        let mut selected_coords: Vec<usize> = Vec::with_capacity(count * b);
        // (miss_cost, block) in selection order, re-ordered for overlap
        // below.
        let mut selected: Vec<(u64, Vec<usize>)> = Vec::with_capacity(count);
        let mut used = vec![false; npool];
        for _ in 0..count {
            let mut best: Option<(Reverse<usize>, u64, u64, usize)> = None;
            for (ci, cand) in cands.iter().enumerate() {
                if used[ci] {
                    continue;
                }
                let (warm, miss_cost) = self.score(cand, &selected_coords, &mut group_add);
                // Ring critical path after adding this block: rank `g`
                // forwards `total − counts[successor]` words, so the max
                // over ranks is `total − min_g counts[g]` — identical on
                // every rank, so the stream stays rank-invariant.
                let crit = if groups > 0 {
                    let blk: u64 = group_add.iter().sum();
                    let min_g = group_words
                        .iter()
                        .zip(&group_add)
                        .map(|(w, a)| w + a)
                        .min()
                        .unwrap_or(0);
                    (total_words + blk) - min_g
                } else {
                    0
                };
                // Maximize warm hits; tie-break by cheapest exchange,
                // then flattest ring, then candidate index (stable ⇒
                // deterministic).
                let key = (Reverse(warm), miss_cost, crit, ci);
                if best.map_or(true, |bk| key < bk) {
                    best = Some(key);
                }
            }
            let (_, miss_cost, _, ci) = best.expect("pool >= 1 candidate per slot");
            used[ci] = true;
            // Recompute the winner's per-group contribution (the scan
            // above reused the scratch buffer).
            let _ = self.score(&cands[ci], &selected_coords, &mut group_add);
            for (w, a) in group_words.iter_mut().zip(&group_add) {
                *w += a;
            }
            total_words += miss_cost;
            selected_coords.extend_from_slice(&cands[ci]);
            selected.push((miss_cost, cands[ci].clone()));
        }
        if self.spec.overlap_order {
            // Largest-transfer-first: block k+1's posted transfer then
            // never exceeds block k's compute window (stable sort keeps
            // equal-cost blocks in selection order — deterministic).
            selected.sort_by(|a, b| b.0.cmp(&a.0));
        }
        for (_, block) in &selected {
            out.extend_from_slice(block);
        }
        self.commit(out);
    }
}

/// Build the schedule a [`ScheduleSpec`] names, seeded on the solver's
/// `(seed, stream)` pair. `row_cost` feeds the [`LocalityAware`]
/// exchange score ([`packed_row_costs`]; pass `&[]` for unit costs —
/// the other kinds ignore it).
pub fn build_schedule(
    spec: &ScheduleSpec,
    m: usize,
    seed: u64,
    stream: u64,
    row_cost: &[u64],
) -> Box<dyn Schedule> {
    match spec.kind {
        ScheduleKind::Uniform => Box::new(Uniform::new(m, seed, stream)),
        ScheduleKind::ShuffledEpochs => Box::new(ShuffledEpochs::new(m, seed, stream)),
        ScheduleKind::LocalityAware => Box::new(LocalityAware::new(m, seed, stream, spec, row_cost)),
    }
}

/// Per-row packed-fragment exchange cost: `2·nnz(row)` words (column
/// index + value per stored entry) — exactly the per-row counts the
/// sharded grid's fragment ring moves and `grid_analytic_ledger`
/// replicates, so the [`LocalityAware`] score optimizes the same
/// quantity the measured `CommStats` records.
pub fn packed_row_costs(a: &Csr) -> Vec<u64> {
    (0..a.nrows())
        .map(|t| {
            let (cols, _) = a.row_parts(t);
            2 * cols.len() as u64
        })
        .collect()
}

/// Replay the per-gram-call coordinate stream of a schedule without
/// running a solver: one `Vec` per call, `s_now` blocks of `b` each —
/// exactly what the (s-step) solvers pass to the oracle. The analytic
/// exchange replica is built on this ([`crate::coordinator::scaling::gram_call_samples`]),
/// cross-validated bitwise against measured execution.
#[allow(clippy::too_many_arguments)]
pub fn call_samples(
    spec: &ScheduleSpec,
    m: usize,
    seed: u64,
    stream: u64,
    s: usize,
    h: usize,
    b: usize,
    row_cost: &[u64],
) -> Vec<Vec<usize>> {
    assert!(s >= 1, "need a positive block size");
    let mut sched = build_schedule(spec, m, seed, stream, row_cost);
    let mut out = Vec::with_capacity(h.div_ceil(s));
    let mut buf = Vec::with_capacity(s * b);
    let mut done = 0usize;
    while done < h {
        let s_now = s.min(h - done);
        sched.next_call(s_now, b, &mut buf);
        out.push(buf.clone());
        done += s_now;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for kind in ScheduleKind::ALL {
            assert_eq!(ScheduleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScheduleKind::parse("bogus"), None);
    }

    /// The Uniform schedule replays the raw PCG streams bit for bit:
    /// `b = 1` blocks are single `gen_below(m)` draws and `b > 1`
    /// blocks are `sample_without_replacement(m, b)` — the exact draws
    /// the solvers made before schedules existed.
    #[test]
    fn uniform_replays_raw_streams_bitwise() {
        let (m, seed) = (23usize, 0x5EEDu64);
        let mut sched = Uniform::new(m, seed, 0x5D);
        let mut rng = Pcg::new(seed, 0x5D);
        let mut buf = Vec::new();
        for count in [1usize, 3, 8, 1, 5] {
            sched.next_call(count, 1, &mut buf);
            let expect: Vec<usize> = (0..count).map(|_| rng.gen_below(m)).collect();
            assert_eq!(buf, expect);
        }
        let mut sched = Uniform::new(m, seed, 0xBD);
        let mut rng = Pcg::new(seed, 0xBD);
        for count in [1usize, 4, 2] {
            sched.next_call(count, 5, &mut buf);
            let expect: Vec<usize> = (0..count)
                .flat_map(|_| rng.sample_without_replacement(m, 5))
                .collect();
            assert_eq!(buf, expect);
        }
    }

    #[test]
    fn shuffled_epochs_visits_every_coordinate_once_per_epoch() {
        let m = 12usize;
        let mut sched = ShuffledEpochs::new(m, 7, 1);
        let mut buf = Vec::new();
        // b = 3 divides m: one epoch = 4 blocks, a permutation of 0..m.
        sched.next_call(4, 3, &mut buf);
        let mut seen = buf.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..m).collect::<Vec<_>>());
        // Next epoch is a different permutation (overwhelmingly likely).
        let first = buf.clone();
        sched.next_call(4, 3, &mut buf);
        let mut seen = buf.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..m).collect::<Vec<_>>());
        assert_ne!(buf, first, "epochs should reshuffle");
    }

    #[test]
    fn shuffled_epochs_discards_partial_tails() {
        let m = 10usize;
        let mut sched = ShuffledEpochs::new(m, 9, 1);
        let mut buf = Vec::new();
        // b = 4: each epoch yields 2 blocks, the 2-entry tail is dropped.
        for _ in 0..5 {
            sched.next_call(1, 4, &mut buf);
            assert_eq!(buf.len(), 4);
            let mut uniq = buf.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 4, "blocks must be distinct-within-block");
        }
    }

    #[test]
    fn schedules_are_deterministic_replicas() {
        let spec = ScheduleSpec {
            kind: ScheduleKind::LocalityAware,
            shadow_rows: 8,
            pool: 3,
            groups: 2,
            group_block: 4,
            overlap_order: true,
        };
        let costs: Vec<u64> = (0..20).map(|i| 2 * (i as u64 % 5 + 1)).collect();
        for kind in ScheduleKind::ALL {
            let spec = ScheduleSpec { kind, ..spec };
            let mut a = build_schedule(&spec, 20, 42, 7, &costs);
            let mut b = build_schedule(&spec, 20, 42, 7, &costs);
            let (mut ba, mut bb) = (Vec::new(), Vec::new());
            for (count, blk) in [(3usize, 2usize), (1, 4), (5, 1), (2, 2)] {
                a.next_call(count, blk, &mut ba);
                b.next_call(count, blk, &mut bb);
                assert_eq!(ba, bb, "{kind:?}");
                assert_eq!(ba.len(), count * blk, "{kind:?}");
                assert!(ba.iter().all(|&t| t < 20), "{kind:?}");
            }
        }
    }

    /// The locality schedule's coordinate stream is a function of the
    /// spec alone — two instances fed different call shapes diverge, but
    /// replaying the same shapes (as the analytic replica does via
    /// [`call_samples`]) reproduces the stream exactly.
    #[test]
    fn call_samples_replays_solver_shapes() {
        let spec = ScheduleSpec {
            kind: ScheduleKind::LocalityAware,
            shadow_rows: 6,
            pool: 4,
            groups: 2,
            group_block: 4,
            overlap_order: false,
        };
        let (m, seed, stream, s, h, b) = (16usize, 5u64, 0xBDu64, 4usize, 18usize, 2usize);
        let calls = call_samples(&spec, m, seed, stream, s, h, b, &[]);
        let mut sched = build_schedule(&spec, m, seed, stream, &[]);
        let mut buf = Vec::new();
        let mut done = 0usize;
        for call in &calls {
            let s_now = s.min(h - done);
            sched.next_call(s_now, b, &mut buf);
            assert_eq!(&buf, call);
            done += s_now;
        }
        assert_eq!(done, h);
    }

    /// On a shadow-sized working set the locality schedule re-draws
    /// cached rows far more often than uniform: strictly more warm
    /// coordinates over a repeat-heavy run (the schedule-level half of
    /// the acceptance benchmark; the measured-engine half lives in
    /// `rust/tests/schedule_props.rs`).
    #[test]
    fn locality_warms_more_coordinates_than_uniform() {
        let (m, seed, stream) = (64usize, 11u64, 0x5Du64);
        let count_warm = |spec: &ScheduleSpec| -> usize {
            let mut sched = build_schedule(spec, m, seed, stream, &[]);
            // An *independent* shadow replica tracks what an
            // equally-sized real cache would hold.
            let mut mirror = LocalityAware::new(m, 1, 1, spec, &[]);
            let mut warm = 0usize;
            let mut buf = Vec::new();
            for _ in 0..32 {
                sched.next_call(8, 1, &mut buf);
                for (i, &t) in buf.iter().enumerate() {
                    if mirror.shadow_resident(t) || buf[..i].contains(&t) {
                        warm += 1;
                    }
                }
                mirror.commit(&buf);
            }
            warm
        };
        let uniform = count_warm(&ScheduleSpec {
            shadow_rows: 16,
            ..ScheduleSpec::default()
        });
        let locality = count_warm(&ScheduleSpec {
            kind: ScheduleKind::LocalityAware,
            shadow_rows: 16,
            pool: 4,
            groups: 0,
            group_block: 4,
            overlap_order: false,
        });
        assert!(
            locality > uniform,
            "locality should rehit the cache more: {locality} vs {uniform}"
        );
    }

    #[test]
    fn overlap_order_emits_largest_transfers_first() {
        let spec = ScheduleSpec {
            kind: ScheduleKind::LocalityAware,
            shadow_rows: 0, // no warm hits: pure cost ordering
            pool: 1,        // selection-free: ordering is the only effect
            groups: 0,
            group_block: 4,
            overlap_order: true,
        };
        let costs: Vec<u64> = (0..32).map(|i| i as u64).collect();
        let mut sched = LocalityAware::new(32, 3, 9, &spec, &costs);
        let mut buf = Vec::new();
        sched.next_call(6, 1, &mut buf);
        let block_costs: Vec<u64> = buf.iter().map(|&t| costs[t]).collect();
        for w in block_costs.windows(2) {
            assert!(w[0] >= w[1], "descending transfer order: {block_costs:?}");
        }
    }

    #[test]
    fn packed_row_costs_are_twice_row_nnz() {
        let a = Csr::from_dense(&crate::dense::Mat::from_vec(
            3,
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 5.0],
        ));
        assert_eq!(packed_row_costs(&a), vec![4, 0, 6]);
    }
}
