//! Trained-model API: prediction on unseen data, persistence, and
//! evaluation — what a downstream user consumes after the solvers run.
//!
//! * [`SvmModel`] — kernel SVM classifier: keeps only the support vectors
//!   (`α_i > 0`), predicts via `sign(Σ α_i y_i K(a_i, x))`.
//! * [`KrrModel`] — kernel ridge regressor: predicts via
//!   `(1/λ) Σ α_i K(a_i, x)` (from the dual stationarity
//!   `x* = (1/λ)Aᵀα*` of the paper's K-RR formulation (2)).
//!
//! Both serialize two ways: a JSON document (via the in-crate
//! [`crate::util::json`] writer — human-inspectable, value-preserving to
//! shortest-roundtrip precision) and the versioned binary `.kcd` format
//! ([`crate::serve::format`] — *bitwise*-preserving, which is what the
//! serving determinism contract requires). K-SVM saves are
//! support-vector-compacted (α = 0 rows never reach the model); K-RR
//! models always retain every training row.
//!
//! Prediction comes in two equivalent flavors: the naive rowwise
//! reference ([`SvmModel::decision_function`] / [`KrrModel::predict`])
//! and the engine-routed [`SvmModel::predict_batch`] /
//! [`KrrModel::predict_batch`], which push query batches through
//! [`crate::serve::Predictor`] (threads + kernel-row cache) and are
//! bitwise identical to the reference for every options combination.

#![forbid(unsafe_code)]

use anyhow::{anyhow, Result};

use crate::costmodel::Ledger;
use crate::data::Dataset;
use crate::kernelfn::Kernel;
use crate::serve::format::{self, ModelKind, RawModel};
use crate::serve::{PredictOptions, Predictor};
use crate::sparse::Csr;
use crate::util::json::Json;

/// A trained kernel-SVM classifier.
#[derive(Clone, Debug)]
pub struct SvmModel {
    /// Support vectors (rows of the training matrix with `α_i > 0`).
    sv: Csr,
    /// `α_i · y_i` per support vector.
    coef: Vec<f64>,
    kernel: Kernel,
    sv_norms: Vec<f64>,
}

impl SvmModel {
    /// Assemble from a dual solution over a training set.
    pub fn from_dual(ds: &Dataset, alpha: &[f64], kernel: Kernel) -> SvmModel {
        assert_eq!(alpha.len(), ds.m());
        let idx: Vec<usize> = (0..ds.m()).filter(|&i| alpha[i] > 0.0).collect();
        let sv = ds.a.gather_rows(&idx);
        let coef: Vec<f64> = idx.iter().map(|&i| alpha[i] * ds.y[i]).collect();
        let sv_norms = sv.row_norms_sq();
        SvmModel {
            sv,
            coef,
            kernel,
            sv_norms,
        }
    }

    /// Number of support vectors kept.
    pub fn n_support(&self) -> usize {
        self.sv.nrows()
    }

    /// The kernel the model was trained with.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The retained support-vector rows.
    pub fn support_vectors(&self) -> &Csr {
        &self.sv
    }

    /// `α_i y_i` per support vector (ascending original-row order).
    pub fn coefficients(&self) -> &[f64] {
        &self.coef
    }

    /// Decision values `f(x_r)` for each row of `x`.
    pub fn decision_function(&self, x: &Csr) -> Vec<f64> {
        assert_eq!(
            x.ncols(),
            self.sv.ncols(),
            "feature dimension mismatch: {} vs {}",
            x.ncols(),
            self.sv.ncols()
        );
        let x_norms = x.row_norms_sq();
        (0..x.nrows())
            .map(|r| {
                let mut f = 0.0;
                for (j, &c) in self.coef.iter().enumerate() {
                    let dot = x.row_dot(r, &self.sv, j);
                    f += c * self.kernel.apply_scalar(dot, x_norms[r], self.sv_norms[j]);
                }
                f
            })
            .collect()
    }

    /// Predicted labels (±1).
    pub fn predict(&self, x: &Csr) -> Vec<f64> {
        self.decision_function(x)
            .into_iter()
            .map(|f| if f >= 0.0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fraction of correct predictions on a labeled set.
    pub fn accuracy(&self, x: &Csr, y: &[f64]) -> f64 {
        let pred = self.predict(x);
        let correct = pred.iter().zip(y).filter(|(p, y)| *p == *y).count();
        correct as f64 / y.len().max(1) as f64
    }

    /// Serialize to a JSON document.
    pub fn to_json(&self) -> Json {
        model_json("svm", &self.sv, &self.coef, self.kernel, None)
    }

    /// Deserialize.
    pub fn from_json(v: &Json) -> Result<SvmModel> {
        let (kind, sv, coef, kernel, _extra) = parse_model_json(v)?;
        anyhow::ensure!(kind == "svm", "not an svm model: {kind}");
        let sv_norms = sv.row_norms_sq();
        Ok(SvmModel {
            sv,
            coef,
            kernel,
            sv_norms,
        })
    }

    /// Save to a file (JSON).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().render()).map_err(|e| anyhow!("save: {e}"))
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<SvmModel> {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("load: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("parse: {e}"))?)
    }

    /// Save to the binary `.kcd` format (bitwise round trip; the rows
    /// are already support-vector-compacted by [`SvmModel::from_dual`]).
    pub fn save_kcd(&self, path: &std::path::Path) -> Result<()> {
        format::write_model(path, ModelKind::Svm, self.kernel, 0.0, &self.sv, &self.coef)
    }

    /// Load a `.kcd` model file, rejecting non-SVM kinds.
    pub fn load_kcd(path: &std::path::Path) -> Result<SvmModel> {
        let raw = format::read_model(path)?;
        anyhow::ensure!(
            raw.kind == ModelKind::Svm,
            "invalid value for 'model.kind': expected an svm model, got {}",
            raw.kind.name()
        );
        Ok(Self::from_kcd(raw))
    }

    /// Assemble from a validated `.kcd` payload.
    pub(crate) fn from_kcd(raw: RawModel) -> SvmModel {
        let sv_norms = raw.mat.row_norms_sq();
        SvmModel {
            sv: raw.mat,
            coef: raw.coef,
            kernel: raw.kernel,
            sv_norms,
        }
    }

    /// Engine-routed decision values: bitwise identical to
    /// [`SvmModel::decision_function`] for every [`PredictOptions`]
    /// combination, but computed through the gram engine — worker
    /// threads split the batch and repeated queries hit the kernel-row
    /// cache. Costs land in `ledger` under the training phases.
    pub fn predict_batch(&self, x: &Csr, opts: &PredictOptions, ledger: &mut Ledger) -> Vec<f64> {
        let mut p = Predictor::new(&self.sv, &self.coef, self.kernel, x, opts);
        let stream: Vec<usize> = (0..x.nrows()).collect();
        p.predict_stream(&stream, opts.batch, ledger)
    }
}

/// A trained kernel-ridge-regression model.
#[derive(Clone, Debug)]
pub struct KrrModel {
    train: Csr,
    /// `α_i / λ` per training row.
    coef: Vec<f64>,
    kernel: Kernel,
    train_norms: Vec<f64>,
    lambda: f64,
}

impl KrrModel {
    /// Assemble from a dual solution (keeps all training rows; K-RR duals
    /// are dense).
    pub fn from_dual(ds: &Dataset, alpha: &[f64], kernel: Kernel, lambda: f64) -> KrrModel {
        assert_eq!(alpha.len(), ds.m());
        let coef: Vec<f64> = alpha.iter().map(|&a| a / lambda).collect();
        let train_norms = ds.a.row_norms_sq();
        KrrModel {
            train: ds.a.clone(),
            coef,
            kernel,
            train_norms,
            lambda,
        }
    }

    /// The ridge penalty the model was trained with.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// The kernel the model was trained with.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The full retained training matrix (K-RR duals are dense — rows
    /// are **never** compacted, even when some `α_i` are zero).
    pub fn train_matrix(&self) -> &Csr {
        &self.train
    }

    /// `α_i / λ` per training row.
    pub fn coefficients(&self) -> &[f64] {
        &self.coef
    }

    /// Predicted targets for each row of `x`.
    pub fn predict(&self, x: &Csr) -> Vec<f64> {
        assert_eq!(x.ncols(), self.train.ncols(), "feature dimension mismatch");
        let x_norms = x.row_norms_sq();
        (0..x.nrows())
            .map(|r| {
                let mut f = 0.0;
                for (j, &c) in self.coef.iter().enumerate() {
                    let dot = x.row_dot(r, &self.train, j);
                    f += c * self.kernel.apply_scalar(dot, x_norms[r], self.train_norms[j]);
                }
                f
            })
            .collect()
    }

    /// Root-mean-square error on a labeled set.
    pub fn rmse(&self, x: &Csr, y: &[f64]) -> f64 {
        let pred = self.predict(x);
        let mse: f64 = pred
            .iter()
            .zip(y)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f64>()
            / y.len().max(1) as f64;
        mse.sqrt()
    }

    /// Serialize to a JSON document.
    pub fn to_json(&self) -> Json {
        model_json("krr", &self.train, &self.coef, self.kernel, Some(self.lambda))
    }

    /// Deserialize.
    pub fn from_json(v: &Json) -> Result<KrrModel> {
        let (kind, train, coef, kernel, extra) = parse_model_json(v)?;
        anyhow::ensure!(kind == "krr", "not a krr model: {kind}");
        let lambda = extra.ok_or_else(|| anyhow!("krr model missing lambda"))?;
        let train_norms = train.row_norms_sq();
        Ok(KrrModel {
            train,
            coef,
            kernel,
            train_norms,
            lambda,
        })
    }

    /// Save to a file (JSON).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().render()).map_err(|e| anyhow!("save: {e}"))
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<KrrModel> {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("load: {e}"))?;
        Self::from_json(&Json::parse(&text).map_err(|e| anyhow!("parse: {e}"))?)
    }

    /// Save to the binary `.kcd` format (bitwise round trip; all
    /// training rows retained).
    pub fn save_kcd(&self, path: &std::path::Path) -> Result<()> {
        format::write_model(
            path,
            ModelKind::Krr,
            self.kernel,
            self.lambda,
            &self.train,
            &self.coef,
        )
    }

    /// Load a `.kcd` model file, rejecting non-KRR kinds.
    pub fn load_kcd(path: &std::path::Path) -> Result<KrrModel> {
        let raw = format::read_model(path)?;
        anyhow::ensure!(
            raw.kind == ModelKind::Krr,
            "invalid value for 'model.kind': expected a krr model, got {}",
            raw.kind.name()
        );
        Ok(Self::from_kcd(raw))
    }

    /// Assemble from a validated `.kcd` payload.
    pub(crate) fn from_kcd(raw: RawModel) -> KrrModel {
        let train_norms = raw.mat.row_norms_sq();
        KrrModel {
            train: raw.mat,
            coef: raw.coef,
            kernel: raw.kernel,
            train_norms,
            lambda: raw.lambda,
        }
    }

    /// Engine-routed predictions: bitwise identical to
    /// [`KrrModel::predict`] for every [`PredictOptions`] combination
    /// (threads, cache, batch split) — see [`crate::serve`].
    pub fn predict_batch(&self, x: &Csr, opts: &PredictOptions, ledger: &mut Ledger) -> Vec<f64> {
        let mut p = Predictor::new(&self.train, &self.coef, self.kernel, x, opts);
        let stream: Vec<usize> = (0..x.nrows()).collect();
        p.predict_stream(&stream, opts.batch, ledger)
    }
}

fn kernel_json(k: Kernel) -> Json {
    match k {
        Kernel::Linear => Json::obj(vec![("kind", Json::Str("linear".into()))]),
        Kernel::Poly { c, d } => Json::obj(vec![
            ("kind", Json::Str("poly".into())),
            ("c", Json::Num(c)),
            ("d", Json::Num(d as f64)),
        ]),
        Kernel::Rbf { sigma } => Json::obj(vec![
            ("kind", Json::Str("rbf".into())),
            ("sigma", Json::Num(sigma)),
        ]),
    }
}

fn kernel_from_json(v: &Json) -> Result<Kernel> {
    let kind = v
        .get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("kernel missing kind"))?;
    match kind {
        "linear" => Ok(Kernel::Linear),
        "poly" => Ok(Kernel::Poly {
            c: v.get("c").and_then(Json::as_f64).unwrap_or(0.0),
            d: v.get("d").and_then(Json::as_f64).unwrap_or(3.0) as i32,
        }),
        "rbf" => Ok(Kernel::Rbf {
            sigma: v.get("sigma").and_then(Json::as_f64).unwrap_or(1.0),
        }),
        other => Err(anyhow!("unknown kernel kind {other}")),
    }
}

/// Shared model-document layout: CSR matrix as (rows, cols, triplet
/// arrays), coefficients, kernel, optional λ.
fn model_json(kind: &str, mat: &Csr, coef: &[f64], kernel: Kernel, lambda: Option<f64>) -> Json {
    let mut ri = Vec::with_capacity(mat.nnz());
    let mut ci = Vec::with_capacity(mat.nnz());
    let mut vs = Vec::with_capacity(mat.nnz());
    for i in 0..mat.nrows() {
        for (j, v) in mat.row_iter(i) {
            ri.push(i as f64);
            ci.push(j as f64);
            vs.push(v);
        }
    }
    let mut fields = vec![
        ("type", Json::Str(kind.into())),
        ("version", Json::Num(1.0)),
        ("rows", Json::Num(mat.nrows() as f64)),
        ("cols", Json::Num(mat.ncols() as f64)),
        ("tri_row", Json::nums(&ri)),
        ("tri_col", Json::nums(&ci)),
        ("tri_val", Json::nums(&vs)),
        ("coef", Json::nums(coef)),
        ("kernel", kernel_json(kernel)),
    ];
    if let Some(l) = lambda {
        fields.push(("lambda", Json::Num(l)));
    }
    Json::obj(fields)
}

fn parse_model_json(v: &Json) -> Result<(String, Csr, Vec<f64>, Kernel, Option<f64>)> {
    let kind = v
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("model missing type"))?
        .to_string();
    let rows = v
        .get("rows")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing rows"))?;
    let cols = v
        .get("cols")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing cols"))?;
    let arr = |key: &str| -> Result<Vec<f64>> {
        v.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing {key}"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad number in {key}")))
            .collect()
    };
    let ri = arr("tri_row")?;
    let ci = arr("tri_col")?;
    let vs = arr("tri_val")?;
    anyhow::ensure!(ri.len() == ci.len() && ci.len() == vs.len(), "triplet arity");
    let trips: Vec<(usize, usize, f64)> = ri
        .iter()
        .zip(&ci)
        .zip(&vs)
        .map(|((&r, &c), &v)| (r as usize, c as usize, v))
        .collect();
    let mat = Csr::from_triplets(rows, cols, &trips);
    let coef = arr("coef")?;
    anyhow::ensure!(coef.len() == rows, "coef length");
    let kernel = kernel_from_json(
        v.get("kernel").ok_or_else(|| anyhow!("missing kernel"))?,
    )?;
    let lambda = v.get("lambda").and_then(Json::as_f64);
    Ok((kind, mat, coef, kernel, lambda))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Ledger;
    use crate::data::{gen_dense_classification, gen_dense_regression};
    use crate::solvers::{bdcd, dcd, krr_exact, KrrParams, LocalGram, SvmParams, SvmVariant};

    fn train_svm(kernel: Kernel) -> (Dataset, Vec<f64>) {
        let ds = gen_dense_classification(80, 8, 0.02, 808);
        let mut oracle = LocalGram::new(ds.a.clone(), kernel);
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 2500,
            seed: 4,
        };
        let alpha = dcd(&mut oracle, &ds.y, &p, &mut Ledger::new(), None);
        (ds, alpha)
    }

    #[test]
    fn svm_model_fits_train_and_generalizes() {
        let (ds, alpha) = train_svm(Kernel::paper_rbf());
        let model = SvmModel::from_dual(&ds, &alpha, Kernel::paper_rbf());
        assert!(model.n_support() > 0 && model.n_support() <= 80);
        let train_acc = model.accuracy(&ds.a, &ds.y);
        assert!(train_acc > 0.9, "train acc {train_acc}");
        // Fresh data from the same generator family (same planted
        // hyperplane family — different seed means a different planted
        // model, so instead hold out by predicting on the train set with
        // the model's own decision values vs the objective's).
        let f = model.decision_function(&ds.a);
        assert!(f.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn svm_decision_matches_objective_formulation() {
        // f(x_i) computed by the model equals (Q̃α)_i / y_i from the
        // cached-kernel objective.
        use crate::solvers::objective::SvmObjective;
        let (ds, alpha) = train_svm(Kernel::paper_rbf());
        let model = SvmModel::from_dual(&ds, &alpha, Kernel::paper_rbf());
        let f = model.decision_function(&ds.a);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let obj = SvmObjective::new(&mut oracle, &ds.y, 1.0, SvmVariant::L1);
        let acc_model = model.accuracy(&ds.a, &ds.y);
        let acc_obj = obj.train_accuracy(&alpha);
        assert!(
            (acc_model - acc_obj).abs() < 1e-12,
            "{acc_model} vs {acc_obj}"
        );
        assert!(f.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn svm_model_save_load_roundtrip() {
        let (ds, alpha) = train_svm(Kernel::Poly { c: 1.0, d: 2 });
        let model = SvmModel::from_dual(&ds, &alpha, Kernel::Poly { c: 1.0, d: 2 });
        let dir = std::env::temp_dir().join("kcd_models");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("svm.json");
        model.save(&path).unwrap();
        let back = SvmModel::load(&path).unwrap();
        assert_eq!(back.n_support(), model.n_support());
        assert_eq!(back.kernel(), model.kernel());
        let f1 = model.decision_function(&ds.a);
        let f2 = back.decision_function(&ds.a);
        crate::testkit::assert_close(&f2, &f1, 1e-12, "reloaded decisions");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn krr_model_predicts_training_targets() {
        let mut ds = gen_dense_regression(60, 6, 0.05, 909);
        // Feature scaling keeps the RBF gram well-conditioned (otherwise
        // pairwise distances ≈ 2n drive K to the identity).
        {
            let mut a = ds.a.to_dense();
            for v in a.data_mut() {
                *v /= (6.0f64).sqrt();
            }
            ds.a = Csr::from_dense(&a);
        }
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        // The paper's dual carries an mI term, so the effective ridge is
        // m·λ — near-interpolation needs λ ≪ 1/m.
        let lambda = 1e-4;
        let alpha = krr_exact(&mut oracle, &ds.y, lambda);
        let model = KrrModel::from_dual(&ds, &alpha, Kernel::paper_rbf(), lambda);
        let rmse = model.rmse(&ds.a, &ds.y);
        let y_scale = crate::util::stddev(&ds.y);
        assert!(rmse < 0.2 * y_scale, "rmse {rmse} vs target scale {y_scale}");
    }

    #[test]
    fn krr_prediction_consistent_with_dual_identity() {
        // On training points: ŷ = (1/λ)Kα = y − mα (from the normal
        // equations ((1/λ)K + mI)α = y).
        let ds = gen_dense_regression(40, 5, 0.1, 1001);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let lambda = 1.0;
        let alpha = krr_exact(&mut oracle, &ds.y, lambda);
        let model = KrrModel::from_dual(&ds, &alpha, Kernel::paper_rbf(), lambda);
        let pred = model.predict(&ds.a);
        for i in 0..40 {
            let expect = ds.y[i] - 40.0 * alpha[i];
            assert!(
                (pred[i] - expect).abs() < 1e-8,
                "{}: {} vs {expect}",
                i,
                pred[i]
            );
        }
    }

    #[test]
    fn krr_model_save_load_roundtrip() {
        let ds = gen_dense_regression(25, 4, 0.1, 1102);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::Linear);
        let alpha = krr_exact(&mut oracle, &ds.y, 2.0);
        let model = KrrModel::from_dual(&ds, &alpha, Kernel::Linear, 2.0);
        let dir = std::env::temp_dir().join("kcd_models");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("krr.json");
        model.save(&path).unwrap();
        let back = KrrModel::load(&path).unwrap();
        assert_eq!(back.lambda(), 2.0);
        crate::testkit::assert_close(&back.predict(&ds.a), &model.predict(&ds.a), 1e-12, "krr");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cross_type_load_is_rejected() {
        let ds = gen_dense_regression(10, 3, 0.1, 1203);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::Linear);
        let alpha = krr_exact(&mut oracle, &ds.y, 1.0);
        let krr = KrrModel::from_dual(&ds, &alpha, Kernel::Linear, 1.0);
        assert!(SvmModel::from_json(&krr.to_json()).is_err());
    }

    #[test]
    fn trained_via_bdcd_equals_trained_via_exact() {
        let ds = gen_dense_regression(30, 5, 0.1, 1304);
        let lambda = 1.0;
        let mut o1 = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let mut o2 = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let p = KrrParams {
            lambda,
            b: 6,
            h: 1200,
            seed: 2,
        };
        let a_iter = bdcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
        let a_star = krr_exact(&mut o2, &ds.y, lambda);
        let m1 = KrrModel::from_dual(&ds, &a_iter, Kernel::paper_rbf(), lambda);
        let m2 = KrrModel::from_dual(&ds, &a_star, Kernel::paper_rbf(), lambda);
        crate::testkit::assert_close(&m1.predict(&ds.a), &m2.predict(&ds.a), 1e-5, "preds");
    }
}
