//! Simulated-MPI communication substrate.
//!
//! The paper's implementation is C + MPI on a Cray EX. This box has a
//! single physical core, so we reproduce the *communication structure*
//! faithfully rather than the wall-clock: `P` ranks run as OS threads,
//! exchange real messages over channels, and every send is instrumented
//! (message count, word count, sequential communication rounds). The
//! [`crate::costmodel`] module then projects the measured per-rank counts
//! onto a Cray-EX-like Hockney machine profile (γF + βW + φL).
//!
//! Collectives are built on point-to-point send/recv exactly like an MPI
//! implementation would, so the counts are *measured from real message
//! traffic*, not computed from formulas.

mod collectives;
mod thread_comm;

pub use collectives::{allgather, allreduce_sum, broadcast, reduce_to_root, AllreduceAlgo};
pub use thread_comm::{run_ranks, ThreadComm};

/// Traffic statistics accumulated by a rank's communicator.
///
/// `rounds` counts *sequential* point-to-point steps on this rank's
/// critical path (each send-or-recv that cannot overlap the previous one),
/// which is the Hockney latency multiplier; `words` counts f64 words sent
/// by this rank (bandwidth term); `msgs` counts messages sent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStats {
    pub msgs: u64,
    pub words: u64,
    pub rounds: u64,
    pub allreduces: u64,
}

impl CommStats {
    /// Merge by taking the elementwise max — the critical path over ranks.
    pub fn max(self, other: CommStats) -> CommStats {
        CommStats {
            msgs: self.msgs.max(other.msgs),
            words: self.words.max(other.words),
            rounds: self.rounds.max(other.rounds),
            allreduces: self.allreduces.max(other.allreduces),
        }
    }

    pub fn reset(&mut self) {
        *self = CommStats::default();
    }
}

/// Point-to-point message transport between ranks plus instrumentation.
///
/// Collectives ([`allreduce_sum`] etc.) are generic over this trait, so
/// the same algorithm code runs on the threaded transport in tests and on
/// the no-op transport when `P = 1`.
pub trait Communicator {
    /// This rank's id in `[0, size)`.
    fn rank(&self) -> usize;

    /// Number of ranks.
    fn size(&self) -> usize;

    /// Send `buf` to rank `to` (non-blocking semantics: buffered channel).
    fn send(&mut self, to: usize, buf: &[f64]);

    /// Receive the next message from rank `from` (blocking).
    fn recv(&mut self, from: usize) -> Vec<f64>;

    /// Synchronize all ranks.
    fn barrier(&mut self);

    /// Traffic counters for this rank.
    fn stats(&self) -> CommStats;

    /// Mutable access for the collectives' round accounting.
    fn stats_mut(&mut self) -> &mut CommStats;
}

/// The `P = 1` communicator: no traffic, no synchronization.
#[derive(Debug, Default)]
pub struct SelfComm {
    stats: CommStats,
}

impl SelfComm {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Communicator for SelfComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn send(&mut self, _to: usize, _buf: &[f64]) {
        panic!("SelfComm: send on a single-rank communicator");
    }

    fn recv(&mut self, _from: usize) -> Vec<f64> {
        panic!("SelfComm: recv on a single-rank communicator");
    }

    fn barrier(&mut self) {}

    fn stats(&self) -> CommStats {
        self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_comm_is_trivial() {
        let mut c = SelfComm::new();
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        c.barrier();
        let mut buf = vec![1.0, 2.0];
        allreduce_sum(&mut c, &mut buf, AllreduceAlgo::Rabenseifner);
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(c.stats().msgs, 0);
    }

    #[test]
    fn stats_max_is_elementwise() {
        let a = CommStats {
            msgs: 3,
            words: 10,
            rounds: 2,
            allreduces: 1,
        };
        let b = CommStats {
            msgs: 1,
            words: 20,
            rounds: 5,
            allreduces: 1,
        };
        let m = a.max(b);
        assert_eq!(m.msgs, 3);
        assert_eq!(m.words, 20);
        assert_eq!(m.rounds, 5);
    }
}
