//! Simulated-MPI communication substrate.
//!
//! The paper's implementation is C + MPI on a Cray EX. This box has a
//! single physical core, so we reproduce the *communication structure*
//! faithfully rather than the wall-clock: `P` ranks run as OS threads,
//! exchange real messages over channels, and every send is instrumented
//! (message count, word count, sequential communication rounds). The
//! [`crate::costmodel`] module then projects the measured per-rank counts
//! onto a Cray-EX-like Hockney machine profile (γF + βW + φL).
//!
//! Collectives are built on point-to-point send/recv exactly like an MPI
//! implementation would, so the counts are *measured from real message
//! traffic*, not computed from formulas.

#![forbid(unsafe_code)]

mod collectives;
mod nonblocking;
mod thread_comm;

pub use collectives::{
    allgather, allgatherv, allreduce_sum, broadcast, reduce_to_root, AllreduceAlgo,
};
pub use nonblocking::CollectiveHandle;
pub use thread_comm::{run_ranks, ThreadComm};

/// Traffic statistics accumulated by a rank's communicator.
///
/// `rounds` counts *sequential* point-to-point steps on this rank's
/// critical path (each send-or-recv that cannot overlap the previous one),
/// which is the Hockney latency multiplier; `words` counts f64 words sent
/// by this rank (bandwidth term); `msgs` counts messages sent.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Messages sent by this rank.
    pub msgs: u64,
    /// f64 words sent by this rank (the Hockney bandwidth term).
    pub words: u64,
    /// Sequential point-to-point steps on this rank's critical path (the
    /// Hockney latency multiplier).
    pub rounds: u64,
    /// Allreduce collectives this rank participated in.
    pub allreduces: u64,
}

impl CommStats {
    /// Merge by taking the elementwise max — the critical path over ranks.
    pub fn max(self, other: CommStats) -> CommStats {
        CommStats {
            msgs: self.msgs.max(other.msgs),
            words: self.words.max(other.words),
            rounds: self.rounds.max(other.rounds),
            allreduces: self.allreduces.max(other.allreduces),
        }
    }

    /// Elementwise sum — composing *sequential* stages on one rank (e.g.
    /// the grid layout's column reduce followed by its row allgather, whose
    /// rounds cannot overlap).
    pub fn plus(self, other: CommStats) -> CommStats {
        CommStats {
            msgs: self.msgs + other.msgs,
            words: self.words + other.words,
            rounds: self.rounds + other.rounds,
            allreduces: self.allreduces + other.allreduces,
        }
    }

    /// Zero all counters.
    pub fn reset(&mut self) {
        *self = CommStats::default();
    }
}

/// Point-to-point message transport between ranks plus instrumentation.
///
/// Collectives ([`allreduce_sum`] etc.) are generic over this trait, so
/// the same algorithm code runs on the threaded transport in tests and on
/// the no-op transport when `P = 1`.
pub trait Communicator {
    /// This rank's id in `[0, size)`.
    fn rank(&self) -> usize;

    /// Number of ranks.
    fn size(&self) -> usize;

    /// Send `buf` to rank `to` (non-blocking semantics: buffered channel).
    fn send(&mut self, to: usize, buf: &[f64]);

    /// Receive the next message from rank `from` (blocking).
    fn recv(&mut self, from: usize) -> Vec<f64>;

    /// Receive the next message from rank `from` if one has already
    /// arrived; `None` otherwise. The nonblocking collectives
    /// ([`CollectiveHandle`]) use this to make progress without
    /// stalling the compute they are overlapped with.
    fn try_recv(&mut self, from: usize) -> Option<Vec<f64>>;

    /// Synchronize all ranks.
    fn barrier(&mut self);

    /// Traffic counters for this rank.
    fn stats(&self) -> CommStats;

    /// Mutable access for the collectives' round accounting.
    fn stats_mut(&mut self) -> &mut CommStats;
}

/// The `P = 1` communicator: no traffic, no synchronization.
#[derive(Debug, Default)]
pub struct SelfComm {
    stats: CommStats,
}

impl SelfComm {
    /// A fresh single-rank communicator with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Communicator for SelfComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn send(&mut self, _to: usize, _buf: &[f64]) {
        panic!("SelfComm: send on a single-rank communicator");
    }

    fn recv(&mut self, _from: usize) -> Vec<f64> {
        panic!("SelfComm: recv on a single-rank communicator");
    }

    fn try_recv(&mut self, _from: usize) -> Option<Vec<f64>> {
        panic!("SelfComm: try_recv on a single-rank communicator");
    }

    fn barrier(&mut self) {}

    fn stats(&self) -> CommStats {
        self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

/// A sub-communicator: a subset of a parent communicator's ranks,
/// renumbered `0..members.len()` in member order, with its own traffic
/// counters — the moral equivalent of `MPI_Comm_split`.
///
/// The 2D grid layout carves two of these out of the global communicator
/// per rank: the *column* subcommunicator (the `pc` ranks holding
/// complementary feature shards of the same row block — the gram reduce
/// runs here) and the *row* subcommunicator (the `pr` ranks holding the
/// same feature shard — the allgather runs here). Collectives are generic
/// over [`Communicator`], so the same allreduce/allgather code runs
/// unchanged over a subgroup.
///
/// Accounting: every send is recorded in the subcommunicator's own
/// [`CommStats`] (borrowed from the caller so counters persist across the
/// subcommunicator's short lifetime). The parent transport additionally
/// counts raw messages in its own stats; grid users report per-subcomm
/// stats (and their [`CommStats::plus`] sum), never the parent's.
///
/// Messages between two ranks travel the parent's dedicated per-pair
/// channels, so concurrent collectives over *disjoint* subgroups (all pr
/// column groups reduce at once) cannot interfere.
pub struct SubComm<'a, C: Communicator> {
    parent: &'a mut C,
    /// Global (parent) ranks of the members, in subgroup rank order.
    members: &'a [usize],
    /// This rank's subgroup rank: `members[rank] == parent.rank()`.
    rank: usize,
    stats: &'a mut CommStats,
}

impl<'a, C: Communicator> SubComm<'a, C> {
    /// View `parent` as the subgroup `members` (which must contain the
    /// parent's own rank). `stats` accumulates this subgroup's traffic.
    pub fn new(parent: &'a mut C, members: &'a [usize], stats: &'a mut CommStats) -> Self {
        let prank = parent.rank();
        let rank = members
            .iter()
            .position(|&r| r == prank)
            .expect("SubComm: the calling rank must be a member of its own subgroup");
        SubComm {
            parent,
            members,
            rank,
            stats,
        }
    }
}

impl<'a, C: Communicator> Communicator for SubComm<'a, C> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.members.len()
    }

    fn send(&mut self, to: usize, buf: &[f64]) {
        self.stats.msgs += 1;
        self.stats.words += buf.len() as u64;
        self.parent.send(self.members[to], buf);
    }

    fn recv(&mut self, from: usize) -> Vec<f64> {
        self.parent.recv(self.members[from])
    }

    fn try_recv(&mut self, from: usize) -> Option<Vec<f64>> {
        self.parent.try_recv(self.members[from])
    }

    fn barrier(&mut self) {
        panic!("SubComm: subgroup barriers are unsupported (collectives never need one)");
    }

    fn stats(&self) -> CommStats {
        *self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_comm_is_trivial() {
        let mut c = SelfComm::new();
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        c.barrier();
        let mut buf = vec![1.0, 2.0];
        allreduce_sum(&mut c, &mut buf, AllreduceAlgo::Rabenseifner);
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(c.stats().msgs, 0);
    }

    /// Disjoint subgroups of one parent communicator run collectives
    /// concurrently without cross-talk, each summing only its members'
    /// contributions, with traffic accounted per subgroup.
    #[test]
    fn subcomm_collectives_stay_within_the_subgroup() {
        let p = 6;
        let groups = [vec![0usize, 1, 2], vec![3usize, 4, 5]];
        let outs = run_ranks(p, |c| {
            let grank = c.rank();
            let members = &groups[grank / 3];
            let mut stats = CommStats::default();
            let mut buf = vec![(grank + 1) as f64; 4];
            let mut sub = SubComm::new(c, members, &mut stats);
            assert_eq!(sub.size(), 3);
            assert_eq!(sub.rank(), grank % 3);
            allreduce_sum(&mut sub, &mut buf, AllreduceAlgo::RecursiveDoubling);
            (buf, stats)
        });
        // Group {0,1,2} sums to 6, group {3,4,5} to 15 — in every slot.
        for (rank, (buf, stats)) in outs.iter().enumerate() {
            let expect = if rank < 3 { 6.0 } else { 15.0 };
            assert!(buf.iter().all(|&v| v == expect), "rank {rank}: {buf:?}");
            assert_eq!(stats.allreduces, 1);
            assert!(stats.words > 0 && stats.rounds > 0);
        }
    }

    /// A subgroup's traffic counters match a standalone communicator of
    /// the same size running the same collective.
    #[test]
    fn subcomm_traffic_matches_standalone_comm_of_same_size() {
        let standalone = run_ranks(3, |c| {
            let mut buf = vec![1.0; 8];
            allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
            c.stats()
        });
        let groups = [vec![0usize, 2, 4], vec![1usize, 3, 5]];
        let sub_stats = run_ranks(6, |c| {
            let members = &groups[c.rank() % 2];
            let mut stats = CommStats::default();
            let mut sub = SubComm::new(c, members, &mut stats);
            let mut buf = vec![1.0; 8];
            allreduce_sum(&mut sub, &mut buf, AllreduceAlgo::Rabenseifner);
            stats
        });
        for (rank, s) in sub_stats.iter().enumerate() {
            let group_rank = rank / 2;
            assert_eq!(*s, standalone[group_rank], "rank {rank}");
        }
    }

    #[test]
    fn stats_plus_is_elementwise_sum() {
        let a = CommStats {
            msgs: 3,
            words: 10,
            rounds: 2,
            allreduces: 1,
        };
        let b = CommStats {
            msgs: 1,
            words: 20,
            rounds: 5,
            allreduces: 0,
        };
        let s = a.plus(b);
        assert_eq!(s.msgs, 4);
        assert_eq!(s.words, 30);
        assert_eq!(s.rounds, 7);
        assert_eq!(s.allreduces, 1);
    }

    #[test]
    fn stats_max_is_elementwise() {
        let a = CommStats {
            msgs: 3,
            words: 10,
            rounds: 2,
            allreduces: 1,
        };
        let b = CommStats {
            msgs: 1,
            words: 20,
            rounds: 5,
            allreduces: 1,
        };
        let m = a.max(b);
        assert_eq!(m.msgs, 3);
        assert_eq!(m.words, 20);
        assert_eq!(m.rounds, 5);
    }
}
