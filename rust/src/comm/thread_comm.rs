//! Threaded rank transport: `P` ranks as scoped OS threads, with a
//! dedicated mpsc channel per (sender, receiver) pair — the moral
//! equivalent of MPI point-to-point over shared memory.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use super::{CommStats, Communicator};

/// Per-rank communicator handle for the threaded transport.
pub struct ThreadComm {
    rank: usize,
    size: usize,
    /// `senders[to]` — channel into rank `to`'s `receivers[self.rank]`.
    senders: Vec<Sender<Vec<f64>>>,
    /// `receivers[from]` — messages sent by rank `from` to this rank.
    receivers: Vec<Receiver<Vec<f64>>>,
    barrier: Arc<Barrier>,
    stats: CommStats,
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, to: usize, buf: &[f64]) {
        assert_ne!(to, self.rank, "send to self");
        self.stats.msgs += 1;
        self.stats.words += buf.len() as u64;
        self.senders[to]
            .send(buf.to_vec())
            .expect("peer rank hung up");
    }

    fn recv(&mut self, from: usize) -> Vec<f64> {
        assert_ne!(from, self.rank, "recv from self");
        self.receivers[from].recv().expect("peer rank hung up")
    }

    fn try_recv(&mut self, from: usize) -> Option<Vec<f64>> {
        assert_ne!(from, self.rank, "recv from self");
        match self.receivers[from].try_recv() {
            Ok(buf) => Some(buf),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => panic!("peer rank hung up"),
        }
    }

    fn barrier(&mut self) {
        self.barrier.wait();
    }

    fn stats(&self) -> CommStats {
        self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

/// Run `f` on `p` ranks (scoped threads), returning the per-rank results
/// in rank order. `f` may borrow from the caller (e.g. shared read-only
/// dataset shards).
///
/// Panics in any rank propagate (the join unwraps), so test assertions
/// inside ranks behave normally.
pub fn run_ranks<T, F>(p: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut ThreadComm) -> T + Sync,
{
    assert!(p > 0);
    // Build the p×p channel mesh. mesh[to][from] = receiver at `to` for
    // messages from `from`.
    let mut senders: Vec<Vec<Option<Sender<Vec<f64>>>>> = (0..p)
        .map(|_| (0..p).map(|_| None).collect())
        .collect();
    let mut receivers: Vec<Vec<Option<Receiver<Vec<f64>>>>> = (0..p)
        .map(|_| (0..p).map(|_| None).collect())
        .collect();
    for from in 0..p {
        for to in 0..p {
            if from == to {
                continue;
            }
            let (tx, rx) = channel();
            senders[from][to] = Some(tx);
            receivers[to][from] = Some(rx);
        }
    }
    let barrier = Arc::new(Barrier::new(p));

    // Assemble per-rank handles (self-channel slots hold dummies).
    let mut comms: Vec<ThreadComm> = Vec::with_capacity(p);
    for (rank, (srow, rrow)) in senders.into_iter().zip(receivers).enumerate() {
        let senders: Vec<Sender<Vec<f64>>> = srow
            .into_iter()
            .map(|s| s.unwrap_or_else(|| channel().0))
            .collect();
        let receivers: Vec<Receiver<Vec<f64>>> = rrow
            .into_iter()
            .map(|r| r.unwrap_or_else(|| channel().1))
            .collect();
        comms.push(ThreadComm {
            rank,
            size: p,
            senders,
            receivers,
            barrier: Arc::clone(&barrier),
            stats: CommStats::default(),
        });
    }

    std::thread::scope(|scope| {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut comm| {
                let f = &f;
                scope.spawn(move || f(&mut comm))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let out = run_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, &[1.0, 2.0, 3.0]);
                c.recv(1)
            } else {
                let got = c.recv(0);
                c.send(0, &got.iter().map(|x| x * 2.0).collect::<Vec<_>>());
                got
            }
        });
        assert_eq!(out[0], vec![2.0, 4.0, 6.0]);
        assert_eq!(out[1], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn stats_count_traffic() {
        let stats = run_ranks(3, |c| {
            if c.rank() == 0 {
                c.send(1, &[0.0; 10]);
                c.send(2, &[0.0; 5]);
            } else {
                let _ = c.recv(0);
            }
            c.stats()
        });
        assert_eq!(stats[0].msgs, 2);
        assert_eq!(stats[0].words, 15);
        assert_eq!(stats[1].msgs, 0);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        run_ranks(4, |c| {
            counter.fetch_add(1, Ordering::SeqCst);
            c.barrier();
            // After the barrier every rank's increment must be visible.
            assert_eq!(counter.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn many_ranks_round_robin() {
        let p = 8;
        let out = run_ranks(p, |c| {
            let next = (c.rank() + 1) % p;
            let prev = (c.rank() + p - 1) % p;
            c.send(next, &[c.rank() as f64]);
            c.recv(prev)[0]
        });
        for (r, v) in out.iter().enumerate() {
            assert_eq!(*v, ((r + p - 1) % p) as f64);
        }
    }
}
