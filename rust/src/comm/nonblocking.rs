//! Nonblocking collectives: `post`/`test`/`wait` handles over the same
//! point-to-point transport as the blocking collectives.
//!
//! A posted collective is a *script* — the exact per-rank sequence of
//! sends, receives, and round increments the blocking algorithm in
//! [`super::collectives`] would execute — replayed lazily. `post` runs the
//! script eagerly up to the first receive whose message has not arrived
//! (sends are buffered, so they never block); `test` resumes it
//! nonblockingly; `wait` resumes it with blocking receives and consumes
//! the handle. Because the script is the blocking algorithm's own step
//! sequence, a posted collective produces bitwise-identical results and
//! word-for-word identical [`CommStats`] to its blocking counterpart, no
//! matter how much compute the caller interleaves between `post` and
//! `wait` — this is what lets the gram engine overlap the fragment
//! exchange and the s-step reduce without touching the determinism
//! contract.
//!
//! Handles are pure data: they do not borrow the communicator. Every
//! `post`/`test`/`wait` call takes the communicator as an argument, so a
//! stage that owns `&mut C` (e.g. the grid reduce) can stash an in-flight
//! handle in a field and keep using its communicator for accounting.

use super::{AllreduceAlgo, CommStats, Communicator};

/// One step of a posted collective's per-rank script. Ranges index into
/// the handle's buffer; only the *data* flowing through a `Recv` depends
/// on other ranks, never the schedule itself.
#[derive(Clone, Copy, Debug)]
enum Step {
    /// Send `buf[lo..hi]` to `to`.
    Send { to: usize, lo: usize, hi: usize },
    /// Receive from `from` into `buf[lo..hi]`; `add` accumulates (reduce
    /// steps), otherwise the block is copied (gather/fold-back steps).
    Recv {
        from: usize,
        lo: usize,
        hi: usize,
        add: bool,
    },
    /// One sequential step on this rank's critical path.
    Round,
}

/// An in-flight nonblocking collective (allreduce or ring allgatherv).
///
/// Obtain one with [`CollectiveHandle::post_allreduce`] or
/// [`CollectiveHandle::post_allgatherv`]; drive it with [`test`] and
/// finish with [`wait`], passing the *same* communicator each time.
/// Waiting twice panics; testing a completed handle keeps returning
/// `true`.
///
/// [`test`]: CollectiveHandle::test
/// [`wait`]: CollectiveHandle::wait
pub struct CollectiveHandle {
    buf: Vec<f64>,
    steps: Vec<Step>,
    cursor: usize,
    consumed: bool,
    posted: CommStats,
}

impl CollectiveHandle {
    /// Post a nonblocking sum-allreduce of `buf` (same algorithm, message
    /// order, and traffic accounting as [`super::allreduce_sum`]). The
    /// reduced vector is returned by [`Self::wait`].
    pub fn post_allreduce<C: Communicator>(
        comm: &mut C,
        buf: Vec<f64>,
        algo: AllreduceAlgo,
    ) -> CollectiveHandle {
        comm.stats_mut().allreduces += 1;
        let p = comm.size();
        let steps = if p == 1 || buf.is_empty() {
            Vec::new()
        } else {
            allreduce_script(comm.rank(), p, buf.len(), algo)
        };
        let mut h = CollectiveHandle::with_script(buf, steps);
        h.posted.allreduces = 1;
        h.advance(comm, false);
        h
    }

    /// Post a nonblocking ring allgatherv (same schedule and accounting
    /// as [`super::allgatherv`]): rank `r` contributes `counts[r]` words
    /// and [`Self::wait`] returns the rank-ordered concatenation.
    pub fn post_allgatherv<C: Communicator>(
        comm: &mut C,
        mine: &[f64],
        counts: &[usize],
    ) -> CollectiveHandle {
        let p = comm.size();
        let rank = comm.rank();
        assert_eq!(counts.len(), p, "post_allgatherv: one count per rank");
        assert_eq!(
            mine.len(),
            counts[rank],
            "post_allgatherv: rank {rank} contributed {} words but counts[{rank}] = {}",
            mine.len(),
            counts[rank]
        );
        let mut offsets = Vec::with_capacity(p + 1);
        let mut total = 0usize;
        offsets.push(0);
        for &c in counts {
            total += c;
            offsets.push(total);
        }
        let mut out = vec![0.0; total];
        out[offsets[rank]..offsets[rank + 1]].copy_from_slice(mine);
        let steps = if p == 1 {
            Vec::new()
        } else {
            allgatherv_script(rank, p, &offsets)
        };
        let mut h = CollectiveHandle::with_script(out, steps);
        h.advance(comm, false);
        h
    }

    fn with_script(buf: Vec<f64>, steps: Vec<Step>) -> CollectiveHandle {
        let mut posted = CommStats::default();
        for s in &steps {
            match *s {
                Step::Send { lo, hi, .. } => {
                    posted.msgs += 1;
                    posted.words += (hi - lo) as u64;
                }
                Step::Round => posted.rounds += 1,
                Step::Recv { .. } => {}
            }
        }
        CollectiveHandle {
            buf,
            steps,
            cursor: 0,
            consumed: false,
            posted,
        }
    }

    /// Traffic this collective adds to the communicator's [`CommStats`]
    /// across its whole post→wait lifetime — known at post time because
    /// the schedule is deterministic. The engine charges this to the
    /// ledger's *posted* (overlappable) column exactly once.
    pub fn posted_stats(&self) -> CommStats {
        self.posted
    }

    /// True once every step of the script has run.
    pub fn is_done(&self) -> bool {
        self.cursor == self.steps.len()
    }

    /// Make progress without blocking; returns completion.
    ///
    /// Ordering contract: the transport is FIFO per rank pair, so
    /// collectives whose message streams share a rank pair must be
    /// *completed in post order* on every rank (receiving out of order
    /// would steal the earlier collective's messages). Handles over
    /// disjoint rank pairs — e.g. different subcommunicator groups — may
    /// complete in any order. The gram engine keeps at most one
    /// collective in flight per communicator, which satisfies this
    /// trivially.
    pub fn test<C: Communicator>(&mut self, comm: &mut C) -> bool {
        self.advance(comm, false)
    }

    /// Block until the collective completes and take the result buffer.
    /// Panics if called twice (the result was already taken).
    pub fn wait<C: Communicator>(&mut self, comm: &mut C) -> Vec<f64> {
        assert!(
            !self.consumed,
            "CollectiveHandle: wait called twice on the same handle"
        );
        self.advance(comm, true);
        self.consumed = true;
        std::mem::take(&mut self.buf)
    }

    /// Run script steps in order; at a `Recv`, block or bail out
    /// according to `block`. Returns completion.
    fn advance<C: Communicator>(&mut self, comm: &mut C, block: bool) -> bool {
        while self.cursor < self.steps.len() {
            match self.steps[self.cursor] {
                Step::Send { to, lo, hi } => comm.send(to, &self.buf[lo..hi]),
                Step::Round => comm.stats_mut().rounds += 1,
                Step::Recv { from, lo, hi, add } => {
                    let got = if block {
                        comm.recv(from)
                    } else {
                        match comm.try_recv(from) {
                            Some(got) => got,
                            None => return false,
                        }
                    };
                    assert_eq!(
                        got.len(),
                        hi - lo,
                        "nonblocking collective: rank {} received {} words where the \
                         schedule expects {}; every rank must post identical shapes",
                        comm.rank(),
                        got.len(),
                        hi - lo
                    );
                    let dst = &mut self.buf[lo..hi];
                    if add {
                        for (d, s) in dst.iter_mut().zip(&got) {
                            *d += s;
                        }
                    } else {
                        dst.copy_from_slice(&got);
                    }
                }
            }
            self.cursor += 1;
        }
        true
    }
}

/// Per-rank script of [`super::allreduce_sum`] — the same step sequence
/// the blocking code executes, with buffer ranges resolved a priori.
fn allreduce_script(rank: usize, p: usize, w: usize, algo: AllreduceAlgo) -> Vec<Step> {
    let mut steps = Vec::new();
    match algo {
        AllreduceAlgo::Linear => {
            reduce_to_root_script(&mut steps, rank, p, w);
            broadcast_script(&mut steps, rank, p, w);
        }
        AllreduceAlgo::RecursiveDoubling => {
            pof2_fold_script(&mut steps, rank, p, w, |steps, group_rank, group, pof2| {
                recursive_doubling_script(steps, group_rank, group, pof2, 0, w);
            });
        }
        AllreduceAlgo::Rabenseifner => {
            pof2_fold_script(&mut steps, rank, p, w, |steps, group_rank, group, pof2| {
                rabenseifner_script(steps, group_rank, group, pof2, w);
            });
        }
    }
    steps
}

/// Script of `with_pof2_fold`: evens of the first `2·rem` ranks fold onto
/// their odd neighbour and wait for the result; survivors run `core` and
/// send folded results back.
fn pof2_fold_script(
    steps: &mut Vec<Step>,
    rank: usize,
    p: usize,
    w: usize,
    core: impl FnOnce(&mut Vec<Step>, usize, &[usize], usize),
) {
    let pof2 = p.next_power_of_two() / if p.is_power_of_two() { 1 } else { 2 };
    let rem = p - pof2;
    let survivors: Vec<usize> = (0..p)
        .filter(|&r| (r < 2 * rem && r % 2 == 1) || r >= 2 * rem)
        .collect();
    if rank < 2 * rem && rank % 2 == 0 {
        steps.push(Step::Send {
            to: rank + 1,
            lo: 0,
            hi: w,
        });
        steps.push(Step::Round);
        steps.push(Step::Recv {
            from: rank + 1,
            lo: 0,
            hi: w,
            add: false,
        });
        steps.push(Step::Round);
        return;
    }
    if rank < 2 * rem {
        steps.push(Step::Recv {
            from: rank - 1,
            lo: 0,
            hi: w,
            add: true,
        });
        steps.push(Step::Round);
    }
    let group_rank = survivors
        .iter()
        .position(|&r| r == rank)
        .expect("survivor rank");
    core(steps, group_rank, &survivors, pof2);
    if rank < 2 * rem {
        steps.push(Step::Send {
            to: rank - 1,
            lo: 0,
            hi: w,
        });
        steps.push(Step::Round);
    }
}

/// Recursive-doubling exchange-and-add over `buf[lo..lo+w]`.
fn recursive_doubling_script(
    steps: &mut Vec<Step>,
    group_rank: usize,
    group: &[usize],
    pof2: usize,
    lo: usize,
    w: usize,
) {
    let mut mask = 1usize;
    while mask < pof2 {
        let partner = group[group_rank ^ mask];
        steps.push(Step::Send {
            to: partner,
            lo,
            hi: lo + w,
        });
        steps.push(Step::Recv {
            from: partner,
            lo,
            hi: lo + w,
            add: true,
        });
        steps.push(Step::Round);
        mask <<= 1;
    }
}

/// Reduce-scatter (recursive halving) + allgather (recursive doubling)
/// over the survivor group — the script of `rabenseifner_core`.
fn rabenseifner_script(
    steps: &mut Vec<Step>,
    group_rank: usize,
    group: &[usize],
    pof2: usize,
    w: usize,
) {
    if w == 0 {
        return;
    }
    if w < pof2 {
        recursive_doubling_script(steps, group_rank, group, pof2, 0, w);
        return;
    }
    let bounds: Vec<usize> = (0..=pof2).map(|i| i * w / pof2).collect();

    let mut span_lo = 0usize;
    let mut span_hi = pof2;
    let mut mask = pof2 / 2;
    while mask > 0 {
        let partner = group[group_rank ^ mask];
        let mid = (span_lo + span_hi) / 2;
        let (keep_lo, keep_hi, send_lo, send_hi) = if group_rank & mask == 0 {
            (span_lo, mid, mid, span_hi)
        } else {
            (mid, span_hi, span_lo, mid)
        };
        steps.push(Step::Send {
            to: partner,
            lo: bounds[send_lo],
            hi: bounds[send_hi],
        });
        steps.push(Step::Recv {
            from: partner,
            lo: bounds[keep_lo],
            hi: bounds[keep_hi],
            add: true,
        });
        steps.push(Step::Round);
        span_lo = keep_lo;
        span_hi = keep_hi;
        mask >>= 1;
    }

    let mut span_lo = group_rank;
    let mut span_hi = group_rank + 1;
    let mut mask = 1usize;
    while mask < pof2 {
        let partner = group[group_rank ^ mask];
        steps.push(Step::Send {
            to: partner,
            lo: bounds[span_lo],
            hi: bounds[span_hi],
        });
        let (new_lo, new_hi) = if group_rank & mask == 0 {
            (span_lo, span_hi + (span_hi - span_lo))
        } else {
            (span_lo - (span_hi - span_lo), span_hi)
        };
        let (recv_lo, recv_hi) = if group_rank & mask == 0 {
            (span_hi, new_hi)
        } else {
            (new_lo, span_lo)
        };
        steps.push(Step::Recv {
            from: partner,
            lo: bounds[recv_lo],
            hi: bounds[recv_hi],
            add: false,
        });
        steps.push(Step::Round);
        span_lo = new_lo;
        span_hi = new_hi;
        mask <<= 1;
    }
}

/// Script of [`super::reduce_to_root`] (binomial tree onto rank 0).
fn reduce_to_root_script(steps: &mut Vec<Step>, rank: usize, p: usize, w: usize) {
    let mut mask = 1usize;
    while mask < p {
        if rank & mask != 0 {
            steps.push(Step::Send {
                to: rank & !mask,
                lo: 0,
                hi: w,
            });
            steps.push(Step::Round);
            return;
        } else if rank | mask < p {
            steps.push(Step::Recv {
                from: rank | mask,
                lo: 0,
                hi: w,
                add: true,
            });
            steps.push(Step::Round);
        }
        mask <<= 1;
    }
}

/// Script of [`super::broadcast`] from root 0 (binomial tree).
fn broadcast_script(steps: &mut Vec<Step>, rank: usize, p: usize, w: usize) {
    let vrank = rank; // root 0: the rotated space is the identity.
    if vrank != 0 {
        let parent = vrank & (vrank - 1);
        steps.push(Step::Recv {
            from: parent,
            lo: 0,
            hi: w,
            add: false,
        });
        steps.push(Step::Round);
    }
    let lowbit = if vrank == 0 {
        p.next_power_of_two()
    } else {
        vrank & vrank.wrapping_neg()
    };
    let mut mask = lowbit >> 1;
    while mask > 0 {
        let child = vrank | mask;
        if child != vrank && child < p {
            steps.push(Step::Send {
                to: child,
                lo: 0,
                hi: w,
            });
            steps.push(Step::Round);
        }
        mask >>= 1;
    }
}

/// Script of [`super::allgatherv`] (ring): at step t, forward the block
/// received at step t−1.
fn allgatherv_script(rank: usize, p: usize, offsets: &[usize]) -> Vec<Step> {
    let mut steps = Vec::new();
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    let mut cur = rank;
    for _ in 0..p - 1 {
        steps.push(Step::Send {
            to: next,
            lo: offsets[cur],
            hi: offsets[cur + 1],
        });
        cur = (cur + p - 1) % p;
        steps.push(Step::Recv {
            from: prev,
            lo: offsets[cur],
            hi: offsets[cur + 1],
            add: false,
        });
        steps.push(Step::Round);
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{allgatherv, allreduce_sum, run_ranks};

    const ALGOS: [AllreduceAlgo; 3] = [
        AllreduceAlgo::Rabenseifner,
        AllreduceAlgo::RecursiveDoubling,
        AllreduceAlgo::Linear,
    ];

    /// A posted allreduce completed by `wait` matches the blocking
    /// allreduce bitwise, and its measured traffic matches both the
    /// blocking run's stats and the handle's own `posted_stats`.
    #[test]
    fn posted_allreduce_matches_blocking_bitwise_and_in_stats() {
        for algo in ALGOS {
            for p in [2usize, 3, 4, 5, 7, 8, 12] {
                for w in [1usize, 3, 17, 64] {
                    let blocking = run_ranks(p, |c| {
                        let mut buf: Vec<f64> = (0..w)
                            .map(|i| ((c.rank() + 1) * (i + 1)) as f64 * 0.25)
                            .collect();
                        allreduce_sum(c, &mut buf, algo);
                        (buf, c.stats())
                    });
                    let posted = run_ranks(p, |c| {
                        let buf: Vec<f64> = (0..w)
                            .map(|i| ((c.rank() + 1) * (i + 1)) as f64 * 0.25)
                            .collect();
                        let mut h = CollectiveHandle::post_allreduce(c, buf, algo);
                        let out = h.wait(c);
                        (out, c.stats(), h.posted_stats())
                    });
                    for (rank, ((bbuf, bstats), (nbuf, nstats, planned))) in
                        blocking.iter().zip(&posted).enumerate()
                    {
                        assert_eq!(bbuf, nbuf, "{algo:?} p={p} w={w} rank {rank}");
                        assert_eq!(bstats, nstats, "{algo:?} p={p} w={w} rank {rank}");
                        let mut with_count = *planned;
                        with_count.allreduces = nstats.allreduces;
                        assert_eq!(
                            &with_count, nstats,
                            "{algo:?} p={p} w={w} rank {rank}: posted_stats must \
                             equal the traffic actually recorded"
                        );
                    }
                }
            }
        }
    }

    /// Same contract for the ring allgatherv, including empty blocks.
    #[test]
    fn posted_allgatherv_matches_blocking_bitwise_and_in_stats() {
        for p in [2usize, 3, 4, 6] {
            let counts: Vec<usize> = (0..p).map(|r| [3, 0, 1, 2][r % 4]).collect();
            let blocking = run_ranks(p, |c| {
                let r = c.rank();
                let mine: Vec<f64> = (0..counts[r]).map(|i| (10 * r + i) as f64).collect();
                let out = allgatherv(c, &mine, &counts);
                (out, c.stats())
            });
            let posted = run_ranks(p, |c| {
                let r = c.rank();
                let mine: Vec<f64> = (0..counts[r]).map(|i| (10 * r + i) as f64).collect();
                let mut h = CollectiveHandle::post_allgatherv(c, &mine, &counts);
                let out = h.wait(c);
                (out, c.stats(), h.posted_stats())
            });
            for (rank, ((bbuf, bstats), (nbuf, nstats, planned))) in
                blocking.iter().zip(&posted).enumerate()
            {
                assert_eq!(bbuf, nbuf, "p={p} rank {rank}");
                assert_eq!(bstats, nstats, "p={p} rank {rank}");
                assert_eq!(planned, nstats, "p={p} rank {rank}: posted-traffic once");
            }
        }
    }

    /// `test` may be polled any number of times, in any order relative to
    /// other ranks' progress; it eventually reports done and never
    /// re-executes traffic (stats equal the single-shot planned stats).
    #[test]
    fn test_polls_are_idempotent_and_converge() {
        let p = 4;
        let outs = run_ranks(p, |c| {
            let buf = vec![c.rank() as f64 + 1.0; 8];
            let mut h = CollectiveHandle::post_allreduce(c, buf, AllreduceAlgo::Rabenseifner);
            // Poll a few times before committing to the blocking wait —
            // rank 0 skips polling entirely (out-of-order completion).
            if c.rank() != 0 {
                for _ in 0..5 {
                    if h.test(c) {
                        break;
                    }
                }
            }
            let out = h.wait(c);
            assert!(h.is_done());
            assert!(h.test(c), "test after completion stays true");
            (out, c.stats(), h.posted_stats())
        });
        let expect = (1..=p).map(|r| r as f64).sum::<f64>();
        for (out, stats, planned) in &outs {
            assert!(out.iter().all(|&v| v == expect));
            let mut with_count = *planned;
            with_count.allreduces = 1;
            assert_eq!(&with_count, stats, "polling must not double-account traffic");
        }
    }

    #[test]
    fn double_wait_panics() {
        let results = run_ranks(2, |c| {
            let buf = vec![1.0; 4];
            let mut h =
                CollectiveHandle::post_allreduce(c, buf, AllreduceAlgo::RecursiveDoubling);
            let _ = h.wait(c);
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = h.wait(c);
            }))
            .is_err()
        });
        assert!(results.iter().all(|&panicked| panicked));
    }

    /// Handles do not borrow the communicator, so one rank can hold two
    /// in-flight collectives over *disjoint* subgroups (disjoint rank
    /// pairs) and complete them in reverse post order. The peers run the
    /// plain blocking allreduce — posted and blocking collectives speak
    /// the same wire protocol.
    #[test]
    fn disjoint_subgroup_handles_complete_out_of_order() {
        use crate::comm::{CommStats, SubComm};
        let g01 = [0usize, 1];
        let g02 = [0usize, 2];
        let outs = run_ranks(3, |c| {
            let mine = vec![(c.rank() + 1) as f64; 4];
            match c.rank() {
                0 => {
                    let (mut s1, mut s2) = (CommStats::default(), CommStats::default());
                    let mut h1 = {
                        let mut sub = SubComm::new(c, &g01, &mut s1);
                        CollectiveHandle::post_allreduce(
                            &mut sub,
                            mine.clone(),
                            AllreduceAlgo::Rabenseifner,
                        )
                    };
                    let mut h2 = {
                        let mut sub = SubComm::new(c, &g02, &mut s2);
                        CollectiveHandle::post_allreduce(
                            &mut sub,
                            mine.clone(),
                            AllreduceAlgo::Rabenseifner,
                        )
                    };
                    // Reverse post order: wait the {0,2} collective first.
                    let out2 = {
                        let mut sub = SubComm::new(c, &g02, &mut s2);
                        h2.wait(&mut sub)
                    };
                    let out1 = {
                        let mut sub = SubComm::new(c, &g01, &mut s1);
                        h1.wait(&mut sub)
                    };
                    (out1, out2)
                }
                r => {
                    let members: &[usize] = if r == 1 { &g01 } else { &g02 };
                    let mut stats = CommStats::default();
                    let mut sub = SubComm::new(c, members, &mut stats);
                    let mut buf = mine;
                    allreduce_sum(&mut sub, &mut buf, AllreduceAlgo::Rabenseifner);
                    (buf.clone(), buf)
                }
            }
        });
        // Group {0,1} sums to 3, group {0,2} sums to 4 — on every member.
        assert!(outs[0].0.iter().all(|&v| v == 3.0), "{:?}", outs[0].0);
        assert!(outs[0].1.iter().all(|&v| v == 4.0), "{:?}", outs[0].1);
        assert!(outs[1].0.iter().all(|&v| v == 3.0), "{:?}", outs[1].0);
        assert!(outs[2].0.iter().all(|&v| v == 4.0), "{:?}", outs[2].0);
    }

    /// Single-rank and empty-buffer posts complete immediately with the
    /// same accounting as the blocking path (one allreduce, no traffic).
    #[test]
    fn degenerate_posts_complete_at_post_time() {
        let outs = run_ranks(1, |c| {
            let mut h = CollectiveHandle::post_allreduce(c, vec![5.0], AllreduceAlgo::Linear);
            assert!(h.is_done());
            let out = h.wait(c);
            (out, c.stats())
        });
        assert_eq!(outs[0].0, vec![5.0]);
        assert_eq!(outs[0].1.allreduces, 1);
        assert_eq!(outs[0].1.words, 0);

        let outs = run_ranks(2, |c| {
            let mut h =
                CollectiveHandle::post_allreduce(c, Vec::new(), AllreduceAlgo::Rabenseifner);
            assert!(h.is_done());
            h.wait(c).len()
        });
        assert_eq!(outs, vec![0, 0]);
    }
}
