//! Collective operations over the [`Communicator`] point-to-point layer.
//!
//! Three allreduce algorithms are provided, matching the classic MPICH
//! implementations (Thakur, Rabenseifner & Gropp 2005 — the paper's
//! reference [26] for its `L = O(log P)`, `W = O(w)` allreduce costs):
//!
//! * [`AllreduceAlgo::Rabenseifner`] — recursive-halving reduce-scatter +
//!   recursive-doubling allgather. `L = 2 log₂ P`, `W ≈ 2w`. This is the
//!   default and the algorithm whose costs the paper assumes.
//! * [`AllreduceAlgo::RecursiveDoubling`] — `L = log₂ P`, `W = w log₂ P`.
//!   Better for small messages (pure latency-bound DCD with small `m`).
//! * [`AllreduceAlgo::Linear`] — gather-to-root + broadcast, `L = O(P)`.
//!   The naive baseline used in the collective-algorithm ablation.
//!
//! Non-power-of-two rank counts are handled the standard way: the first
//! `2·rem` ranks pre-fold pairwise onto `pof2` survivor ranks, the core
//! algorithm runs on the survivors, and the result is sent back.

use super::Communicator;

/// Allreduce algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllreduceAlgo {
    /// Reduce-scatter + allgather (`L = 2 log₂ P`, `W ≈ 2w`) — the
    /// default, and the costs the paper assumes.
    Rabenseifner,
    /// Recursive doubling (`L = log₂ P`, `W = w log₂ P`) — better for
    /// small latency-bound messages.
    RecursiveDoubling,
    /// Gather-to-root + broadcast (`L = O(P)`) — the naive baseline.
    Linear,
}

impl AllreduceAlgo {
    /// Canonical CLI/report name (`rabenseifner`, `recursive-doubling`,
    /// `linear`).
    pub fn name(&self) -> &'static str {
        match self {
            AllreduceAlgo::Rabenseifner => "rabenseifner",
            AllreduceAlgo::RecursiveDoubling => "recursive-doubling",
            AllreduceAlgo::Linear => "linear",
        }
    }

    /// Parse a [`Self::name`]-style string (plus the `rsag`/`rd`
    /// shorthands); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rabenseifner" | "rsag" => Some(AllreduceAlgo::Rabenseifner),
            "recursive-doubling" | "rd" => Some(AllreduceAlgo::RecursiveDoubling),
            "linear" => Some(AllreduceAlgo::Linear),
            _ => None,
        }
    }
}

#[inline]
fn add_into(dst: &mut [f64], src: &[f64]) {
    // A real assert, not a debug_assert: in release builds `zip` would
    // silently truncate a ragged contribution into a wrong answer. One
    // comparison per received message is free next to the adds.
    assert_eq!(
        dst.len(),
        src.len(),
        "collective: ranks contributed unequal lengths ({} vs {} words); \
         every rank must pass the same buffer size",
        dst.len(),
        src.len()
    );
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// In-place sum-allreduce of `buf` across all ranks.
pub fn allreduce_sum<C: Communicator>(comm: &mut C, buf: &mut [f64], algo: AllreduceAlgo) {
    let p = comm.size();
    comm.stats_mut().allreduces += 1;
    if p == 1 || buf.is_empty() {
        return;
    }
    match algo {
        AllreduceAlgo::Linear => {
            reduce_to_root(comm, buf);
            broadcast(comm, buf, 0);
        }
        AllreduceAlgo::RecursiveDoubling => {
            with_pof2_fold(comm, buf, |comm, buf, group_rank, group, pof2| {
                let mut mask = 1usize;
                while mask < pof2 {
                    let partner = group[group_rank ^ mask];
                    comm.send(partner, buf);
                    let got = comm.recv(partner);
                    add_into(buf, &got);
                    comm.stats_mut().rounds += 1;
                    mask <<= 1;
                }
            });
        }
        AllreduceAlgo::Rabenseifner => {
            with_pof2_fold(comm, buf, |comm, buf, group_rank, group, pof2| {
                rabenseifner_core(comm, buf, group_rank, group, pof2);
            });
        }
    }
}

/// Handle non-power-of-two `P`: ranks `r < 2·rem` fold pairwise (evens
/// send their vector to odds, which become survivors), the core runs on
/// the `pof2` survivors, and survivors send results back. `core` gets the
/// survivor-group rank, the survivor global ids, and `pof2`.
fn with_pof2_fold<C: Communicator>(
    comm: &mut C,
    buf: &mut [f64],
    core: impl FnOnce(&mut C, &mut [f64], usize, &[usize], usize),
) {
    let p = comm.size();
    let rank = comm.rank();
    let pof2 = p.next_power_of_two() / if p.is_power_of_two() { 1 } else { 2 };
    let rem = p - pof2;

    // Survivor set: odd ranks among the first 2·rem, plus all ranks ≥ 2·rem.
    let survivors: Vec<usize> = (0..p)
        .filter(|&r| (r < 2 * rem && r % 2 == 1) || r >= 2 * rem)
        .collect();
    debug_assert_eq!(survivors.len(), pof2);

    if rank < 2 * rem {
        if rank % 2 == 0 {
            // Fold onto rank+1, wait for the result.
            comm.send(rank + 1, buf);
            comm.stats_mut().rounds += 1;
            let result = comm.recv(rank + 1);
            buf.copy_from_slice(&result);
            comm.stats_mut().rounds += 1;
            return;
        } else {
            let got = comm.recv(rank - 1);
            add_into(buf, &got);
            comm.stats_mut().rounds += 1;
        }
    }

    let group_rank = survivors
        .iter()
        .position(|&r| r == rank)
        .expect("survivor rank");
    core(comm, buf, group_rank, &survivors, pof2);

    if rank < 2 * rem {
        // Send the finished vector back to the folded partner.
        comm.send(rank - 1, buf);
        comm.stats_mut().rounds += 1;
    }
}

/// Reduce-scatter (recursive halving) + allgather (recursive doubling)
/// among a power-of-two survivor group. Word count per rank ≈ 2·w·(1−1/P).
fn rabenseifner_core<C: Communicator>(
    comm: &mut C,
    buf: &mut [f64],
    group_rank: usize,
    group: &[usize],
    pof2: usize,
) {
    let w = buf.len();
    if w == 0 {
        return;
    }
    // Degenerate small vectors: fall back to recursive doubling (the
    // chunking below needs at least one element per rank to be useful).
    if w < pof2 {
        let mut mask = 1usize;
        while mask < pof2 {
            let partner = group[group_rank ^ mask];
            comm.send(partner, buf);
            let got = comm.recv(partner);
            add_into(buf, &got);
            comm.stats_mut().rounds += 1;
            mask <<= 1;
        }
        return;
    }

    // Chunk boundaries: contiguous, near-equal.
    let bounds: Vec<usize> = (0..=pof2).map(|i| i * w / pof2).collect();

    // --- Reduce-scatter via recursive halving ------------------------------
    // After step k, this rank owns a contiguous span of chunks that halves
    // each step; at the end it owns exactly chunk `group_rank`, fully
    // reduced.
    let mut span_lo = 0usize; // chunk index range [span_lo, span_hi)
    let mut span_hi = pof2;
    let mut mask = pof2 / 2;
    while mask > 0 {
        let partner_group = group_rank ^ mask;
        let partner = group[partner_group];
        let mid = (span_lo + span_hi) / 2;
        // The half containing our final chunk is kept; the other is sent.
        let (keep_lo, keep_hi, send_lo, send_hi) = if group_rank & mask == 0 {
            (span_lo, mid, mid, span_hi)
        } else {
            (mid, span_hi, span_lo, mid)
        };
        let send_slice = &buf[bounds[send_lo]..bounds[send_hi]];
        comm.send(partner, send_slice);
        let got = comm.recv(partner);
        add_into(&mut buf[bounds[keep_lo]..bounds[keep_hi]], &got);
        comm.stats_mut().rounds += 1;
        span_lo = keep_lo;
        span_hi = keep_hi;
        mask >>= 1;
    }
    debug_assert_eq!(span_lo + 1, span_hi);
    debug_assert_eq!(span_lo, group_rank);

    // --- Allgather via recursive doubling ----------------------------------
    let mut span_lo = group_rank;
    let mut span_hi = group_rank + 1;
    let mut mask = 1usize;
    while mask < pof2 {
        let partner_group = group_rank ^ mask;
        let partner = group[partner_group];
        comm.send(partner, &buf[bounds[span_lo]..bounds[span_hi]]);
        let got = comm.recv(partner);
        // Partner's span mirrors ours within the doubled window.
        let (new_lo, new_hi) = if group_rank & mask == 0 {
            (span_lo, span_hi + (span_hi - span_lo))
        } else {
            (span_lo - (span_hi - span_lo), span_hi)
        };
        if group_rank & mask == 0 {
            buf[bounds[span_hi]..bounds[new_hi]].copy_from_slice(&got);
        } else {
            buf[bounds[new_lo]..bounds[span_lo]].copy_from_slice(&got);
        }
        comm.stats_mut().rounds += 1;
        span_lo = new_lo;
        span_hi = new_hi;
        mask <<= 1;
    }
}

/// Binomial-tree reduce onto rank 0 (sum).
pub fn reduce_to_root<C: Communicator>(comm: &mut C, buf: &mut [f64]) {
    let p = comm.size();
    let rank = comm.rank();
    let mut mask = 1usize;
    while mask < p {
        if rank & mask != 0 {
            comm.send(rank & !mask, buf);
            comm.stats_mut().rounds += 1;
            return; // Sent up the tree; done.
        } else if rank | mask < p {
            let got = comm.recv(rank | mask);
            add_into(buf, &got);
            comm.stats_mut().rounds += 1;
        }
        mask <<= 1;
    }
}

/// Binomial-tree broadcast from `root`.
pub fn broadcast<C: Communicator>(comm: &mut C, buf: &mut [f64], root: usize) {
    let p = comm.size();
    // Work in the rotated space where root is rank 0.
    let vrank = (comm.rank() + p - root) % p;
    // Receive from parent (clear lowest set bit), unless root.
    if vrank != 0 {
        let parent = (vrank & (vrank - 1)).wrapping_add(root) % p;
        let got = comm.recv(parent);
        buf.copy_from_slice(&got);
        comm.stats_mut().rounds += 1;
    }
    // Forward to children: set bits above the lowest set bit.
    let lowbit = if vrank == 0 {
        p.next_power_of_two()
    } else {
        vrank & vrank.wrapping_neg()
    };
    let mut mask = lowbit >> 1;
    while mask > 0 {
        let child_v = vrank | mask;
        if child_v != vrank && child_v < p {
            let child = (child_v + root) % p;
            comm.send(child, buf);
            comm.stats_mut().rounds += 1;
        }
        mask >>= 1;
    }
}

/// Allgather: each rank contributes `mine`; returns the rank-ordered
/// concatenation. (Ring algorithm; equal contribution lengths required —
/// a ragged contribution is detected and rejected with a panic as soon
/// as the first mismatched block arrives, instead of corrupting `out`.)
pub fn allgather<C: Communicator>(comm: &mut C, mine: &[f64]) -> Vec<f64> {
    let counts = vec![mine.len(); comm.size()];
    allgatherv(comm, mine, &counts)
}

/// Variable-count allgather (`MPI_Allgatherv`): rank `r` contributes
/// `counts[r]` words; returns the rank-ordered concatenation
/// (`Σ counts` words). Every rank must pass the *same* `counts` — the
/// schedule is agreed a priori, exactly like the block-cyclic slice
/// sizes of the grid layout's row allgather, so no size-exchange
/// messages are needed.
///
/// Ring algorithm: `P − 1` sequential rounds; each rank forwards the
/// block it received in the previous round, so per-rank sent words are
/// `Σ counts − counts[next]` and rounds are `P − 1`. A block whose length
/// contradicts `counts` (a ragged contribution) panics as soon as it
/// arrives instead of corrupting the output.
pub fn allgatherv<C: Communicator>(comm: &mut C, mine: &[f64], counts: &[usize]) -> Vec<f64> {
    let p = comm.size();
    let rank = comm.rank();
    assert_eq!(counts.len(), p, "allgatherv: one count per rank");
    assert_eq!(
        mine.len(),
        counts[rank],
        "allgatherv: rank {rank} contributed {} words but counts[{rank}] = {}",
        mine.len(),
        counts[rank]
    );
    let mut offsets = Vec::with_capacity(p + 1);
    let mut total = 0usize;
    offsets.push(0);
    for &c in counts {
        total += c;
        offsets.push(total);
    }
    let mut out = vec![0.0; total];
    out[offsets[rank]..offsets[rank + 1]].copy_from_slice(mine);
    if p == 1 {
        return out;
    }
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    // Ring: at step t, forward the block received at step t-1.
    let mut cur = rank;
    for _ in 0..p - 1 {
        comm.send(next, &out[offsets[cur]..offsets[cur + 1]]);
        let got = comm.recv(prev);
        cur = (cur + p - 1) % p;
        assert_eq!(
            got.len(),
            counts[cur],
            "allgatherv: rank {rank} received {} words for rank {cur}'s block \
             but the shared counts say {}; every rank must pass identical \
             counts matching its own contribution",
            got.len(),
            counts[cur]
        );
        out[offsets[cur]..offsets[cur + 1]].copy_from_slice(&got);
        comm.stats_mut().rounds += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;

    fn check_allreduce(p: usize, w: usize, algo: AllreduceAlgo) {
        let outs = run_ranks(p, |c| {
            // Rank r contributes r+1 in every slot plus a slot index term.
            let mut buf: Vec<f64> = (0..w)
                .map(|i| (c.rank() + 1) as f64 + i as f64 * 0.5)
                .collect();
            allreduce_sum(c, &mut buf, algo);
            buf
        });
        let total_rank: f64 = (1..=p).map(|r| r as f64).sum();
        for out in &outs {
            for (i, v) in out.iter().enumerate() {
                let expect = total_rank + p as f64 * i as f64 * 0.5;
                assert!(
                    (v - expect).abs() < 1e-9,
                    "{algo:?} p={p} w={w} slot {i}: {v} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn allreduce_all_algorithms_all_shapes() {
        for algo in [
            AllreduceAlgo::Rabenseifner,
            AllreduceAlgo::RecursiveDoubling,
            AllreduceAlgo::Linear,
        ] {
            for p in [2, 3, 4, 5, 7, 8, 12, 16] {
                for w in [1, 2, 3, 17, 64, 257] {
                    check_allreduce(p, w, algo);
                }
            }
        }
    }

    #[test]
    fn rabenseifner_bandwidth_is_near_2w() {
        // For power-of-two P and w >> P the per-rank sent words should be
        // ≈ 2·w·(1−1/P), far below recursive doubling's w·log2(P).
        let p = 8;
        let w = 4096;
        let stats = run_ranks(p, |c| {
            let mut buf = vec![1.0; w];
            allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
            c.stats()
        });
        let max_words = stats.iter().map(|s| s.words).max().unwrap() as f64;
        let bound = 2.0 * w as f64 * (1.0 - 1.0 / p as f64) * 1.05;
        assert!(
            max_words <= bound,
            "rabenseifner sent {max_words} words, expected ≤ {bound}"
        );
        // And the round count is 2·log2(P).
        let max_rounds = stats.iter().map(|s| s.rounds).max().unwrap();
        assert_eq!(max_rounds, 2 * 3);
    }

    #[test]
    fn recursive_doubling_rounds_are_log_p() {
        let stats = run_ranks(8, |c| {
            let mut buf = vec![1.0; 32];
            allreduce_sum(c, &mut buf, AllreduceAlgo::RecursiveDoubling);
            c.stats()
        });
        for s in &stats {
            assert_eq!(s.rounds, 3);
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for p in [2, 3, 5, 8] {
            for root in 0..p {
                let outs = run_ranks(p, |c| {
                    let mut buf = if c.rank() == root {
                        vec![42.0, -1.0]
                    } else {
                        vec![0.0, 0.0]
                    };
                    broadcast(c, &mut buf, root);
                    buf
                });
                for out in outs {
                    assert_eq!(out, vec![42.0, -1.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn reduce_to_root_sums_on_rank0() {
        let outs = run_ranks(6, |c| {
            let mut buf = vec![(c.rank() + 1) as f64];
            reduce_to_root(c, &mut buf);
            (c.rank(), buf[0])
        });
        assert_eq!(outs[0], (0, 21.0));
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        for p in [1, 2, 3, 6] {
            let outs = run_ranks(p, |c| {
                let mine = vec![c.rank() as f64 * 10.0, c.rank() as f64 * 10.0 + 1.0];
                allgather(c, &mine)
            });
            let expect: Vec<f64> = (0..p)
                .flat_map(|r| vec![r as f64 * 10.0, r as f64 * 10.0 + 1.0])
                .collect();
            for out in outs {
                assert_eq!(out, expect);
            }
        }
    }

    #[test]
    fn allgatherv_concatenates_uneven_blocks_in_rank_order() {
        // Block sizes 3, 0, 1, 2 — including an empty contribution (a
        // row group that owns no block-cyclic rows).
        let counts = [3usize, 0, 1, 2];
        let outs = run_ranks(4, |c| {
            let r = c.rank();
            let mine: Vec<f64> = (0..counts[r]).map(|i| (10 * r + i) as f64).collect();
            let out = allgatherv(c, &mine, &counts);
            (out, c.stats())
        });
        let expect = vec![0.0, 1.0, 2.0, 20.0, 30.0, 31.0];
        for (r, (out, stats)) in outs.iter().enumerate() {
            assert_eq!(*out, expect, "rank {r}");
            assert_eq!(stats.rounds, 3, "ring is P-1 rounds");
            // Ring sends every block except the successor's own (which it
            // never needs forwarded).
            let next = (r + 1) % 4;
            let sent: usize = counts.iter().sum::<usize>() - counts[next];
            assert_eq!(stats.words, sent as u64, "rank {r}");
        }
    }

    #[test]
    fn allgatherv_single_rank_is_local() {
        let outs = run_ranks(1, |c| allgatherv(c, &[7.0, 8.0], &[2]));
        assert_eq!(outs[0], vec![7.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn allgatherv_rejects_contribution_not_matching_counts() {
        run_ranks(2, |c| {
            // Rank 1 lies about its length.
            let mine = vec![1.0; if c.rank() == 0 { 2 } else { 3 }];
            allgatherv(c, &mine, &[2, 2])
        });
    }

    /// Ragged contributions must be rejected loudly (they used to slip
    /// past everything but a cryptic slice-copy panic, or a silent
    /// release-mode truncation in the allreduce's `add_into`).
    #[test]
    #[should_panic]
    fn allgather_rejects_ragged_contributions() {
        run_ranks(3, |c| {
            let mine = vec![1.0; if c.rank() == 0 { 3 } else { 2 }];
            allgather(c, &mine)
        });
    }

    #[test]
    #[should_panic]
    fn allreduce_rejects_ragged_contributions() {
        run_ranks(2, |c| {
            let mut buf = vec![1.0; if c.rank() == 0 { 3 } else { 2 }];
            allreduce_sum(c, &mut buf, AllreduceAlgo::RecursiveDoubling);
            buf
        });
    }

    #[test]
    fn linear_allreduce_root_rounds_scale_with_p() {
        // The naive algorithm's root does O(P)-ish sequential work — this
        // is what the ablation bench contrasts against.
        let p = 8;
        let stats = run_ranks(p, |c| {
            let mut buf = vec![1.0; 16];
            allreduce_sum(c, &mut buf, AllreduceAlgo::Linear);
            c.stats()
        });
        let root_rounds = stats[0].rounds;
        assert!(root_rounds >= 3, "root should do at least log2(P) rounds");
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in [
            AllreduceAlgo::Rabenseifner,
            AllreduceAlgo::RecursiveDoubling,
            AllreduceAlgo::Linear,
        ] {
            assert_eq!(AllreduceAlgo::parse(a.name()), Some(a));
        }
        assert_eq!(AllreduceAlgo::parse("nope"), None);
    }
}
