//! CLI argument parsing and the `kcd` subcommands (clap is unavailable in
//! the offline build; this is a small, strict flag parser).

#![forbid(unsafe_code)]

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::comm::AllreduceAlgo;
use crate::coordinator::breakdown::breakdown;
use crate::coordinator::report::{breakdown_table, scaling_table, Table};
use crate::coordinator::scaling::{sweep, SweepConfig};
use crate::coordinator::{run_distributed, Config, ProblemSpec, SolverSpec};
use crate::costmodel::MachineProfile;
use crate::data::{paper_dataset, paper_datasets, read_libsvm, Dataset, Task};
use crate::kernelfn::Kernel;
use crate::solvers::{krr_exact, objective::SvmObjective, LocalGram, SvmVariant};

/// Every flag the CLI accepts, with its arity. One table instead of the
/// old "flags that never take a value" list: an unknown flag is a hard
/// error (instead of silently swallowing the next token), and adding a
/// flag means adding one row here — valueless flags can no longer be
/// mis-parsed by omission.
const KNOWN_FLAGS: &[(&str, bool /* takes a value */)] = &[
    ("dataset", true),
    ("scale", true),
    ("kernel", true),
    ("problem", true),
    ("c", true),
    ("lambda", true),
    ("b", true),
    ("h", true),
    ("s", true),
    ("p", true),
    ("p-list", true),
    ("s-list", true),
    ("algo", true),
    ("machine", true),
    ("seed", true),
    ("every", true),
    ("measured-limit", true),
    ("gram-cache-rows", true),
    ("threads", true),
    ("t-list", true),
    ("grid", true),
    ("grid-rows", true),
    ("grid-storage", true),
    ("row-block", true),
    ("overlap", true),
    ("schedule", true),
    ("mem-limit", true),
    ("s-max", true),
    ("t-max", true),
    ("top", true),
    ("config", true),
    ("save", true),
    ("model", true),
    ("requests", true),
    ("batch", true),
    ("profile-out", true),
    ("csv", false),
    ("json", false),
    ("auto-tune", false),
    ("calibrate", false),
    ("quick", false),
    ("force", false),
    ("verbose", false),
];

fn flag_spec(name: &str) -> Option<bool> {
    KNOWN_FLAGS
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, takes_value)| *takes_value)
}

/// Parsed command line: subcommand, `--key value` flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// The subcommand (`train-svm`, `scaling`, …).
    pub command: String,
    flags: BTreeMap<String, String>,
    /// Non-flag arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags are `--key value` or `--key=value`;
    /// boolean flags stand alone. Every flag is validated against the
    /// known-flag table: unknown names and missing values are errors.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.next() {
            out.command = cmd;
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    let takes_value = flag_spec(k)
                        .ok_or_else(|| anyhow!("unknown flag '--{k}'\n\n{USAGE}"))?;
                    if !takes_value && !matches!(v, "true" | "false") {
                        bail!("--{k} is a boolean flag; got '--{k}={v}'");
                    }
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value = flag_spec(name)
                        .ok_or_else(|| anyhow!("unknown flag '--{name}'\n\n{USAGE}"))?;
                    if takes_value {
                        let value = it
                            .next()
                            .filter(|n| !n.starts_with("--"))
                            .ok_or_else(|| anyhow!("--{name} expects a value"))?;
                        out.flags.insert(name.to_string(), value);
                    } else {
                        out.flags.insert(name.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Raw value of `--name`, if present.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// `--name` as a usize; `default` when absent, error when malformed.
    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `--name` as an f64; `default` when absent, error when malformed.
    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// `--name a,b,c` as a usize list; `default` when absent.
    pub fn usize_list_flag(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flag(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .with_context(|| format!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }

    /// True when the boolean flag `--name` was passed.
    pub fn bool_flag(&self, name: &str) -> bool {
        self.flag(name) == Some("true")
    }
}

/// The `kcd help` command reference (also shown on flag errors).
pub const USAGE: &str = "kcd — scalable (s-step) dual coordinate descent for kernel methods

USAGE: kcd <command> [--flags]

COMMANDS:
  train-svm     Train K-SVM with DCD / s-step DCD; report gap + accuracy.
  train-krr     Train K-RR with BDCD / s-step BDCD; report solution error.
  convergence   Duality-gap / relative-error series, classical vs s-step.
  scaling       Strong-scaling sweep over P (measured + projected engines).
  breakdown     Per-phase runtime breakdown as s varies at fixed P.
  tune          Auto-tune (pr, pc, t, s) for a machine profile from the
                cost model; ranked plan with a latency/bandwidth/compute
                split per candidate.
  predict       Score a request stream against a saved .kcd model once.
  serve         Request/response loop over a saved .kcd model: LIBSVM-style
                request lines in (file or stdin), response lines plus a
                latency/throughput report out; batches route through the
                same gram engine (threads + kernel-row cache) as training.
  datasets      List the paper dataset registry.
  artifacts-check  Verify PJRT artifacts load and execute.

COMMON FLAGS:
  --dataset <name|libsvm-path>  Paper registry name or a LIBSVM file.
  --scale <f>       Generate the dataset at a fraction of published size.
  --kernel <k>      linear | poly[:c=..,d=..] | rbf[:sigma=..]  [rbf]
  --problem <p>     svm-l1 | svm-l2 | krr                      [svm-l1]
  --c <f> --lambda <f> --b <n>   Problem parameters.
  --h <n>           Inner iterations                            [256]
  --s <n>           s-step block (1 = classical)                [1]
  --p <n>           Ranks for distributed runs                  [1]
  --p-list / --s-list <a,b,c>    Sweep lists.
  --measured-limit <n>  scaling / breakdown: ranks up to this bound
                    run the measured engine; beyond it, projected  [8]
  --algo <a>        rabenseifner | rd | linear                  [rabenseifner]
  --machine <m>     cray-ex | cloud | profile:<path>            [cray-ex]
  --seed <n>        Coordinate-stream seed.
  --gram-cache-rows <n>  Kernel-row LRU cache capacity (0 = off)  [0]
                    train-svm / train-krr / convergence only; the
                    scaling and breakdown sweeps always run uncached
                    (hit patterns cannot be projected analytically).
  --threads <n>     Intra-rank worker threads for the gram product  [1]
                    (bitwise-identical results for every count;
                    all solver commands, scaling and breakdown).
  --t-list <a,b,c>  scaling only: thread counts for the hybrid
                    P ranks × t threads sweep           [--threads]
  --grid <PRxPC>    train-svm / train-krr: run the 2D process-grid
                    layout (pr×pc must equal --p; the gram reduce then
                    spans pc ranks instead of P, and results are
                    bitwise-identical to the 1D layout over pc ranks).
  --grid-rows <pr>  scaling only: run every sweep point P divisible by
                    pr as a pr×(P/pr) grid (1 = the 1D sweep)   [1]
  --grid-storage <m>  replicated | sharded          [replicated]
                    sharded stores only each cell's block-cyclic row
                    group (≈m/pr × ≈n/pc — per-rank memory finally
                    shrinks with pr) and assembles sampled rows via a
                    per-call fragment exchange; results are
                    bitwise-identical to replicated. train-svm /
                    train-krr / scaling.
  --row-block <n>   Block-cyclic row-block size of the grid layout
                    (bitwise-invariant wall-time/traffic knob; also a
                    tuner candidate axis)     [4]
  --overlap <m>     off | exchange | pipeline               [off]
                    Nonblocking communication/compute overlap:
                    exchange posts the sharded grid's fragment rings
                    under the owned-rows partial product; pipeline
                    posts gram call k+1's reduce under block k's s-step
                    inner updates. Bitwise-identical results; the
                    ledgers split posted vs exposed traffic and the
                    projection credits the hidden fraction. Inert where
                    it has no substrate (serial, s = 1 for pipeline,
                    non-sharded for exchange). train-svm / train-krr /
                    scaling / breakdown; also a tuner candidate axis.
  --schedule <k>    uniform | shuffle | locality             [uniform]
                    Coordinate schedule. uniform replays the legacy
                    seeded sampling bit for bit; shuffle walks seeded
                    Fisher–Yates epoch permutations; locality draws a
                    seeded candidate pool per block and packs greedily
                    for cache overlap and minimal fragment-exchange
                    words. Every kind is bitwise-deterministic for a
                    fixed spec — invariant to threads, cache capacity,
                    row-block, storage and overlap. train-svm /
                    train-krr / convergence / scaling; also a tuner
                    candidate axis.
  --mem-limit <MB>  tune: per-rank memory budget; candidates whose
                    modeled footprint exceeds it rank after every
                    feasible one (marked OVER, never hidden).
  --s-max <n>       tune: bound of the power-of-two s candidate grid
                    (--s-list overrides with an explicit list)  [256]
  --t-max <n>       tune: bound on thread candidates (always also
                    capped at the machine's cores-per-rank)  [cores]
  --top <n>         tune: candidates shown in the ranked report  [10]
  --calibrate       tune: skip planning and instead *measure* this
                    machine — time a deterministic microbench suite
                    (sampled-gram kernels, loopback collectives), fit
                    (alpha, beta, gamma) by least squares against the
                    cost model's own counts, and save the result as a
                    machine profile. --quick shrinks the suite for CI
                    smoke runs (noisier fit).
  --profile-out <file>  tune --calibrate: where the fitted profile is
                    written            [machine-profile.toml]
  --json            tune: emit the machine-readable JSON report.
  --auto-tune       scaling: append the tuner's predicted-best
                    (pr, pc, t, s) row per sweep point.
  --save <file>     train-svm / train-krr: persist the trained model to
                    a versioned binary .kcd file (bitwise-preserving;
                    K-SVM saves keep only the support vectors, and
                    sharded-grid runs reassemble the retained rows from
                    their block-cyclic cells first).
  --model <file>    predict / serve: the .kcd model to score against.
  --requests <file> predict / serve: line-delimited request stream —
                    optional label, then 1-based ascending index:value
                    features ('-' or absent = stdin; blank lines and
                    '#' comments skipped).
  --batch <n>       predict / serve: requests per engine batch; a pure
                    wall-time knob, responses are bitwise-invariant to
                    the split (0 = one batch)   [predict 0, serve 64]
  --csv             Emit CSV instead of markdown tables.
  --config <file>   TOML-subset config (flags override).

--machine accepts per-parameter overrides for your own machine, e.g.
cray-ex:alpha=1e-5,beta=4e-9,gamma=2.5e-10,cores=32 (alpha = seconds
per message, beta = per word, gamma = per flop); malformed or
non-positive values are hard errors naming the key. `profile:<path>`
loads a saved profile file instead — the handoff from
`kcd tune --calibrate`, which measures the coefficients and writes one.

Every value flag may also be given as a config-file key (lists as
`p-list = [1, 2, 4]`); flags override the file. A key that is present
but malformed (e.g. `--h 2.5`, `seed = -1`) is a hard error, never a
silent default.
";

/// Entry point used by `main.rs` (kept in the library for testability).
pub fn run(argv: Vec<String>) -> Result<String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => Ok(USAGE.to_string()),
        "datasets" => cmd_datasets(),
        "train-svm" => cmd_train_svm(&args),
        "train-krr" => cmd_train_krr(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "convergence" => cmd_convergence(&args),
        "scaling" => cmd_scaling(&args),
        "breakdown" => cmd_breakdown(&args),
        "tune" => cmd_tune(&args),
        "artifacts-check" => cmd_artifacts_check(),
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.flag("config") {
        Some(path) => {
            Config::load(std::path::Path::new(path)).map_err(|e| anyhow!("config: {e}"))?
        }
        None => Config::new(),
    };
    // CLI flags override file values under their own names. (List flags
    // — p-list / s-list / t-list — are merged in `list_from` instead:
    // their comma syntax is not a config value.)
    for key in [
        "dataset", "scale", "kernel", "problem", "c", "lambda", "b", "h", "s", "p", "algo",
        "machine", "seed", "gram-cache-rows", "threads", "grid", "grid-rows", "grid-storage",
        "row-block", "overlap", "schedule", "mem-limit", "every", "measured-limit", "s-max",
        "t-max", "top", "save", "model", "requests", "batch", "profile-out",
    ] {
        if let Some(v) = args.flag(key) {
            cfg.set(key, v);
        }
    }
    Ok(cfg)
}

/// Resolve a sweep-list parameter: the `--key a,b,c` flag wins, else a
/// `key = [a, b, c]` config entry (strictly validated), else `default`.
fn list_from(args: &Args, cfg: &Config, key: &str, default: &[usize]) -> Result<Vec<usize>> {
    if args.flag(key).is_some() {
        return args.usize_list_flag(key, default);
    }
    match cfg.try_usize_list(key).map_err(|e| anyhow!(e))? {
        Some(list) => Ok(list),
        None => Ok(default.to_vec()),
    }
}

/// Strictly parse `--grid PRxPC` (e.g. `2x4`) against the launch's rank
/// count `p`: absent → 1D layout (`None`); present-but-malformed or not
/// factoring `p` → a hard error naming the key.
fn grid_from(cfg: &Config, p: usize) -> Result<Option<(usize, usize)>> {
    let Some(raw) = cfg_str(cfg, "grid")? else {
        return Ok(None);
    };
    let parse = |part: &str| -> Result<usize> {
        part.trim().parse::<usize>().map_err(|_| {
            anyhow!("invalid value for 'grid': expected PRxPC (e.g. 2x4), got '{raw}'")
        })
    };
    let (a, b) = raw
        .split_once(|c| c == 'x' || c == 'X')
        .ok_or_else(|| anyhow!("invalid value for 'grid': expected PRxPC (e.g. 2x4), got '{raw}'"))?;
    let (pr, pc) = (parse(a)?, parse(b)?);
    ensure!(
        pr >= 1 && pc >= 1,
        "invalid value for 'grid': grid dimensions must be at least 1, got {pr}x{pc}"
    );
    ensure!(
        pr * pc == p,
        "invalid value for 'grid': {pr}x{pc} needs P = {} ranks, but --p is {p}",
        pr * pc
    );
    Ok(Some((pr, pc)))
}

/// Strictly read the scaling sweep's grid row-group count (`--grid-rows`,
/// default 1 = the 1D sweep).
fn grid_rows_from(cfg: &Config) -> Result<usize> {
    let pr = cfg_usize(cfg, "grid-rows")?.unwrap_or(1);
    ensure!(
        pr >= 1,
        "invalid value for 'grid-rows': need at least one row group"
    );
    Ok(pr)
}

/// Strictly read the grid-cell storage mode (`--grid-storage`,
/// default replicated): `replicated` keeps the full feature shard per
/// cell, `sharded` keeps only the block-cyclic row group and assembles
/// sampled rows through the per-call fragment exchange (bitwise-equal
/// results, smaller memory, extra exchange traffic).
fn grid_storage_from(cfg: &Config) -> Result<crate::gram::GridStorage> {
    let Some(raw) = cfg_str(cfg, "grid-storage")? else {
        return Ok(crate::gram::GridStorage::Replicated);
    };
    crate::gram::GridStorage::parse(raw).ok_or_else(|| {
        anyhow!(
            "invalid value for 'grid-storage': expected replicated or sharded, got '{raw}'"
        )
    })
}

/// Strictly read the communication-overlap mode (`--overlap`, default
/// off). A pure wall-time knob — results are bitwise identical in every
/// mode, and a mode without a substrate on the launch's layout is inert.
fn overlap_from(cfg: &Config) -> Result<crate::gram::OverlapMode> {
    let Some(raw) = cfg_str(cfg, "overlap")? else {
        return Ok(crate::gram::OverlapMode::Off);
    };
    crate::gram::OverlapMode::parse(raw).ok_or_else(|| {
        anyhow!("invalid value for 'overlap': expected off, exchange or pipeline, got '{raw}'")
    })
}

/// Strictly read the coordinate schedule (`--schedule`, default
/// uniform). Every kind is bitwise-deterministic for a fixed spec; only
/// `uniform` replays the pre-schedule sampling stream bit for bit.
fn schedule_from(cfg: &Config) -> Result<crate::schedule::ScheduleSpec> {
    let Some(raw) = cfg_str(cfg, "schedule")? else {
        return Ok(crate::schedule::ScheduleSpec::default());
    };
    let kind = crate::schedule::ScheduleKind::parse(raw).ok_or_else(|| {
        anyhow!("invalid value for 'schedule': expected uniform, shuffle or locality, got '{raw}'")
    })?;
    Ok(crate::schedule::ScheduleSpec::of(kind))
}

/// Strictly read the block-cyclic row-block size (`--row-block`,
/// default `gram::DEFAULT_ROW_BLOCK`). A pure wall-time/traffic knob —
/// results are bitwise identical for every value.
fn row_block_from(cfg: &Config) -> Result<usize> {
    let rb = cfg_usize(cfg, "row-block")?.unwrap_or(crate::gram::DEFAULT_ROW_BLOCK);
    ensure!(
        rb >= 1,
        "invalid value for 'row-block': block size must be at least 1"
    );
    Ok(rb)
}

/// Strictly read the tuner's per-rank memory budget (`--mem-limit`, in
/// decimal megabytes) and convert to f64 words; `None` disables the
/// feasibility filter.
fn mem_limit_from(cfg: &Config) -> Result<Option<u64>> {
    let Some(mb) = cfg_f64(cfg, "mem-limit")? else {
        return Ok(None);
    };
    ensure!(
        mb.is_finite() && mb > 0.0,
        "invalid value for 'mem-limit': expected a positive number of MB, got {mb}"
    );
    Ok(Some((mb * 1e6 / 8.0) as u64))
}

/// Strictly read the intra-rank worker-thread count (default 1).
fn threads_from(cfg: &Config) -> Result<usize> {
    let threads = cfg_usize(cfg, "threads")?.unwrap_or(1);
    ensure!(
        threads >= 1,
        "invalid value for 'threads': need at least one worker thread"
    );
    Ok(threads)
}

// Strict config accessors: a key that is *present but malformed* is a
// hard error naming the key (`Config::try_*`); only a genuinely absent
// key falls back to the default. The lenient `Config::usize`-style
// accessors return `None` for both cases, which used to make
// `--h 2.5` or `seed = -1` silently run the default — contradicting
// the strict-CLI contract.
fn cfg_usize(cfg: &Config, key: &str) -> Result<Option<usize>> {
    cfg.try_usize(key).map_err(|e| anyhow!(e))
}

fn cfg_f64(cfg: &Config, key: &str) -> Result<Option<f64>> {
    cfg.try_f64(key).map_err(|e| anyhow!(e))
}

fn cfg_str<'a>(cfg: &'a Config, key: &str) -> Result<Option<&'a str>> {
    cfg.try_str(key).map_err(|e| anyhow!(e))
}

fn dataset_from(cfg: &Config, default_name: &str, task_hint: Task) -> Result<Dataset> {
    let name = cfg_str(cfg, "dataset")?.unwrap_or(default_name);
    let scale = cfg_f64(cfg, "scale")?.unwrap_or(1.0);
    ensure!(
        scale > 0.0 && scale.is_finite(),
        "invalid value for 'scale': expected a positive fraction, got {scale}"
    );
    if let Some(spec) = paper_dataset(name) {
        return Ok(spec.generate_scaled(scale));
    }
    let path = std::path::Path::new(name);
    if path.exists() {
        return read_libsvm(path, task_hint, None).map_err(|e| anyhow!("libsvm: {e}"));
    }
    bail!(
        "unknown dataset '{name}' (not in registry, not a file). Known: {}",
        paper_datasets()
            .iter()
            .map(|d| d.name)
            .collect::<Vec<_>>()
            .join(", ")
    )
}

fn kernel_from(cfg: &Config) -> Result<Kernel> {
    let s = cfg_str(cfg, "kernel")?.unwrap_or("rbf");
    Kernel::parse(s).ok_or_else(|| anyhow!("bad --kernel '{s}'"))
}

fn machine_from(cfg: &Config) -> Result<MachineProfile> {
    let spec = cfg_str(cfg, "machine")?.unwrap_or("cray-ex");
    MachineProfile::parse(spec).map_err(|e| anyhow!(e))
}

fn algo_from(cfg: &Config) -> Result<AllreduceAlgo> {
    let s = cfg_str(cfg, "algo")?.unwrap_or("rabenseifner");
    AllreduceAlgo::parse(s).ok_or_else(|| anyhow!("bad --algo '{s}'"))
}

fn problem_from(cfg: &Config) -> Result<ProblemSpec> {
    let c = cfg_f64(cfg, "c")?.unwrap_or(1.0);
    let lambda = cfg_f64(cfg, "lambda")?.unwrap_or(1.0);
    let b = cfg_usize(cfg, "b")?.unwrap_or(1);
    match cfg_str(cfg, "problem")?.unwrap_or("svm-l1") {
        "svm-l1" => Ok(ProblemSpec::Svm {
            c,
            variant: SvmVariant::L1,
        }),
        "svm-l2" => Ok(ProblemSpec::Svm {
            c,
            variant: SvmVariant::L2,
        }),
        "krr" => Ok(ProblemSpec::Krr { lambda, b }),
        other => bail!("unknown --problem '{other}'"),
    }
}

fn solver_from(cfg: &Config) -> Result<SolverSpec> {
    let threads = threads_from(cfg)?;
    Ok(SolverSpec {
        s: cfg_usize(cfg, "s")?.unwrap_or(1),
        h: cfg_usize(cfg, "h")?.unwrap_or(256),
        seed: cfg_usize(cfg, "seed")?.unwrap_or(0x5EED) as u64,
        cache_rows: cfg_usize(cfg, "gram-cache-rows")?.unwrap_or(0),
        threads,
        // The grid layout is per-command (it must be validated against
        // the launch's rank count); commands that take --grid overwrite
        // this via `grid_from`.
        grid: None,
        grid_storage: grid_storage_from(cfg)?,
        row_block: row_block_from(cfg)?,
        overlap: overlap_from(cfg)?,
        schedule: schedule_from(cfg)?,
    })
}

fn cmd_datasets() -> Result<String> {
    let mut t = Table::new(vec!["name", "m", "n", "task", "table"]);
    for d in paper_datasets() {
        t.row(vec![
            d.name.to_string(),
            d.m.to_string(),
            d.n.to_string(),
            format!("{:?}", d.task),
            d.table.to_string(),
        ]);
    }
    Ok(t.markdown())
}

fn cmd_train_svm(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let ds = dataset_from(&cfg, "duke", Task::Classification)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    let mut problem = problem_from(&cfg)?;
    if matches!(problem, ProblemSpec::Krr { .. }) {
        problem = ProblemSpec::Svm {
            c: cfg_f64(&cfg, "c")?.unwrap_or(1.0),
            variant: SvmVariant::L1,
        };
    }
    let mut solver = solver_from(&cfg)?;
    let p = cfg_usize(&cfg, "p")?.unwrap_or(1);
    ensure!(p >= 1, "invalid value for 'p': need at least one rank");
    solver.grid = grid_from(&cfg, p)?;
    let algo = algo_from(&cfg)?;
    let res = run_distributed(&ds, kernel, &problem, &solver, p, algo, &machine);
    let (c, variant) = match problem {
        ProblemSpec::Svm { c, variant } => (c, variant),
        _ => unreachable!(),
    };
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let obj = SvmObjective::new(&mut oracle, &ds.y, c, variant);
    let mut out = String::new();
    out.push_str(&format!(
        "dataset={} m={} n={} kernel={} problem={} P={p} layout={} t={} s={} H={} overlap={} \
         schedule={}\n",
        ds.name,
        ds.m(),
        ds.n(),
        kernel.name(),
        problem.name(),
        grid_tag(solver.grid, solver.grid_storage),
        solver.threads,
        solver.s,
        solver.h,
        solver.overlap.name(),
        solver.schedule.kind.name()
    ));
    out.push_str(&format!(
        "duality gap      = {:.6e}\ntrain accuracy   = {:.2}%\n",
        obj.duality_gap(&res.alpha),
        100.0 * obj.train_accuracy(&res.alpha)
    ));
    out.push_str(&format!(
        "projected time   = {:.4e} s on {} (local wall {:.3}s)\n",
        res.projection.total_secs(),
        machine.name,
        res.wall_secs
    ));
    if solver.cache_rows > 0 {
        let cs = res.critical.cache;
        out.push_str(&format!(
            "gram cache       = {} rows: {:.1}% hit rate ({} hits / {} misses), \
             {} allreduce bytes saved\n",
            solver.cache_rows,
            100.0 * cs.hit_rate(),
            cs.hits,
            cs.misses,
            cs.bytes_saved()
        ));
    }
    if let Some(path) = cfg_str(&cfg, "save")? {
        let save_ds = save_dataset(&ds, &solver)?;
        let model = crate::model::SvmModel::from_dual(&save_ds, &res.alpha, kernel);
        model.save_kcd(std::path::Path::new(path))?;
        out.push_str(&format!(
            "model saved      = {path} ({} of {} rows kept as support vectors{})\n",
            model.n_support(),
            ds.m(),
            save_tag(&solver),
        ));
    }
    Ok(out)
}

fn cmd_train_krr(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let ds = dataset_from(&cfg, "bodyfat", Task::Regression)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    let lambda = cfg_f64(&cfg, "lambda")?.unwrap_or(1.0);
    let b = cfg_usize(&cfg, "b")?.unwrap_or(8);
    let problem = ProblemSpec::Krr { lambda, b };
    let mut solver = solver_from(&cfg)?;
    let p = cfg_usize(&cfg, "p")?.unwrap_or(1);
    ensure!(p >= 1, "invalid value for 'p': need at least one rank");
    solver.grid = grid_from(&cfg, p)?;
    let algo = algo_from(&cfg)?;
    let res = run_distributed(&ds, kernel, &problem, &solver, p, algo, &machine);
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let astar = krr_exact(&mut oracle, &ds.y, lambda);
    let rel = crate::dense::rel_err(&res.alpha, &astar);
    let mut out = format!(
        "dataset={} m={} n={} kernel={} b={b} λ={lambda} P={p} layout={} s={} H={} overlap={} \
         schedule={}\n\
         relative solution error = {rel:.6e}\n\
         projected time = {:.4e} s on {} (local wall {:.3}s)\n",
        ds.name,
        ds.m(),
        ds.n(),
        kernel.name(),
        grid_tag(solver.grid, solver.grid_storage),
        solver.s,
        solver.h,
        solver.overlap.name(),
        solver.schedule.kind.name(),
        res.projection.total_secs(),
        machine.name,
        res.wall_secs
    );
    if let Some(path) = cfg_str(&cfg, "save")? {
        let save_ds = save_dataset(&ds, &solver)?;
        let model = crate::model::KrrModel::from_dual(&save_ds, &res.alpha, kernel, lambda);
        model.save_kcd(std::path::Path::new(path))?;
        out.push_str(&format!(
            "model saved = {path} (all {} training rows retained{})\n",
            ds.m(),
            save_tag(&solver),
        ));
    }
    Ok(out)
}

/// The training matrix a `--save` sees: replicated layouts hand back the
/// dataset as-is; a sharded grid run reassembles the matrix from the
/// block-cyclic cell shards each rank actually stores (bitwise-equal to
/// the original — pinned in `serve::format` and
/// `rust/tests/serve_props.rs`), so persistence exercises the same
/// extraction path a real sharded deployment needs.
fn save_dataset(ds: &Dataset, solver: &SolverSpec) -> Result<Dataset> {
    let a = match solver.grid {
        Some((pr, pc))
            if matches!(solver.grid_storage, crate::gram::GridStorage::Sharded) =>
        {
            let cells = crate::serve::format::shard_cells(&ds.a, pr, pc, solver.row_block);
            crate::serve::format::assemble_cells(
                ds.m(),
                ds.n(),
                pr,
                pc,
                solver.row_block,
                &cells,
            )?
        }
        _ => ds.a.clone(),
    };
    Ok(Dataset {
        name: ds.name.clone(),
        a,
        y: ds.y.clone(),
        task: ds.task,
    })
}

/// Suffix for the "model saved" line naming the extraction path.
fn save_tag(solver: &SolverSpec) -> &'static str {
    match solver.grid {
        Some(_) if matches!(solver.grid_storage, crate::gram::GridStorage::Sharded) => {
            ", rows reassembled from sharded grid cells"
        }
        _ => "",
    }
}

/// Report tag for the layout: `1d`, `grid-PRxPC` (replicated cells) or
/// `grid-PRxPC-sharded` (memory-sharded cells).
fn grid_tag(grid: Option<(usize, usize)>, storage: crate::gram::GridStorage) -> String {
    match grid {
        Some((pr, pc)) => match storage {
            crate::gram::GridStorage::Replicated => format!("grid-{pr}x{pc}"),
            crate::gram::GridStorage::Sharded => format!("grid-{pr}x{pc}-sharded"),
        },
        None => "1d".to_string(),
    }
}

/// Strictly read the serving knobs shared by `predict` and `serve`
/// (threads, cache, batch). All three are pure wall-time knobs — the
/// responses are bitwise identical for every combination.
fn predict_opts_from(cfg: &Config, default_batch: usize) -> Result<crate::serve::PredictOptions> {
    Ok(crate::serve::PredictOptions {
        threads: threads_from(cfg)?,
        cache_rows: cfg_usize(cfg, "gram-cache-rows")?.unwrap_or(0),
        batch: cfg_usize(cfg, "batch")?.unwrap_or(default_batch),
    })
}

/// The `--model` path (required for `predict` / `serve`).
fn model_from(cfg: &Config) -> Result<&str> {
    cfg_str(cfg, "model")?
        .ok_or_else(|| anyhow!("invalid value for 'model': pass --model <file.kcd>"))
}

/// Read the request stream: `--requests <file>`, or stdin when the flag
/// is absent or `-` (so `kcd serve` pipes without touching the network).
fn read_requests(cfg: &Config) -> Result<String> {
    match cfg_str(cfg, "requests")? {
        Some(path) if path != "-" => std::fs::read_to_string(path)
            .map_err(|e| anyhow!("invalid value for 'requests': cannot read '{path}': {e}")),
        _ => {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin()
                .read_to_string(&mut buf)
                .map_err(|e| anyhow!("invalid value for 'requests': stdin: {e}"))?;
            Ok(buf)
        }
    }
}

fn cmd_predict(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let path = model_from(&cfg)?;
    let model = crate::serve::LoadedModel::load(std::path::Path::new(path))?;
    let reqs = crate::serve::parse_requests(&read_requests(&cfg)?, model.ncols())?;
    let opts = predict_opts_from(&cfg, 0)?;
    let mut ledger = crate::costmodel::Ledger::new();
    let mut timer = crate::util::PhaseTimer::new();
    let scores = timer.time(|| model.score(&reqs, &opts, &mut ledger));
    let mut out = String::new();
    for s in &scores {
        out.push_str(&model.response_line(*s));
        out.push('\n');
    }
    out.push_str(&format!(
        "scored {} requests ({} unique) against {} model '{path}' in {:.4e} s\n",
        reqs.len(),
        reqs.unique(),
        model.kind().name(),
        timer.secs(),
    ));
    Ok(out)
}

fn cmd_serve(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let path = model_from(&cfg)?;
    let model = crate::serve::LoadedModel::load(std::path::Path::new(path))?;
    let reqs = crate::serve::parse_requests(&read_requests(&cfg)?, model.ncols())?;
    let opts = predict_opts_from(&cfg, 64)?;
    let mut out = format!(
        "serving {} model '{path}': {} retained rows × {} features, {} kernel, \
         batch={}, t={}, cache={}\n",
        model.kind().name(),
        model.nrows(),
        model.ncols(),
        model.kernel().name(),
        opts.batch,
        opts.threads,
        opts.cache_rows,
    );
    // One predictor for the whole loop: the kernel-row cache carries
    // hits across batches, exactly as a long-lived server would.
    let mut predictor = model.predictor(&reqs.queries, &opts);
    let mut ledger = crate::costmodel::Ledger::new();
    let mut timer = crate::util::PhaseTimer::new();
    let step = if opts.batch == 0 {
        reqs.len().max(1)
    } else {
        opts.batch
    };
    for chunk in reqs.stream.chunks(step) {
        let scores = timer.time(|| predictor.predict_indices(chunk, &mut ledger));
        for s in scores {
            out.push_str(&model.response_line(s));
            out.push('\n');
        }
    }
    let report = crate::coordinator::report::ServeReport {
        requests: reqs.len(),
        unique: reqs.unique(),
        batches: timer.count() as usize,
        batch: opts.batch,
        kernel_flops: ledger.total_flops(),
        cache: ledger.cache,
        wall_secs: timer.secs(),
    };
    let t = crate::coordinator::report::serve_table(&report);
    out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
    out.push_str(&format!(
        "engine rate = {:.3} Gflop/s over {} kernel calls ({} rows)\n",
        ledger.flops_per_sec(timer.secs()) / 1e9,
        ledger.kernel_calls,
        ledger.kernel_rows,
    ));
    Ok(out)
}

fn cmd_convergence(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let problem = problem_from(&cfg)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    let solver = solver_from(&cfg)?;
    let every = cfg_usize(&cfg, "every")?.unwrap_or(16);
    ensure!(every >= 1, "invalid value for 'every': must be at least 1");
    let mut out = String::new();
    // Footer shared by both problems: the run-total counters the
    // locality-aware schedule trades against each other, per series —
    // the convergence-vs-traffic ablation reads off these lines (wall
    // profile only; the series table above them stays bitwise-invariant
    // to threads, cache and schedule-inert knobs).
    let ledger_line = |tag: &str, l: &crate::costmodel::Ledger| -> String {
        format!(
            "{tag}: schedule={}, cache hit={:.1}% ({} hits / {} misses), exchange words={}\n",
            solver.schedule.kind.name(),
            100.0 * l.cache.hit_rate(),
            l.cache.hits,
            l.cache.misses,
            l.comm_exch.words,
        )
    };
    match problem {
        ProblemSpec::Svm { c, variant } => {
            let ds = dataset_from(&cfg, "duke", Task::Classification)?;
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let obj = SvmObjective::new(&mut oracle, &ds.y, c, variant);
            let row_cost = crate::schedule::packed_row_costs(&ds.a);
            let series = |s: usize| -> (Vec<(usize, f64)>, crate::costmodel::Ledger) {
                let solver = SolverSpec { s, ..solver };
                let mut pts = Vec::new();
                let mut ledger = crate::costmodel::Ledger::new();
                let mut cb = |k: usize, a: &[f64]| {
                    if k % every == 0 {
                        pts.push((k, obj.duality_gap(a)));
                    }
                };
                let mut o =
                    LocalGram::with_opts(ds.a.clone(), kernel, solver.cache_rows, solver.threads);
                let mut sched = crate::schedule::build_schedule(
                    &solver.schedule,
                    ds.m(),
                    solver.seed,
                    crate::solvers::SVM_COORD_STREAM,
                    &row_cost,
                );
                let params = crate::solvers::SvmParams {
                    c,
                    variant,
                    h: solver.h,
                    seed: solver.seed,
                };
                let _ = match s {
                    1 => crate::solvers::dcd_with_schedule(
                        &mut o,
                        &ds.y,
                        &params,
                        sched.as_mut(),
                        &mut ledger,
                        Some(&mut cb),
                    ),
                    s => crate::solvers::dcd_sstep_with_schedule(
                        &mut o,
                        &ds.y,
                        &params,
                        s,
                        sched.as_mut(),
                        &mut ledger,
                        Some(&mut cb),
                    ),
                };
                (pts, ledger)
            };
            let (classical, classical_ledger) = series(1);
            let (sstep, sstep_ledger) = series(solver.s.max(2));
            let mut t = Table::new(vec!["iter", "gap (classical)", "gap (s-step)", "|Δ|"]);
            for (a, b) in classical.iter().zip(&sstep) {
                t.row(vec![
                    a.0.to_string(),
                    format!("{:.6e}", a.1),
                    format!("{:.6e}", b.1),
                    format!("{:.1e}", (a.1 - b.1).abs()),
                ]);
            }
            out.push_str(&format!(
                "K-SVM-{} duality gap, {} kernel, dataset {} (s = {}, schedule = {})\n",
                match variant {
                    SvmVariant::L1 => "L1",
                    SvmVariant::L2 => "L2",
                },
                kernel.name(),
                ds.name,
                solver.s.max(2),
                solver.schedule.kind.name()
            ));
            out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
            out.push_str(&ledger_line("classical", &classical_ledger));
            out.push_str(&ledger_line("s-step   ", &sstep_ledger));
        }
        ProblemSpec::Krr { lambda, b } => {
            let ds = dataset_from(&cfg, "bodyfat", Task::Regression)?;
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let astar = krr_exact(&mut oracle, &ds.y, lambda);
            let row_cost = crate::schedule::packed_row_costs(&ds.a);
            let series = |s: usize| -> (Vec<(usize, f64)>, crate::costmodel::Ledger) {
                let mut pts = Vec::new();
                let mut ledger = crate::costmodel::Ledger::new();
                let mut cb = |k: usize, a: &[f64]| {
                    if k % every == 0 {
                        pts.push((k, crate::dense::rel_err(a, &astar)));
                    }
                };
                let mut o =
                    LocalGram::with_opts(ds.a.clone(), kernel, solver.cache_rows, solver.threads);
                let mut sched = crate::schedule::build_schedule(
                    &solver.schedule,
                    ds.m(),
                    solver.seed,
                    crate::solvers::KRR_COORD_STREAM,
                    &row_cost,
                );
                let params = crate::solvers::KrrParams {
                    lambda,
                    b,
                    h: solver.h,
                    seed: solver.seed,
                };
                let _ = match s {
                    1 => crate::solvers::bdcd_with_schedule(
                        &mut o,
                        &ds.y,
                        &params,
                        sched.as_mut(),
                        &mut ledger,
                        Some(&mut cb),
                    ),
                    s => crate::solvers::bdcd_sstep_with_schedule(
                        &mut o,
                        &ds.y,
                        &params,
                        s,
                        sched.as_mut(),
                        &mut ledger,
                        Some(&mut cb),
                    ),
                };
                (pts, ledger)
            };
            let (classical, classical_ledger) = series(1);
            let (sstep, sstep_ledger) = series(solver.s.max(2));
            let mut t = Table::new(vec!["iter", "relerr (classical)", "relerr (s-step)", "|Δ|"]);
            for (a, bb) in classical.iter().zip(&sstep) {
                t.row(vec![
                    a.0.to_string(),
                    format!("{:.6e}", a.1),
                    format!("{:.6e}", bb.1),
                    format!("{:.1e}", (a.1 - bb.1).abs()),
                ]);
            }
            out.push_str(&format!(
                "K-RR relative solution error, {} kernel, dataset {} (b = {b}, s = {}, \
                 schedule = {})\n",
                kernel.name(),
                ds.name,
                solver.s.max(2),
                solver.schedule.kind.name()
            ));
            out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
            out.push_str(&ledger_line("classical", &classical_ledger));
            out.push_str(&ledger_line("s-step   ", &sstep_ledger));
        }
    }
    let _ = machine;
    Ok(out)
}

fn cmd_scaling(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let problem = problem_from(&cfg)?;
    let task = match problem {
        ProblemSpec::Svm { .. } => Task::Classification,
        ProblemSpec::Krr { .. } => Task::Regression,
    };
    let ds = dataset_from(&cfg, "colon-cancer", task)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    // --threads sets the single-point thread count; --t-list (flag or
    // config list) widens it into a hybrid sweep axis.
    let threads = threads_from(&cfg)?;
    let t_list = list_from(args, &cfg, "t-list", &[threads])?;
    ensure!(
        t_list.iter().all(|&t| t >= 1),
        "invalid value for 't-list': thread counts must be at least 1"
    );
    let sweep_cfg = SweepConfig {
        p_list: list_from(args, &cfg, "p-list", &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512])?,
        s_list: list_from(args, &cfg, "s-list", &[2, 4, 8, 16, 32, 64, 128, 256])?,
        t_list,
        pr: grid_rows_from(&cfg)?,
        grid_storage: grid_storage_from(&cfg)?,
        row_block: row_block_from(&cfg)?,
        overlap: overlap_from(&cfg)?,
        schedule: schedule_from(&cfg)?,
        h: cfg_usize(&cfg, "h")?.unwrap_or(256),
        seed: cfg_usize(&cfg, "seed")?.unwrap_or(0x5EED) as u64,
        algo: algo_from(&cfg)?,
        measured_limit: cfg_usize(&cfg, "measured-limit")?.unwrap_or(8),
        auto_tune: args.bool_flag("auto-tune"),
    };
    let rows = sweep(&ds, kernel, &problem, &sweep_cfg, &machine);
    let t = scaling_table(&rows);
    let mut out = format!(
        "strong scaling: {} / {} / {} on {} (H = {})\n",
        ds.name,
        problem.name(),
        kernel.name(),
        machine.name,
        sweep_cfg.h
    );
    out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
    Ok(out)
}

fn cmd_breakdown(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    let problem = problem_from(&cfg)?;
    let task = match problem {
        ProblemSpec::Svm { .. } => Task::Classification,
        ProblemSpec::Krr { .. } => Task::Regression,
    };
    let ds = dataset_from(&cfg, "colon-cancer", task)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    let s_list = list_from(args, &cfg, "s-list", &[2, 8, 32, 256])?;
    let p = cfg_usize(&cfg, "p")?.unwrap_or(32);
    let threads = threads_from(&cfg)?;
    let bars = breakdown(
        &ds,
        kernel,
        &problem,
        &s_list,
        cfg_usize(&cfg, "h")?.unwrap_or(256),
        p,
        threads,
        algo_from(&cfg)?,
        &machine,
        cfg_usize(&cfg, "measured-limit")?.unwrap_or(8),
        overlap_from(&cfg)?,
    );
    let t = breakdown_table(&bars);
    let mut out = format!(
        "runtime breakdown: {} / {} / {} at P = {p} on {}\n",
        ds.name,
        problem.name(),
        kernel.name(),
        machine.name
    );
    out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
    Ok(out)
}

fn cmd_tune(args: &Args) -> Result<String> {
    if args.bool_flag("calibrate") {
        return cmd_calibrate(args);
    }
    let cfg = load_config(args)?;
    let problem = problem_from(&cfg)?;
    let task = match problem {
        ProblemSpec::Svm { .. } => Task::Classification,
        ProblemSpec::Krr { .. } => Task::Regression,
    };
    let ds = dataset_from(&cfg, "colon-cancer", task)?;
    let kernel = kernel_from(&cfg)?;
    let machine = machine_from(&cfg)?;
    let p = cfg_usize(&cfg, "p")?.unwrap_or(32);
    ensure!(p >= 1, "invalid value for 'p': need at least one rank");
    let h = cfg_usize(&cfg, "h")?.unwrap_or(256);
    ensure!(h >= 1, "invalid value for 'h': need at least one iteration");
    let s_max = cfg_usize(&cfg, "s-max")?.unwrap_or(256);
    ensure!(s_max >= 1, "invalid value for 's-max': need at least 1");
    let t_max = cfg_usize(&cfg, "t-max")?.unwrap_or(machine.cores_per_rank);
    ensure!(
        t_max >= 1,
        "invalid value for 't-max': need at least one thread"
    );
    let top = cfg_usize(&cfg, "top")?.unwrap_or(10);
    ensure!(top >= 1, "invalid value for 'top': need at least one row");
    let measured_limit = cfg_usize(&cfg, "measured-limit")?.unwrap_or(8);

    let mut req = crate::tune::TuneRequest::new(p, h);
    req.s_max = s_max;
    req.t_max = t_max;
    // Explicit candidate lists (flag or config) override the bounded
    // power-of-two grids.
    req.s_list = list_from(args, &cfg, "s-list", &[])?;
    req.t_list = list_from(args, &cfg, "t-list", &[])?;
    req.algo = algo_from(&cfg)?;
    req.row_block = row_block_from(&cfg)?;
    req.mem_limit_words = mem_limit_from(&cfg)?;
    req.seed = cfg_usize(&cfg, "seed")?.unwrap_or(0x5EED) as u64;

    let plan = crate::tune::tune(&ds, kernel, &problem, &req, &machine);
    let best = plan.best();
    // The trust layer: replay the winner on real ranks and compare
    // traffic word for word — feasible exactly when the measured
    // scaling engine would be (P within the measured budget).
    let xval = (p <= measured_limit).then(|| {
        crate::tune::cross_validate(&ds, kernel, &problem, best, &req, &machine)
    });
    if args.bool_flag("json") {
        return Ok(crate::tune::tune_json(&plan, top, xval.as_ref()));
    }
    // Print the actual coefficients, not just the profile tag: with
    // `--machine name:alpha=..` overrides the base name alone would
    // misattribute the plan to the stock profile.
    let mut out = format!(
        "auto-tune: {} / {} / {} on {} (α={:.1e} s/msg, β={:.1e} s/word, γ={:.1e} s/flop, \
         cores={}) — P={p}, H={h}, algo={} ({} candidates)\n",
        ds.name,
        problem.name(),
        kernel.name(),
        machine.name,
        machine.phi,
        machine.beta,
        machine.gamma,
        machine.cores_per_rank,
        plan.algo.name(),
        plan.candidates.len(),
    );
    let t = crate::tune::tune_table(&plan, top);
    out.push_str(&if args.bool_flag("csv") { t.csv() } else { t.markdown() });
    out.push_str(&format!(
        "best: layout={}, storage={}, rb={}, overlap={}, schedule={}, t={}, s={} → {:.4e} s \
         predicted ({}-bound, {:.2} MB/rank)\n",
        best.layout_tag(),
        best.storage_tag(),
        best.row_block,
        best.overlap.name(),
        best.schedule.kind.name(),
        best.t,
        best.s,
        best.predicted.total_secs(),
        best.predicted.dominant(),
        best.mem_words() as f64 * 8.0 / 1e6,
    ));
    out.push_str(&format!("run it: {}\n", tune_run_line(best, &cfg, &problem, &plan, h)?));
    match xval {
        Some(check) => out.push_str(&format!(
            "cross-validated against measured ranks: {}\n",
            check.summary()
        )),
        None => out.push_str(&format!(
            "(not cross-validated: P={p} exceeds --measured-limit {measured_limit}; \
             predictions rest on the count replicas pinned in `cargo test`)\n"
        )),
    }
    Ok(out)
}

/// `kcd tune --calibrate`: measure this machine's Hockney coefficients
/// and persist them as a profile for `--machine profile:<path>`.
///
/// Division of labor: the wall-clock sampling lives in
/// [`crate::bench_harness::calibrate`] (the detlint-allowlisted timing
/// module); the least-squares fit in [`crate::tune::calibrate`] is pure
/// and unit-tested on planted coefficients. This command strings them
/// together, enforces a loose sanity band, and writes the profile.
fn cmd_calibrate(args: &Args) -> Result<String> {
    let cfg = load_config(args)?;
    // The base contributes the unmeasured shape parameters (mu-scale,
    // blas1 penalty, iteration overhead, cores); `--machine cloud
    // --calibrate` grafts the measurements onto cloud's shape.
    let base = machine_from(&cfg)?;
    let quick = args.bool_flag("quick");
    let path_s = cfg_str(&cfg, "profile-out")?
        .unwrap_or("machine-profile.toml")
        .to_string();

    let obs = crate::bench_harness::calibrate::run_suite(quick);
    let fitted = crate::tune::calibrate::fit(&obs).map_err(|e| anyhow!(e))?;
    let profile = crate::tune::calibrate::apply(&base, &fitted);
    // Loose sanity band: a reference mix of 1e9 flops + 1e6 words +
    // 1e3 rounds must land between 100 ns and an hour. Outside that the
    // fit is garbage (a paused VM, a clock glitch) and is not saved.
    let ref_secs = profile.gamma * 1e9 + profile.beta * 1e6 + profile.phi * 1e3;
    ensure!(
        ref_secs.is_finite() && ref_secs > 1e-7 && ref_secs < 3600.0,
        "calibration failed its sanity band: the fitted profile prices the \
         reference mix (1e9 flops + 1e6 words + 1e3 rounds) at {ref_secs:.3e} s; \
         rerun without --quick, or on a quieter machine"
    );
    profile
        .save(std::path::Path::new(&path_s))
        .map_err(|e| anyhow!(e))?;

    let mut out = format!(
        "calibration: {} observations ({} suite), base shape '{}'\n",
        obs.len(),
        if quick { "quick" } else { "full" },
        base.name,
    );
    out.push_str(&format!(
        "{:<24} {:>11} {:>11} {:>7} {:>11} {:>11}\n",
        "bench", "flops", "words", "rounds", "measured", "fitted"
    ));
    for o in &obs {
        let pred = fitted.gamma * o.flops + fitted.beta * o.words + fitted.alpha * o.rounds;
        out.push_str(&format!(
            "{:<24} {:>11.3e} {:>11.3e} {:>7.1} {:>10.3e}s {:>10.3e}s\n",
            o.name, o.flops, o.words, o.rounds, o.secs, pred
        ));
    }
    out.push_str(&format!(
        "fit: alpha={:.3e} s/msg, beta={:.3e} s/word, gamma={:.3e} s/flop \
         (rms relative residual {:.1}%)\n",
        fitted.alpha,
        fitted.beta,
        fitted.gamma,
        fitted.rel_residual * 100.0
    ));
    out.push_str(&format!("wrote machine profile to {path_s}\n"));
    out.push_str(&format!("use it: kcd tune --machine profile:{path_s}\n"));
    Ok(out)
}

/// The full tune → train handoff line: the candidate's configuration
/// (`Candidate::cli_hint`) plus the data/problem context flags, so
/// running the printed command verbatim trains exactly what was tuned —
/// not the train commands' defaults (which differ from tune's).
fn tune_run_line(
    best: &crate::tune::Candidate,
    cfg: &Config,
    problem: &ProblemSpec,
    plan: &crate::tune::TunedPlan,
    h: usize,
) -> Result<String> {
    let mut line = best.cli_hint(problem, h);
    let dataset = cfg_str(cfg, "dataset")?.unwrap_or("colon-cancer");
    line.push_str(&format!(" --dataset {dataset}"));
    let scale = cfg_f64(cfg, "scale")?.unwrap_or(1.0);
    if scale != 1.0 {
        line.push_str(&format!(" --scale {scale}"));
    }
    if let Some(kernel) = cfg_str(cfg, "kernel")? {
        line.push_str(&format!(" --kernel {kernel}"));
    }
    match *problem {
        ProblemSpec::Svm { c, variant } => {
            if matches!(variant, SvmVariant::L2) {
                line.push_str(" --problem svm-l2");
            }
            if c != 1.0 {
                line.push_str(&format!(" --c {c}"));
            }
        }
        ProblemSpec::Krr { lambda, b } => {
            line.push_str(&format!(" --lambda {lambda} --b {b}"));
        }
    }
    if let Some(machine) = cfg_str(cfg, "machine")? {
        line.push_str(&format!(" --machine {machine}"));
    }
    if plan.algo != AllreduceAlgo::Rabenseifner {
        line.push_str(&format!(" --algo {}", plan.algo.name()));
    }
    Ok(line)
}

fn cmd_artifacts_check() -> Result<String> {
    let dir = crate::runtime::PjrtRuntime::default_dir();
    let mut rt = crate::runtime::PjrtRuntime::open(&dir)
        .with_context(|| format!("opening artifacts at {dir:?} (run `make artifacts`)"))?;
    let n = rt.manifest().artifacts().len();
    // Execute the smallest artifact as a smoke test.
    let spec = rt
        .manifest()
        .artifacts()
        .iter()
        .min_by_key(|a| a.m * a.n * a.k)
        .ok_or_else(|| anyhow!("empty manifest"))?
        .clone();
    let a = vec![0.5f32; spec.m * spec.n];
    let s = vec![0.5f32; spec.k * spec.n];
    let out = rt.execute_gram(&spec.name, &a, &s)?;
    anyhow::ensure!(out.len() == spec.k * spec.m, "bad output size");
    anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite output");
    Ok(format!(
        "artifacts OK: {n} programs in {dir:?}; platform = {}; executed {} → {} values\n",
        rt.platform(),
        spec.name,
        out.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(argv("scaling --dataset duke --s 8 --csv pos1")).unwrap();
        assert_eq!(a.command, "scaling");
        assert_eq!(a.flag("dataset"), Some("duke"));
        assert_eq!(a.usize_flag("s", 1).unwrap(), 8);
        assert!(a.bool_flag("csv"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn parses_equals_syntax_and_lists() {
        let a = Args::parse(argv("x --p-list=1,2,4 --h 32")).unwrap();
        assert_eq!(a.usize_list_flag("p-list", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.usize_flag("h", 0).unwrap(), 32);
    }

    #[test]
    fn rejects_unknown_flags_with_clear_error() {
        let err = Args::parse(argv("scaling --bogus 3")).unwrap_err();
        assert!(format!("{err:#}").contains("unknown flag '--bogus'"));
        let err = Args::parse(argv("scaling --csv=maybe")).unwrap_err();
        assert!(format!("{err:#}").contains("boolean"));
    }

    #[test]
    fn rejects_missing_values() {
        assert!(Args::parse(argv("train-svm --h")).is_err());
        assert!(Args::parse(argv("train-svm --h --csv")).is_err());
    }

    #[test]
    fn gram_cache_rows_flag_parses_through_strict_path() {
        let a = Args::parse(argv("train-svm --gram-cache-rows 64 --csv")).unwrap();
        assert_eq!(a.usize_flag("gram-cache-rows", 0).unwrap(), 64);
        assert!(a.bool_flag("csv"));
    }

    #[test]
    fn train_svm_with_cache_reports_hits_and_same_gap() {
        let base = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 2",
        ))
        .unwrap();
        let cached = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 2 \
             --gram-cache-rows 32",
        ))
        .unwrap();
        assert!(cached.contains("gram cache"), "{cached}");
        assert!(cached.contains("hit rate"), "{cached}");
        // Bit-identical solve ⇒ identical reported duality gap line.
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        assert_eq!(gap(&base), gap(&cached));
    }

    /// Present-but-malformed config values must be hard errors naming
    /// the key — never a silent fallback to the default (the old lenient
    /// accessors made `--h 2.5` run with H = 256).
    #[test]
    fn malformed_values_are_hard_errors_naming_the_key() {
        for (argv_str, key) in [
            ("train-svm --h 2.5", "h"),
            ("train-svm --seed -1", "seed"),
            ("train-svm --s 1.5", "s"),
            ("train-svm --b -3 --problem krr", "b"),
            ("train-svm --gram-cache-rows 0.5", "gram-cache-rows"),
            ("train-svm --threads 2.5", "threads"),
            ("train-krr --lambda notanumber", "lambda"),
            ("train-svm --kernel 5", "kernel"),
            ("train-svm --machine 7", "machine"),
            ("scaling --h -8", "h"),
            ("breakdown --p 2.5", "p"),
        ] {
            let err = run(argv(argv_str)).expect_err(argv_str);
            let msg = format!("{err:#}");
            assert!(
                msg.contains(&format!("'{key}'")),
                "{argv_str}: error must name '{key}', got: {msg}"
            );
        }
        // Zero threads is present-and-invalid, too.
        let err = run(argv("train-svm --threads 0")).unwrap_err();
        assert!(format!("{err:#}").contains("'threads'"));
    }

    #[test]
    fn malformed_config_file_values_are_hard_errors() {
        let dir = std::env::temp_dir().join("kcd_cli_strict");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.toml");
        std::fs::write(&path, "seed = -1\n").unwrap();
        let err = run(vec![
            "train-svm".into(),
            "--config".into(),
            path.to_str().unwrap().into(),
        ])
        .unwrap_err();
        assert!(format!("{err:#}").contains("'seed'"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn threads_flag_runs_and_reports_identical_model() {
        let base = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 2",
        ))
        .unwrap();
        let threaded = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 2 \
             --threads 3",
        ))
        .unwrap();
        assert!(base.contains("t=1"), "{base}");
        assert!(threaded.contains("t=3"), "{threaded}");
        // Bit-identical solve ⇒ identical duality-gap line.
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        assert_eq!(gap(&base), gap(&threaded));
    }

    #[test]
    fn scaling_accepts_t_list_for_hybrid_sweep() {
        let out = run(argv(
            "scaling --dataset colon-cancer --scale 0.3 --h 32 --p-list 2,64 --s-list 4 \
             --t-list 1,4 --measured-limit 2",
        ))
        .unwrap();
        assert!(out.contains("speedup"), "{out}");
        // One row per (P, t) grid point, both engines present.
        assert!(out.contains("measured"), "{out}");
        assert!(out.contains("projected"), "{out}");
        let data_rows = out
            .lines()
            .filter(|l| l.contains("measured") || l.contains("projected"))
            .count();
        assert_eq!(data_rows, 4, "{out}");
        let err = run(argv("scaling --t-list 0,2")).unwrap_err();
        assert!(format!("{err:#}").contains("t-list"));
    }

    /// --grid runs end to end, reports the layout, and — the grid
    /// determinism contract — reproduces the 1D run over pc ranks
    /// bit-for-bit (identical duality-gap line).
    #[test]
    fn grid_flag_runs_and_matches_1d_over_pc_ranks() {
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        let grid = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 4 \
             --grid 2x2",
        ))
        .unwrap();
        assert!(grid.contains("layout=grid-2x2"), "{grid}");
        let one_d = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 2",
        ))
        .unwrap();
        assert!(one_d.contains("layout=1d"), "{one_d}");
        assert_eq!(gap(&grid), gap(&one_d));
        // train-krr takes the flag too.
        let krr = run(argv(
            "train-krr --dataset bodyfat --scale 0.3 --kernel linear --h 60 --b 4 --s 4 \
             --p 4 --grid 4x1",
        ))
        .unwrap();
        assert!(krr.contains("layout=grid-4x1"), "{krr}");
    }

    /// The sharded-storage acceptance at the CLI level: a sharded grid
    /// run reports its storage tag and reproduces the replicated grid
    /// (and therefore the 1D-over-pc) bits exactly.
    #[test]
    fn grid_storage_sharded_runs_and_matches_replicated_bitwise() {
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        let base = "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 4 \
                    --grid 2x2";
        let replicated = run(argv(base)).unwrap();
        assert!(replicated.contains("layout=grid-2x2"), "{replicated}");
        let sharded = run(argv(&format!("{base} --grid-storage sharded"))).unwrap();
        assert!(sharded.contains("layout=grid-2x2-sharded"), "{sharded}");
        assert_eq!(gap(&replicated), gap(&sharded));
        // Explicit replicated is accepted and identical in output shape.
        let explicit = run(argv(&format!("{base} --grid-storage replicated"))).unwrap();
        assert_eq!(gap(&explicit), gap(&replicated));
        // row-block is bitwise-invariant through the CLI too.
        let rb = run(argv(&format!("{base} --grid-storage sharded --row-block 2"))).unwrap();
        assert_eq!(gap(&rb), gap(&replicated));
    }

    /// The overlap acceptance at the CLI level: every mode reports its
    /// tag and reproduces the blocking run's bits exactly (identical
    /// duality-gap line) on both the 1D pipeline substrate and the
    /// sharded-grid exchange substrate.
    #[test]
    fn overlap_modes_run_and_match_blocking_bitwise() {
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        let base = "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 4";
        let off = run(argv(base)).unwrap();
        assert!(off.contains("overlap=off"), "{off}");
        let pipe = run(argv(&format!("{base} --overlap pipeline"))).unwrap();
        assert!(pipe.contains("overlap=pipeline"), "{pipe}");
        assert_eq!(gap(&off), gap(&pipe));
        let sharded = format!("{base} --grid 2x2 --grid-storage sharded");
        let exch = run(argv(&format!("{sharded} --overlap exchange"))).unwrap();
        assert!(exch.contains("overlap=exchange"), "{exch}");
        assert_eq!(gap(&off), gap(&exch));
        // Inert substrate (replicated 1D has no fragment exchange) is
        // accepted and still bitwise-identical, not an error.
        let inert = run(argv(&format!("{base} --overlap exchange"))).unwrap();
        assert_eq!(gap(&off), gap(&inert));
    }

    #[test]
    fn grid_storage_row_block_and_mem_limit_are_strictly_validated() {
        for (bad, key) in [
            ("train-svm --p 4 --grid 2x2 --grid-storage full", "grid-storage"),
            ("train-svm --p 4 --grid 2x2 --grid-storage 1", "grid-storage"),
            ("train-svm --p 4 --grid 2x2 --row-block 0", "row-block"),
            ("train-svm --row-block 2.5", "row-block"),
            ("tune --mem-limit 0", "mem-limit"),
            ("tune --mem-limit -3", "mem-limit"),
            ("tune --mem-limit big", "mem-limit"),
            ("scaling --grid-rows 2 --grid-storage shardd", "grid-storage"),
            ("train-svm --p 2 --overlap sometimes", "overlap"),
            ("scaling --overlap 1", "overlap"),
            ("breakdown --overlap pipelined2", "overlap"),
            ("train-svm --p 2 --schedule random", "schedule"),
            ("scaling --schedule 1", "schedule"),
            ("convergence --schedule greedy", "schedule"),
        ] {
            let err = run(argv(bad)).expect_err(bad);
            let msg = format!("{err:#}");
            assert!(msg.contains(&format!("'{key}'")), "{bad}: {msg}");
        }
    }

    #[test]
    fn scaling_grid_storage_adds_storage_column() {
        let out = run(argv(
            "scaling --dataset colon-cancer --scale 0.3 --h 32 --p-list 4 --s-list 4 \
             --grid-rows 2 --grid-storage sharded --measured-limit 4",
        ))
        .unwrap();
        assert!(out.contains("storage"), "{out}");
        assert!(out.contains("sharded"), "{out}");
        assert!(out.contains("mem (MB)"), "{out}");
    }

    #[test]
    fn tune_mem_limit_filters_and_reports_fit() {
        let out = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 4 --h 16 --s-list 4 --t-list 1 \
             --mem-limit 0.001 --top 100",
        ))
        .unwrap();
        // A 1 KB budget cannot fit these shards: the fit column flags it.
        assert!(out.contains("OVER"), "{out}");
        assert!(out.contains("mem (MB)"), "{out}");
        assert!(out.contains("storage"), "{out}");
    }

    #[test]
    fn grid_flag_is_strictly_validated() {
        for bad in [
            "train-svm --p 4 --grid 3x2",  // does not factor P
            "train-svm --p 4 --grid 2",    // missing separator
            "train-svm --p 4 --grid ax2",  // not a number
            "train-svm --p 4 --grid 0x4",  // zero dimension
            "scaling --grid-rows 0",       // zero row groups
        ] {
            let err = run(argv(bad)).expect_err(bad);
            let msg = format!("{err:#}");
            assert!(
                msg.contains("'grid'") || msg.contains("'grid-rows'"),
                "{bad}: {msg}"
            );
        }
    }

    #[test]
    fn scaling_grid_rows_adds_grid_column() {
        let out = run(argv(
            "scaling --dataset colon-cancer --scale 0.3 --h 32 --p-list 4,6,64 --s-list 4 \
             --grid-rows 2 --measured-limit 4",
        ))
        .unwrap();
        assert!(out.contains("grid"), "{out}");
        assert!(out.contains("2x2"), "{out}");
        assert!(out.contains("2x3"), "{out}");
        assert!(out.contains("2x32"), "{out}");
    }

    #[test]
    fn config_file_drives_sweep_lists() {
        let dir = std::env::temp_dir().join("kcd_cli_lists");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.toml");
        std::fs::write(
            &path,
            "dataset = \"colon-cancer\"\nscale = 0.3\nh = 32\nmeasured-limit = 2\n\
             p-list = [2]\ns-list = [4]\nt-list = [1, 2]\n",
        )
        .unwrap();
        let out = run(vec![
            "scaling".into(),
            "--config".into(),
            path.to_str().unwrap().into(),
        ])
        .unwrap();
        // One measured row per t in the config list.
        let data_rows = out.lines().filter(|l| l.contains("measured")).count();
        assert_eq!(data_rows, 2, "{out}");
        // Malformed list entries are hard errors naming the key.
        std::fs::write(&path, "t-list = [1, 2.5]\n").unwrap();
        let err = run(vec![
            "scaling".into(),
            "--config".into(),
            path.to_str().unwrap().into(),
        ])
        .unwrap_err();
        assert!(format!("{err:#}").contains("'t-list'"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    /// tune runs end to end: ranked table, handoff command line, and —
    /// at P within the measured budget — a bitwise traffic
    /// cross-validation of the winner against real ranks.
    #[test]
    fn tune_produces_ranked_plan_and_cross_validates() {
        let out = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 8 --h 32 --s-max 8 --t-max 4 --top 5",
        ))
        .unwrap();
        assert!(out.contains("auto-tune:"), "{out}");
        assert!(out.contains("compute (s)"), "{out}");
        assert!(out.contains("best: layout="), "{out}");
        assert!(out.contains("run it: kcd train-svm --p 8"), "{out}");
        // The handoff line must carry the data context, so running it
        // verbatim trains what was tuned (train-svm's default dataset
        // differs from tune's).
        assert!(out.contains("--dataset diabetes"), "{out}");
        assert!(out.contains("--scale 0.1"), "{out}");
        // The header shows the machine coefficients, not just the tag.
        assert!(out.contains("s/msg"), "{out}");
        assert!(out.contains("traffic exact"), "{out}");
        // Past the measured budget the report says so instead.
        let far = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 64 --h 32 --s-max 8 --t-max 2",
        ))
        .unwrap();
        assert!(far.contains("not cross-validated"), "{far}");
    }

    #[test]
    fn tune_json_is_machine_readable() {
        let out = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 16 --h 32 --s-max 4 --t-max 2 --json",
        ))
        .unwrap();
        assert!(out.starts_with('{') && out.trim_end().ends_with('}'), "{out}");
        assert!(out.contains("\"candidates\":["), "{out}");
        assert!(out.contains("\"latency_secs\":"), "{out}");
        // P = 16 exceeds the default measured limit: no cross-validation.
        assert!(!out.contains("cross_validation"), "{out}");
        let near = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 4 --h 16 --s-max 4 --t-max 2 --json",
        ))
        .unwrap();
        assert!(near.contains("\"cross_validation\""), "{near}");
        assert!(near.contains("\"traffic_exact\":true"), "{near}");
    }

    #[test]
    fn tune_flags_are_strictly_validated() {
        for (argv_str, key) in [
            ("tune --s-max 0", "s-max"),
            ("tune --s-max 2.5", "s-max"),
            ("tune --t-max 0", "t-max"),
            ("tune --top 0", "top"),
            ("tune --p 0", "p"),
            ("tune --h 0", "h"),
            ("tune --machine cray-ex:alpha=-1", "machine.alpha"),
            ("tune --machine cray-ex:beta=slow", "machine.beta"),
            ("tune --machine cray-ex:gamma=0", "machine.gamma"),
            ("tune --machine cray-ex:cores=0", "machine.cores"),
            ("tune --machine laptop", "machine"),
        ] {
            let err = run(argv(argv_str)).expect_err(argv_str);
            let msg = format!("{err:#}");
            assert!(
                msg.contains(&format!("'{key}'")),
                "{argv_str}: error must name '{key}', got: {msg}"
            );
        }
    }

    #[test]
    fn tune_accepts_machine_overrides_and_explicit_lists() {
        let out = run(argv(
            "tune --dataset diabetes --scale 0.1 --p 8 --h 32 --s-list 2,8 --t-list 1,2 \
             --machine cray-ex:alpha=5e-3,cores=4",
        ))
        .unwrap();
        // The overridden coefficient is visible in the header (the tag
        // alone would misattribute the plan to the stock profile).
        assert!(out.contains("α=5.0e-3"), "{out}");
        // 1D: s {1, 2, 8} × t {1, 2} = 6, plus a pipelined twin for
        // each s > 1 point = 10. Grids (2,4)/(4,2): 3 row-block ×
        // (replicated s-ledgers {1, 2, 2} + sharded {2, 3, 3} counting
        // overlap variants, doubled by the uniform/locality schedule
        // axis → {4, 6, 6}) × 2 t = 126 each. Grid (8,1) has no column
        // peers, so pipeline is infeasible: 3 × (3 + 6 × 2) × 2 = 90.
        assert!(out.contains("(352 candidates)"), "{out}");
        // And the handoff line reproduces the override spec.
        assert!(out.contains("--machine cray-ex:alpha=5e-3,cores=4"), "{out}");
    }

    /// End-to-end `tune --calibrate --quick` through the library entry:
    /// the suite runs, and the fit either succeeds — then the written
    /// profile must load back through `--machine profile:<path>` with
    /// positive finite coefficients — or fails with the calibration
    /// error naming its cause (legal on a noisy builder: the quick
    /// suite is deliberately small; CI's calibrate-smoke step enforces
    /// success on a quiet runner). A wiring bug surfaces as any *other*
    /// error and still fails the test.
    #[test]
    fn tune_calibrate_quick_end_to_end() {
        let path = std::env::temp_dir().join("kcd_cli_calibrate_profile.toml");
        std::fs::remove_file(&path).ok();
        match run(argv(&format!(
            "tune --calibrate --quick --profile-out {}",
            path.display()
        ))) {
            Ok(out) => {
                assert!(out.contains("wrote machine profile"), "{out}");
                assert!(out.contains("use it: kcd tune --machine profile:"), "{out}");
                let p =
                    MachineProfile::parse(&format!("profile:{}", path.display())).unwrap();
                assert_eq!(p.name, "calibrated");
                for v in [p.gamma, p.beta, p.phi] {
                    assert!(v.is_finite() && v > 0.0, "bad coefficient {v:e}");
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("calibration"), "unexpected error: {msg}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scaling_auto_tune_appends_tuned_row() {
        let base = "scaling --dataset colon-cancer --scale 0.3 --h 16 --p-list 4 --s-list 4 \
                    --measured-limit 4";
        let plain = run(argv(base)).unwrap();
        assert!(!plain.contains("auto"), "{plain}");
        let tuned = run(argv(&format!("{base} --auto-tune"))).unwrap();
        assert!(tuned.contains("tuned"), "{tuned}");
        assert!(tuned.contains("auto"), "{tuned}");
        let data_rows = tuned.lines().filter(|l| l.contains("measured")).count();
        assert_eq!(data_rows, 2, "{tuned}");
    }

    #[test]
    fn convergence_honors_threads_and_cache() {
        let base = run(argv(
            "convergence --dataset diabetes --scale 0.08 --problem svm-l1 --h 64 --s 8 --every 16",
        ))
        .unwrap();
        let threaded = run(argv(
            "convergence --dataset diabetes --scale 0.08 --problem svm-l1 --h 64 --s 8 \
             --every 16 --threads 3 --gram-cache-rows 16",
        ))
        .unwrap();
        // Threads + cache are bitwise-transparent: identical series
        // tables (the footer deliberately reports the wall profile —
        // cache hit rate — and is the one part allowed to differ).
        let table = |out: &str| {
            out.lines()
                .filter(|l| l.starts_with('|'))
                .map(String::from)
                .collect::<Vec<_>>()
        };
        assert_eq!(table(&base), table(&threaded));
        // The cached run's ablation footer shows real hits; the
        // uncached one reports a 0.0% rate.
        assert!(base.contains("cache hit=0.0%"), "{base}");
        assert!(threaded.contains("hits"), "{threaded}");
        assert!(!threaded.contains("cache hit=0.0%"), "{threaded}");
    }

    /// The schedule axis at the CLI level: the default is the uniform
    /// replay (bitwise-identical output to not passing the flag at
    /// all), every kind reports its tag, and non-uniform kinds draw a
    /// genuinely different coordinate stream (different gap trace) while
    /// staying bitwise-invariant to threads and cache capacity.
    #[test]
    fn schedule_flag_runs_and_uniform_is_the_default_stream() {
        let base = "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 2";
        let default_run = run(argv(base)).unwrap();
        assert!(default_run.contains("schedule=uniform"), "{default_run}");
        let uniform = run(argv(&format!("{base} --schedule uniform"))).unwrap();
        assert_eq!(default_run, uniform, "explicit uniform must replay the default bits");
        let gap = |out: &str| {
            out.lines()
                .find(|l| l.starts_with("duality gap"))
                .unwrap()
                .to_string()
        };
        for kind in ["shuffle", "locality"] {
            let out = run(argv(&format!("{base} --schedule {kind}"))).unwrap();
            assert!(out.contains(&format!("schedule={kind}")), "{out}");
            // A different schedule is a different solve path — but
            // threads/cache stay bitwise-transparent under it.
            let threaded = run(argv(&format!(
                "{base} --schedule {kind} --threads 3 --gram-cache-rows 16"
            )))
            .unwrap();
            assert_eq!(gap(&out), gap(&threaded), "{kind}");
        }
        // convergence takes the flag too and reports it in the header.
        let conv = run(argv(
            "convergence --dataset diabetes --scale 0.08 --problem svm-l1 --h 64 --s 8 \
             --every 16 --schedule locality --gram-cache-rows 16",
        ))
        .unwrap();
        assert!(conv.contains("schedule = locality"), "{conv}");
        assert!(conv.contains("exchange words="), "{conv}");
    }

    /// Extract every `--flag` name mentioned in `text` as an exact token:
    /// leading punctuation (backticks, brackets, parens) is stripped so
    /// table cells like `` `--grid <PRxPC>` `` count, and the name ends at
    /// the first non-flag character — `--p` inside `--p-list` is NOT a
    /// mention of `--p`.
    fn mentioned_flags(text: &str) -> std::collections::BTreeSet<String> {
        let mut out = std::collections::BTreeSet::new();
        for raw in text.split_whitespace() {
            let token = raw.trim_start_matches(|c: char| "`[(\"'*|".contains(c));
            let Some(name) = token.strip_prefix("--") else {
                continue;
            };
            let name: String = name
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '-')
                .collect();
            let name = name.trim_end_matches('-');
            if !name.is_empty() {
                out.insert(name.to_string());
            }
        }
        out
    }

    /// docs/CLI.md and the parser's flag table must agree *exactly*, in
    /// both directions, on whole flag names (substring matching would let
    /// `--p` ride on `--p-list` and backticked mentions go unchecked) —
    /// so the reference cannot silently rot. The in-binary usage text is
    /// held to the forward direction for every flag it is expected to
    /// carry.
    #[test]
    fn every_known_flag_is_documented() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/CLI.md");
        let doc = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("docs/CLI.md must exist next to the crate: {e}"));
        let documented = mentioned_flags(&doc);
        let usage_flags = mentioned_flags(USAGE);
        for (name, _) in KNOWN_FLAGS {
            assert!(
                documented.contains(*name),
                "docs/CLI.md is missing flag --{name}"
            );
            assert!(
                usage_flags.contains(*name)
                    || matches!(*name, "force" | "verbose" | "quick" | "every"),
                "usage text is missing flag --{name}"
            );
        }
        for name in &documented {
            assert!(
                flag_spec(name).is_some(),
                "docs/CLI.md documents unknown flag --{name}"
            );
        }
    }

    /// The tentpole acceptance: `train-svm --save` persists a .kcd
    /// model, `predict` scores it, and a sharded-grid save of the same
    /// problem (rows reassembled from its cells) serves identical bits.
    #[test]
    fn train_save_then_predict_end_to_end() {
        let dir = std::env::temp_dir().join("kcd_cli_serve_e2e");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("svm.kcd");
        let reqf = dir.join("req.txt");
        std::fs::write(&reqf, "1:0.5 3:-0.25\n2:1.0\n# comment\n\n1:0.5 3:-0.25\n").unwrap();
        let out = run(argv(&format!(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 2 \
             --save {}",
            model.display()
        )))
        .unwrap();
        assert!(out.contains("model saved"), "{out}");
        assert!(out.contains("support vectors"), "{out}");
        let pred = run(argv(&format!(
            "predict --model {} --requests {}",
            model.display(),
            reqf.display()
        )))
        .unwrap();
        assert!(pred.contains("scored 3 requests (2 unique)"), "{pred}");
        let labels: Vec<&str> = pred.lines().take(3).collect();
        assert!(
            labels.iter().all(|l| l.starts_with("+1 ") || l.starts_with("-1 ")),
            "{pred}"
        );
        // Duplicate request lines score bitwise-identically.
        assert_eq!(labels[0], labels[2], "{pred}");

        // Grid 2x2 over P = 4 matches the 1D run over pc = 2 ranks
        // bitwise, so the sharded-extraction save must serve the same
        // responses as the replicated one.
        let sharded = dir.join("svm_sharded.kcd");
        let out2 = run(argv(&format!(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 4 \
             --grid 2x2 --grid-storage sharded --save {}",
            sharded.display()
        )))
        .unwrap();
        assert!(out2.contains("reassembled from sharded grid cells"), "{out2}");
        let pred2 = run(argv(&format!(
            "predict --model {} --requests {}",
            sharded.display(),
            reqf.display()
        )))
        .unwrap();
        assert_eq!(
            labels,
            pred2.lines().take(3).collect::<Vec<_>>(),
            "sharded save must serve identical bits\n{pred}\n{pred2}"
        );
    }

    #[test]
    fn train_krr_save_then_predict() {
        let dir = std::env::temp_dir().join("kcd_cli_serve_krr");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("krr.kcd");
        let reqf = dir.join("req.txt");
        std::fs::write(&reqf, "1:0.5 2:0.25\n3:1.0\n").unwrap();
        let out = run(argv(&format!(
            "train-krr --dataset bodyfat --scale 0.3 --kernel linear --h 60 --b 4 --s 4 \
             --save {}",
            model.display()
        )))
        .unwrap();
        assert!(out.contains("model saved"), "{out}");
        assert!(out.contains("training rows retained"), "{out}");
        let pred = run(argv(&format!(
            "predict --model {} --requests {}",
            model.display(),
            reqf.display()
        )))
        .unwrap();
        assert!(
            pred.contains("scored 2 requests (2 unique) against krr model"),
            "{pred}"
        );
        // K-RR responses are bare predicted targets, no ±1 label.
        let first = pred.lines().next().unwrap();
        assert!(first.parse::<f64>().is_ok(), "{pred}");
    }

    /// `kcd serve` drains the request loop through one predictor (the
    /// cache carries across batches), reports the latency/throughput
    /// table, and its responses are bitwise-invariant to the batch
    /// split, the thread count and the cache.
    #[test]
    fn serve_reports_latency_table_and_is_batch_invariant() {
        let dir = std::env::temp_dir().join("kcd_cli_serve_loop");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("svm.kcd");
        run(argv(&format!(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 2 \
             --save {}",
            model.display()
        )))
        .unwrap();
        let reqf = dir.join("req.txt");
        std::fs::write(&reqf, "1:0.5\n2:1.0\n1:0.5\n3:-1.5\n2:1.0\n1:0.5\n").unwrap();
        let a = run(argv(&format!(
            "serve --model {} --requests {} --batch 2 --gram-cache-rows 8",
            model.display(),
            reqf.display()
        )))
        .unwrap();
        assert!(a.contains("serving svm model"), "{a}");
        assert!(a.contains("req/s"), "{a}");
        assert!(a.contains("engine rate"), "{a}");
        let lines = |out: &str| {
            out.lines()
                .filter(|l| l.starts_with("+1 ") || l.starts_with("-1 "))
                .map(String::from)
                .collect::<Vec<_>>()
        };
        let la = lines(&a);
        assert_eq!(la.len(), 6, "{a}");
        // Repeats are bitwise copies (served from the cache).
        assert_eq!(la[0], la[2]);
        assert_eq!(la[2], la[5]);
        assert_eq!(la[1], la[4]);
        // Batch split, threads and cache are invisible in the bits.
        let b = run(argv(&format!(
            "serve --model {} --requests {} --batch 4 --threads 3",
            model.display(),
            reqf.display()
        )))
        .unwrap();
        assert_eq!(la, lines(&b));
        // CSV mode renders the same counters.
        let c = run(argv(&format!(
            "serve --model {} --requests {} --csv",
            model.display(),
            reqf.display()
        )))
        .unwrap();
        assert!(c.contains("requests,unique"), "{c}");
    }

    #[test]
    fn predict_and_serve_flags_are_strictly_validated() {
        // Missing --model names the key (both commands).
        for cmd in ["predict", "serve"] {
            let err = run(argv(cmd)).unwrap_err();
            assert!(format!("{err:#}").contains("'model'"), "{cmd}: {err:#}");
        }
        let dir = std::env::temp_dir().join("kcd_cli_serve_strict");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("svm.kcd");
        run(argv(&format!(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 120 --s 8 --p 2 \
             --save {}",
            model.display()
        )))
        .unwrap();
        let reqf = dir.join("req.txt");
        std::fs::write(&reqf, "1:0.5\n").unwrap();
        let err = run(argv(&format!(
            "predict --model {} --requests {} --batch 2.5",
            model.display(),
            reqf.display()
        )))
        .unwrap_err();
        assert!(format!("{err:#}").contains("'batch'"), "{err:#}");
        let err = run(argv(&format!(
            "predict --model {} --requests {}/does-not-exist",
            model.display(),
            dir.display()
        )))
        .unwrap_err();
        assert!(format!("{err:#}").contains("'requests'"), "{err:#}");
        // A malformed request line names its line number.
        let bad = dir.join("bad_req.txt");
        std::fs::write(&bad, "1:0.5\n0:1\n").unwrap();
        let err = run(argv(&format!(
            "predict --model {} --requests {}",
            model.display(),
            bad.display()
        )))
        .unwrap_err();
        assert!(format!("{err:#}").contains("request line 2"), "{err:#}");
        // A truncated model file is a named hard error, never garbage.
        let bytes = std::fs::read(&model).unwrap();
        let trunc = dir.join("trunc.kcd");
        std::fs::write(&trunc, &bytes[..bytes.len() - 5]).unwrap();
        let err = run(argv(&format!(
            "predict --model {} --requests {}",
            trunc.display(),
            reqf.display()
        )))
        .unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn help_and_unknown() {
        assert!(run(argv("help")).unwrap().contains("USAGE"));
        assert!(run(argv("bogus")).is_err());
    }

    #[test]
    fn datasets_lists_registry() {
        let out = run(argv("datasets")).unwrap();
        assert!(out.contains("duke"));
        assert!(out.contains("news20"));
    }

    #[test]
    fn train_svm_small_end_to_end() {
        let out = run(argv(
            "train-svm --dataset diabetes --scale 0.1 --kernel rbf --h 200 --s 8 --p 2",
        ))
        .unwrap();
        assert!(out.contains("duality gap"), "{out}");
        assert!(out.contains("train accuracy"));
    }

    #[test]
    fn train_krr_small_end_to_end() {
        let out = run(argv(
            "train-krr --dataset bodyfat --scale 0.3 --kernel linear --h 300 --b 4 --s 4",
        ))
        .unwrap();
        assert!(out.contains("relative solution error"), "{out}");
    }

    #[test]
    fn convergence_table_shows_overlay() {
        let out = run(argv(
            "convergence --dataset diabetes --scale 0.08 --problem svm-l1 --h 64 --s 8 --every 16",
        ))
        .unwrap();
        assert!(out.contains("gap (classical)"), "{out}");
    }

    #[test]
    fn scaling_produces_rows() {
        let out = run(argv(
            "scaling --dataset colon-cancer --scale 0.3 --h 32 --p-list 1,4,64 --s-list 4,16 --measured-limit 4",
        ))
        .unwrap();
        assert!(out.contains("speedup"), "{out}");
        assert!(out.contains("projected"));
    }

    #[test]
    fn breakdown_produces_bars() {
        let out = run(argv(
            "breakdown --dataset colon-cancer --scale 0.3 --h 32 --s-list 4,16 --p 16 --measured-limit 0",
        ))
        .unwrap();
        assert!(out.contains("classical"), "{out}");
        assert!(out.contains("allreduce"));
    }
}
