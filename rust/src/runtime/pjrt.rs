//! The XLA-backed PJRT runtime (compiled with the `xla-pjrt` feature).
//!
//! [`PjrtGram`] is a gram-engine configuration: an XLA-executing product
//! stage ([`PjrtProduct`], emitting finished kernel values) → no
//! reduction → optional row cache.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::costmodel::Ledger;
use crate::dense::Mat;
use crate::gram::{BlockKind, GramEngine, GramOracle, Layout, NoReduce, ProductCost, ProductStage};
use crate::kernelfn::Kernel;

use super::manifest::{ArtifactSpec, Manifest};

/// A PJRT CPU client plus the compiled artifact cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Open the artifact directory (reads `manifest.json`; compiles
    /// lazily).
    pub fn open(dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// The default artifact directory (`$KCD_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Upload a host f32 array to the device once; the returned buffer
    /// can be reused across `execute_gram_buf` calls (the §Perf
    /// optimization that keeps `A` device-resident instead of shipping
    /// it on every iteration).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Execute the gram artifact with a device-resident `a` buffer and a
    /// host-side sampled block `s` (uploaded per call — it is small).
    pub fn execute_gram_buf(
        &mut self,
        name: &str,
        a_buf: &xla::PjRtBuffer,
        s: &[f32],
    ) -> Result<Vec<f32>> {
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        anyhow::ensure!(
            s.len() == spec.k * spec.n,
            "s: expected {}x{} f32s, got {}",
            spec.k,
            spec.n,
            s.len()
        );
        let s_buf = self.upload_f32(s, &[spec.k, spec.n])?;
        let exe = self.ensure_compiled(&spec.name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&[a_buf, &s_buf])
            .map_err(|e| anyhow!("execute_b {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute the gram artifact `name` on `(a, s)` (f32, row-major),
    /// returning the `(k, m)` block as a flat row-major `Vec<f32>`.
    pub fn execute_gram(&mut self, name: &str, a: &[f32], s: &[f32]) -> Result<Vec<f32>> {
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        anyhow::ensure!(
            a.len() == spec.m * spec.n,
            "a: expected {}x{} = {} f32s, got {}",
            spec.m,
            spec.n,
            spec.m * spec.n,
            a.len()
        );
        anyhow::ensure!(
            s.len() == spec.k * spec.n,
            "s: expected {}x{} f32s, got {}",
            spec.k,
            spec.n,
            s.len()
        );
        let exe = self.ensure_compiled(name)?;
        let a_lit = xla::Literal::vec1(a)
            .reshape(&[spec.m as i64, spec.n as i64])
            .map_err(|e| anyhow!("reshape a: {e:?}"))?;
        let s_lit = xla::Literal::vec1(s)
            .reshape(&[spec.k as i64, spec.n as i64])
            .map_err(|e| anyhow!("reshape s: {e:?}"))?;
        let result = exe
            .execute::<xla::Literal>(&[a_lit, s_lit])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // L2 lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Pick the smallest lowered artifact that fits `(kind, m, n, k)` —
    /// the sampled dimension is padded up to the next lowered `k`.
    pub fn select_artifact(&self, kind: &str, m: usize, n: usize, k: usize) -> Option<&ArtifactSpec> {
        self.manifest
            .artifacts()
            .iter()
            .filter(|a| a.kind == kind && a.m == m && a.n == n && a.k >= k)
            .min_by_key(|a| a.k)
    }
}

/// Product stage that executes the lowered XLA gram artifact. Emits
/// finished kernel values (the artifact applies the kernel map on
/// device), so the engine skips the epilogue. Numerics are f32
/// (documented in DESIGN.md §5); the native f64 path remains the
/// correctness reference.
struct PjrtProduct {
    runtime: PjrtRuntime,
    kernel: Kernel,
    a: Vec<f32>,
    /// Device-resident copy of `a`, uploaded once (§Perf).
    a_buf: xla::PjRtBuffer,
    m: usize,
    n: usize,
}

impl ProductStage for PjrtProduct {
    fn m(&self) -> usize {
        self.m
    }

    fn kind(&self) -> BlockKind {
        BlockKind::Kernel
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        let spec = self
            .runtime
            .select_artifact(self.kernel.name(), self.m, self.n, sample.len())
            .unwrap_or_else(|| {
                panic!(
                    "no artifact covers k={} (kind={}, m={}, n={})",
                    sample.len(),
                    self.kernel.name(),
                    self.m,
                    self.n
                )
            })
            .clone();
        // Gather sampled rows, padding with zeros (discarded).
        let mut s = vec![0f32; spec.k * self.n];
        for (r, &idx) in sample.iter().enumerate() {
            s[r * self.n..(r + 1) * self.n]
                .copy_from_slice(&self.a[idx * self.n..(idx + 1) * self.n]);
        }
        let out = self
            .runtime
            .execute_gram_buf(&spec.name, &self.a_buf, &s)
            .expect("PJRT gram execution failed");
        for r in 0..sample.len() {
            let src = &out[r * self.m..(r + 1) * self.m];
            for (dst, &v) in q.row_mut(r).iter_mut().zip(src) {
                *dst = v as f64;
            }
        }
        ProductCost {
            flops: 2.0 * (spec.k * self.m * self.n) as f64
                + self.kernel.mu() * (spec.k * self.m) as f64,
            rows_charged: spec.k,
        }
    }
}

/// [`GramOracle`] backed by the PJRT runtime: the dense fast path, as a
/// gram-engine configuration.
pub struct PjrtGram {
    engine: GramEngine<PjrtProduct, NoReduce>,
}

impl PjrtGram {
    /// Build from a dense dataset. Fails fast if no artifact covers
    /// `(kernel, m, n)`.
    pub fn new(runtime: PjrtRuntime, a_mat: &Mat, kernel: Kernel) -> Result<PjrtGram> {
        Self::with_cache(runtime, a_mat, kernel, 0)
    }

    /// Same, with the engine's kernel-row cache for `cache_rows > 0`.
    pub fn with_cache(
        runtime: PjrtRuntime,
        a_mat: &Mat,
        kernel: Kernel,
        cache_rows: usize,
    ) -> Result<PjrtGram> {
        let (m, n) = (a_mat.nrows(), a_mat.ncols());
        anyhow::ensure!(
            runtime.select_artifact(kernel.name(), m, n, 1).is_some(),
            "no artifact for kind={} m={m} n={n}; run `make artifacts` or \
             add the shape to python/compile/model.py",
            kernel.name()
        );
        let a: Vec<f32> = a_mat.data().iter().map(|&v| v as f32).collect();
        let a_buf = runtime.upload_f32(&a, &[m, n])?;
        let row_norms = a_mat.row_norms_sq();
        let diag = (0..m)
            .map(|i| kernel.apply_scalar(row_norms[i], row_norms[i], row_norms[i]))
            .collect();
        let product = PjrtProduct {
            runtime,
            kernel,
            a,
            a_buf,
            m,
            n,
        };
        Ok(PjrtGram {
            engine: GramEngine::new(Layout::Full, product, NoReduce, None, diag, cache_rows),
        })
    }

    /// See [`super::check_kernel_params`].
    pub fn check_params(kernel: Kernel) -> Result<()> {
        super::check_kernel_params(kernel)
    }
}

impl GramOracle for PjrtGram {
    fn m(&self) -> usize {
        self.engine.m()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram(sample, q, ledger);
    }

    fn diag(&self) -> Vec<f64> {
        self.engine.diag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Ledger;
    use crate::solvers::LocalGram;
    use crate::sparse::Csr;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; artifacts are built by `make
        // artifacts` (a test-suite prerequisite, see Makefile).
        PjrtRuntime::default_dir()
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn dense_dataset(m: usize, n: usize) -> Mat {
        let mut rng = crate::rng::Pcg::seeded(2024);
        Mat::from_fn(m, n, |_, _| 0.3 * rng.next_gaussian())
    }

    #[test]
    fn runtime_opens_and_lists_artifacts() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = PjrtRuntime::open(&artifacts_dir()).unwrap();
        assert!(rt.manifest().artifacts().len() >= 30);
        assert!(rt.select_artifact("rbf", 256, 64, 5).is_some());
        // Padding picks the smallest k ≥ request.
        assert_eq!(rt.select_artifact("rbf", 256, 64, 5).unwrap().k, 8);
        assert_eq!(rt.select_artifact("rbf", 256, 64, 200).unwrap().k, 256);
        assert!(rt.select_artifact("rbf", 256, 64, 500).is_none());
        assert!(rt.select_artifact("rbf", 123, 64, 1).is_none());
    }

    #[test]
    fn pjrt_gram_matches_native_path_all_kernels() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = dense_dataset(256, 64);
        let a_csr = Csr::from_dense(&a);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let rt = PjrtRuntime::open(&artifacts_dir()).unwrap();
            let mut pjrt = PjrtGram::new(rt, &a, kernel).unwrap();
            let mut native = LocalGram::new(a_csr.clone(), kernel);
            let sample = vec![3usize, 77, 200, 13, 13];
            let mut q1 = Mat::zeros(5, 256);
            let mut q2 = Mat::zeros(5, 256);
            pjrt.gram(&sample, &mut q1, &mut Ledger::new());
            native.gram(&sample, &mut q2, &mut Ledger::new());
            for (x, y) in q1.data().iter().zip(q2.data()) {
                // f32 artifact vs f64 native: loose tolerance.
                assert!(
                    (x - y).abs() < 1e-4 * y.abs().max(1.0),
                    "{kernel:?}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn cached_pjrt_gram_is_bitwise_equal_to_uncached() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = dense_dataset(256, 64);
        let mut plain =
            PjrtGram::new(PjrtRuntime::open(&artifacts_dir()).unwrap(), &a, Kernel::paper_rbf())
                .unwrap();
        let mut cached = PjrtGram::with_cache(
            PjrtRuntime::open(&artifacts_dir()).unwrap(),
            &a,
            Kernel::paper_rbf(),
            16,
        )
        .unwrap();
        for sample in [vec![1usize, 2, 3], vec![2usize, 1, 9], vec![1usize, 1, 2]] {
            let mut q1 = Mat::zeros(sample.len(), 256);
            let mut q2 = Mat::zeros(sample.len(), 256);
            plain.gram(&sample, &mut q1, &mut Ledger::new());
            cached.gram(&sample, &mut q2, &mut Ledger::new());
            assert_eq!(q1.data(), q2.data());
        }
    }

    #[test]
    fn pjrt_gram_diag_is_consistent() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let a = dense_dataset(256, 64);
        let rt = PjrtRuntime::open(&artifacts_dir()).unwrap();
        let pjrt = PjrtGram::new(rt, &a, Kernel::paper_rbf()).unwrap();
        for v in pjrt.diag() {
            assert!((v - 1.0).abs() < 1e-12); // RBF diag = 1
        }
    }
}
