//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One lowered artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Unique artifact name (keys the compiled-executable cache).
    pub name: String,
    /// HLO text file name, relative to the artifact directory.
    pub file: String,
    /// Kernel family: `linear` | `poly` | `rbf`.
    pub kind: String,
    /// Data shape `(m, n)` and sampled-row count `k` the program was
    /// lowered for.
    pub m: usize,
    /// Feature count the program was lowered for.
    pub n: usize,
    /// Sampled-row count the program was lowered for.
    pub k: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing 'version'"))?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let field = |key: &str| -> Result<&Json> {
                a.get(key)
                    .ok_or_else(|| anyhow!("artifact {i}: missing '{key}'"))
            };
            let spec = ArtifactSpec {
                name: field("name")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact {i}: name not a string"))?
                    .to_string(),
                file: field("file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact {i}: file not a string"))?
                    .to_string(),
                kind: field("kind")?
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact {i}: kind not a string"))?
                    .to_string(),
                m: field("m")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("artifact {i}: bad m"))?,
                n: field("n")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("artifact {i}: bad n"))?,
                k: field("k")?
                    .as_usize()
                    .ok_or_else(|| anyhow!("artifact {i}: bad k"))?,
            };
            artifacts.push(spec);
        }
        // Names must be unique (they key the compiled-executable cache).
        let mut names: Vec<&str> = artifacts.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        anyhow::ensure!(
            names.len() == artifacts.len(),
            "duplicate artifact names in manifest"
        );
        Ok(Manifest { artifacts })
    }

    /// All artifacts, in manifest order.
    pub fn artifacts(&self) -> &[ArtifactSpec] {
        &self.artifacts
    }

    /// Look an artifact up by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "jax_version": "0.8.2",
      "artifacts": [
        {"name": "gram_linear_m8_n2_k1", "file": "gram_linear_m8_n2_k1.hlo.txt",
         "kind": "linear", "m": 8, "n": 2, "k": 1,
         "params": {"c": 0.0, "d": 3, "sigma": 1.0},
         "dtype": "f32", "inputs": [[8, 2], [1, 2]], "output": [1, 8]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts().len(), 1);
        let a = m.get("gram_linear_m8_n2_k1").unwrap();
        assert_eq!((a.m, a.n, a.k), (8, 2, 1));
        assert_eq!(a.kind, "linear");
    }

    #[test]
    fn rejects_bad_version() {
        let text = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&text).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let dup = SAMPLE.replace(
            "]\n    }",
            r#", {"name": "gram_linear_m8_n2_k1", "file": "x.hlo.txt",
                "kind": "linear", "m": 8, "n": 2, "k": 1}]
            }"#,
        );
        assert!(Manifest::parse(&dup).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"version": 1, "artifacts": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
