//! Offline stub for the PJRT runtime (built without the `xla-pjrt`
//! feature). Same API surface as the real implementation; the only
//! constructor ([`PjrtRuntime::open`]) returns an error, so the other
//! methods are unreachable at runtime — the `Infallible` field makes
//! both types unconstructable.

use std::convert::Infallible;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::costmodel::Ledger;
use crate::dense::Mat;
use crate::gram::GramOracle;
use crate::kernelfn::Kernel;

use super::manifest::Manifest;

const UNAVAILABLE: &str =
    "PJRT support not compiled in (enable the `xla-pjrt` cargo feature and provide the \
     vendored `xla` crate)";

/// Stub PJRT client: cannot be constructed.
pub struct PjrtRuntime {
    #[allow(dead_code)]
    _unconstructable: Infallible,
}

impl PjrtRuntime {
    /// Always fails in the stub build.
    pub fn open(_dir: &Path) -> Result<PjrtRuntime> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    /// The default artifact directory (`$KCD_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        unreachable!("stub PjrtRuntime cannot be constructed")
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        unreachable!("stub PjrtRuntime cannot be constructed")
    }

    /// Execute the gram artifact `name` on `(a, s)` (f32, row-major).
    pub fn execute_gram(&mut self, _name: &str, _a: &[f32], _s: &[f32]) -> Result<Vec<f32>> {
        unreachable!("stub PjrtRuntime cannot be constructed")
    }
}

/// Stub PJRT-backed oracle: cannot be constructed.
pub struct PjrtGram {
    #[allow(dead_code)]
    _unconstructable: Infallible,
}

impl PjrtGram {
    /// Always fails in the stub build (the runtime argument cannot exist,
    /// but the signature keeps call sites compiling unchanged).
    pub fn new(_runtime: PjrtRuntime, _a: &Mat, _kernel: Kernel) -> Result<PjrtGram> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    /// Cached-constructor counterpart; always fails in the stub build.
    pub fn with_cache(
        _runtime: PjrtRuntime,
        _a: &Mat,
        _kernel: Kernel,
        _cache_rows: usize,
    ) -> Result<PjrtGram> {
        Err(anyhow!("{UNAVAILABLE}"))
    }

    /// See [`super::check_kernel_params`].
    pub fn check_params(kernel: Kernel) -> Result<()> {
        super::check_kernel_params(kernel)
    }
}

impl GramOracle for PjrtGram {
    fn m(&self) -> usize {
        unreachable!("stub PjrtGram cannot be constructed")
    }

    fn gram(&mut self, _sample: &[usize], _q: &mut Mat, _ledger: &mut Ledger) {
        unreachable!("stub PjrtGram cannot be constructed")
    }

    fn diag(&self) -> Vec<f64> {
        unreachable!("stub PjrtGram cannot be constructed")
    }
}
