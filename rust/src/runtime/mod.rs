//! PJRT runtime: loads the AOT-compiled JAX/Pallas gram artifacts and
//! executes them from the L3 hot path.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 gram programs
//! to `artifacts/<name>.hlo.txt` plus `manifest.json`. At startup the
//! coordinator constructs a [`PjrtRuntime`]: one `PjRtClient::cpu()`, and
//! one compiled executable per artifact, compiled lazily on first use and
//! cached. [`PjrtGram`] adapts a runtime + dense dataset into a
//! [`GramOracle`](crate::gram::GramOracle) — as a configuration of the
//! staged gram engine (an XLA-executing product stage that emits finished
//! kernel values, no reduction, optional row cache) — so the solvers can
//! run their kernel hot-spot through XLA instead of the native Rust path.
//! Python never runs at solve time.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not a
//! serialized proto — see DESIGN.md §9 and /opt/xla-example/README.md.
//!
//! ### Feature gating
//!
//! The XLA FFI crate cannot be vendored into the offline build, so the
//! real implementation sits behind the `xla-pjrt` cargo feature (see
//! `rust/Cargo.toml`). Without it, this module compiles a stub with the
//! same API whose `PjrtRuntime::open` returns an error — callers already
//! treat "no artifacts" as a skip, so every bench/example degrades
//! gracefully.

#![forbid(unsafe_code)]

mod manifest;

pub use manifest::{ArtifactSpec, Manifest};

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::kernelfn::Kernel;

/// The default artifact directory (`$KCD_ARTIFACTS` or `artifacts/`).
/// Shared by the real and stub runtimes so the contract cannot diverge.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("KCD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Kernel parameters must match what the artifacts were lowered with
/// (the paper defaults). Guarded here — once, for both feature builds —
/// so a config mismatch fails loudly instead of silently computing a
/// different kernel.
pub fn check_kernel_params(kernel: Kernel) -> Result<()> {
    match kernel {
        Kernel::Linear => Ok(()),
        Kernel::Poly { c, d } if c == 0.0 && d == 3 => Ok(()),
        Kernel::Rbf { sigma } if sigma == 1.0 => Ok(()),
        other => Err(anyhow!(
            "artifacts are lowered with paper-default kernel params; got {other:?}"
        )),
    }
}

#[cfg(feature = "xla-pjrt")]
mod pjrt;
#[cfg(feature = "xla-pjrt")]
pub use pjrt::{PjrtGram, PjrtRuntime};

#[cfg(not(feature = "xla-pjrt"))]
mod stub;
#[cfg(not(feature = "xla-pjrt"))]
pub use stub::{PjrtGram, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelfn::Kernel;

    #[test]
    fn param_guard_rejects_non_default_kernels() {
        assert!(PjrtGram::check_params(Kernel::Rbf { sigma: 2.0 }).is_err());
        assert!(PjrtGram::check_params(Kernel::paper_rbf()).is_ok());
        assert!(PjrtGram::check_params(Kernel::Linear).is_ok());
    }

    #[test]
    fn default_dir_respects_env_contract() {
        // Pure path logic — no client construction.
        let d = PjrtRuntime::default_dir();
        assert!(!d.as_os_str().is_empty());
    }
}
