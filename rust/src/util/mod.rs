//! Small shared utilities: timers, formatting, simple stats, JSON.

#![forbid(unsafe_code)]

pub mod json;

use std::time::Instant;

/// A cumulative phase timer (monotonic clock; `start`/`stop` pairs).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    total_ns: u128,
    count: u64,
}

impl PhaseTimer {
    /// A zeroed timer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure, accumulating into this phase.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total_ns += t0.elapsed().as_nanos();
        self.count += 1;
        out
    }

    /// Accumulated seconds.
    pub fn secs(&self) -> f64 {
        self.total_ns as f64 * 1e-9
    }

    /// Number of timed intervals.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Add raw nanoseconds (for merging per-rank timers).
    pub fn add_ns(&mut self, ns: u128) {
        self.total_ns += ns;
        self.count += 1;
    }

    /// Zero the accumulated time and count.
    pub fn reset(&mut self) {
        self.total_ns = 0;
        self.count = 0;
    }
}

/// Human-friendly duration formatting for reports.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-friendly byte-count formatting.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Median of a sample (copies + sorts; fine for report sizes).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Mean of a sample.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Geometric mean (for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn stats_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.time(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        t.time(|| ());
        assert!(t.secs() >= 0.001);
        assert_eq!(t.count(), 2);
        t.reset();
        assert_eq!(t.secs(), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(0.002), "2.000 ms");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }
}
