//! Minimal JSON parser (serde is unavailable in the offline build).
//!
//! Supports the full JSON grammar minus surrogate-pair unicode escapes —
//! enough for `artifacts/manifest.json` and experiment configs. Strict:
//! trailing garbage, unterminated literals, and malformed numbers are
//! errors.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64 precision).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic rendering).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer, if exact.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize back to JSON text (compact; deterministic key order via
    /// the BTreeMap backing). Round-trips with [`Json::parse`].
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    // Shortest round-trippable form.
                    out.push_str(&format!("{n:e}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a numeric array.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(format!("bad keyword at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| format!("invalid codepoint {code:#x}"))?,
                            );
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected ',' or ']', found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected ',' or '}}', found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("x".into())
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""éA""#).unwrap(),
            Json::Str("éA".into())
        );
        assert_eq!(Json::parse("\"π\"").unwrap(), Json::Str("π".into()));
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("42.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn render_parse_roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::Str("x\"y\n".into())),
            ("n", Json::Num(42.0)),
            ("x", Json::Num(-2.5e-3)),
            ("flag", Json::Bool(true)),
            ("xs", Json::nums(&[1.0, 0.5, 1e300])),
            ("nil", Json::Null),
        ]);
        let back = Json::parse(&v.render()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn render_floats_roundtrip_exactly() {
        for x in [0.1, 1.0 / 3.0, f64::MIN_POSITIVE, 12345.6789e-200] {
            let v = Json::Num(x);
            let back = Json::parse(&v.render()).unwrap();
            assert_eq!(back.as_f64(), Some(x), "{x}");
        }
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "gram_rbf_m256_n64_k8", "file": "gram_rbf_m256_n64_k8.hlo.txt",
             "kind": "rbf", "m": 256, "n": 64, "k": 8,
             "params": {"c": 0.0, "d": 3, "sigma": 1.0},
             "inputs": [[256, 64], [8, 64]], "output": [8, 256]}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("k").unwrap().as_usize(), Some(8));
        assert_eq!(arts[0].get("kind").unwrap().as_str(), Some("rbf"));
    }
}
