//! Sparse-matrix substrate (CSR), replacing the paper's Intel MKL
//! SparseBLAS dependency.
//!
//! The performance datasets (Table 3) are sparse and stored in CSR; the
//! hot operation is the sampled gram product `A_S Aᵀ` (CSR × CSRᵀ with a
//! dense `sb×m` output) plus the SpMV-like products in the gradient path.
//! The matrix is partitioned in 1D-column layout across ranks, so we also
//! provide column slicing with re-indexing.

#![forbid(unsafe_code)]

use crate::dense::Mat;

/// Compressed Sparse Row matrix (`f64` values, `usize` indices).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    /// Row pointer, length `nrows + 1`.
    indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    indices: Vec<usize>,
    /// Nonzero values, parallel to `indices`.
    data: Vec<f64>,
}

impl Csr {
    /// Build from raw CSR arrays; validates invariants.
    pub fn new(
        nrows: usize,
        ncols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        data: Vec<f64>,
    ) -> Csr {
        assert_eq!(indptr.len(), nrows + 1, "CSR: indptr length");
        assert_eq!(indices.len(), data.len(), "CSR: indices/data length");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "CSR: nnz mismatch");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "CSR: indptr monotone");
        debug_assert!(indices.iter().all(|&j| j < ncols), "CSR: col index bound");
        Csr {
            nrows,
            ncols,
            indptr,
            indices,
            data,
        }
    }

    /// An `m×n` matrix with no stored entries.
    pub fn empty(nrows: usize, ncols: usize) -> Csr {
        Csr {
            nrows,
            ncols,
            indptr: vec![0; nrows + 1],
            indices: Vec::new(),
            data: Vec::new(),
        }
    }

    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(nrows: usize, ncols: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); nrows];
        for &(i, j, v) in triplets {
            assert!(i < nrows && j < ncols, "triplet out of bounds");
            per_row[i].push((j, v));
        }
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::with_capacity(triplets.len());
        let mut data = Vec::with_capacity(triplets.len());
        indptr.push(0);
        for row in &mut per_row {
            row.sort_unstable_by_key(|&(j, _)| j);
            let mut k = 0;
            while k < row.len() {
                let j = row[k].0;
                let mut v = 0.0;
                while k < row.len() && row[k].0 == j {
                    v += row[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    indices.push(j);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows,
            ncols,
            indptr,
            indices,
            data,
        }
    }

    /// Convert a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &Mat) -> Csr {
        let mut indptr = Vec::with_capacity(a.nrows() + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for i in 0..a.nrows() {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    data.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: a.nrows(),
            ncols: a.ncols(),
            indptr,
            indices,
            data,
        }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.nrows, self.ncols);
        for i in 0..self.nrows {
            let row = out.row_mut(i);
            for (j, v) in self.row_iter(i) {
                row[j] = v;
            }
        }
        out
    }

    /// Number of rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Fraction of entries stored.
    pub fn density(&self) -> f64 {
        if self.nrows == 0 || self.ncols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// Stored entries of row `i` as `(col, value)` pairs.
    #[inline]
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .copied()
            .zip(self.data[lo..hi].iter().copied())
    }

    /// Number of stored entries in row `i`.
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// `(cols, vals)` slices for row `i` — the zero-overhead accessor used
    /// in the hot loops.
    #[inline]
    pub fn row_parts(&self, i: usize) -> (&[usize], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.data[lo..hi])
    }

    /// Sparse dot of rows `i` of `self` and `k` of `other` (merge join;
    /// both index lists are sorted).
    pub fn row_dot(&self, i: usize, other: &Csr, k: usize) -> f64 {
        let (ci, vi) = self.row_parts(i);
        let (ck, vk) = other.row_parts(k);
        let mut a = 0;
        let mut b = 0;
        let mut s = 0.0;
        while a < ci.len() && b < ck.len() {
            match ci[a].cmp(&ck[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += vi[a] * vk[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    /// `y ← S x` (SpMV).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        for i in 0..self.nrows {
            let (cols, vals) = self.row_parts(i);
            let mut s = 0.0;
            for (&j, &v) in cols.iter().zip(vals) {
                s += v * x[j];
            }
            y[i] = s;
        }
    }

    /// `y ← Sᵀ x` (transpose SpMV, scatter form).
    pub fn spmv_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.ncols);
        y.fill(0.0);
        for i in 0..self.nrows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let (cols, vals) = self.row_parts(i);
            for (&j, &v) in cols.iter().zip(vals) {
                y[j] += xi * v;
            }
        }
    }

    /// Dense output `C ← S Bᵀ_dense` where `B` is `n×k` dense row-major and
    /// `C` is `nrows×n` — i.e. `C[i][r] = Σ_j S[i,j] B[r,j]`.
    ///
    /// This is the gram hot path when the *sampled* side is dense
    /// (`B = A_S` gathered rows) and `self` is the big CSR shard.
    pub fn spmm_dense_t(&self, b: &Mat, c: &mut Mat) {
        assert_eq!(b.ncols(), self.ncols, "spmm_dense_t: inner dim");
        assert_eq!(c.nrows(), self.nrows);
        assert_eq!(c.ncols(), b.nrows());
        for i in 0..self.nrows {
            let (cols, vals) = self.row_parts(i);
            let crow = c.row_mut(i);
            for (r, cir) in crow.iter_mut().enumerate() {
                let brow = b.row(r);
                let mut s = 0.0;
                for (&j, &v) in cols.iter().zip(vals) {
                    s += v * brow[j];
                }
                *cir = s;
            }
        }
    }

    /// Sampled gram block `Q ← S_rows(sample) · Sᵀ` as a dense
    /// `sample.len()×nrows` matrix: `Q[r][i] = <S[sample_r,:], S[i,:]>`.
    ///
    /// Uses a scatter of the (short) sampled row into a dense accumulator,
    /// then a gather pass over all rows — O(nnz(sample) + nnz(S)) per
    /// sampled row in the worst case but with excellent locality, matching
    /// what MKL's CSR SpGEMM does for this shape.
    pub fn sampled_gram(&self, sample: &[usize], q: &mut Mat, scratch: &mut Vec<f64>) {
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.nrows);
        scratch.clear();
        scratch.resize(self.ncols, 0.0);
        for (r, &sr) in sample.iter().enumerate() {
            // Scatter sampled row into dense scratch.
            let (scols, svals) = self.row_parts(sr);
            for (&j, &v) in scols.iter().zip(svals) {
                scratch[j] = v;
            }
            // Dot every row against scratch.
            let qrow = q.row_mut(r);
            for i in 0..self.nrows {
                let (cols, vals) = self.row_parts(i);
                let mut s = 0.0;
                for (&j, &v) in cols.iter().zip(vals) {
                    s += v * scratch[j];
                }
                qrow[i] = s;
            }
            // Un-scatter.
            for &j in scols {
                scratch[j] = 0.0;
            }
        }
    }

    /// Blocked variant of [`Csr::sampled_gram`]: gathers the sampled rows
    /// into a dense `k×n` scratch once, then streams the CSR a *single*
    /// time, producing all `k` output rows per matrix row — versus the
    /// scatter variant's full pass over the CSR per sampled row. Same
    /// flop count, `k×` less memory traffic over `self` (the §Perf
    /// locality win the gram engine's product stage uses for dense-ish
    /// data). Per-entry summation order is identical to
    /// [`Csr::sampled_gram`] (ascending column index within each row), so
    /// results are bitwise equal.
    pub fn sampled_gram_blocked(&self, sample: &[usize], q: &mut Mat, scratch: &mut Vec<f64>) {
        self.sampled_gram_blocked_against(sample, self, q, scratch);
    }

    /// [`Csr::sampled_gram_blocked`] with the output columns restricted to
    /// the rows of `targets`, a row subset of the same column space:
    /// `q[r][u] = ⟨self[sample_r, :], targets[u, :]⟩`.
    ///
    /// The sampled side always gathers from `self` (the full row set), so
    /// sampled indices remain global. Per-element arithmetic is identical
    /// to the unrestricted variant — restricting the target set drops
    /// output columns without reordering a single addition — which is what
    /// makes the 2D grid layout's row-sliced partial blocks bitwise equal
    /// to column slices of the 1D partial block (see `crate::gram`).
    pub fn sampled_gram_blocked_against(
        &self,
        sample: &[usize],
        targets: &Csr,
        q: &mut Mat,
        scratch: &mut Vec<f64>,
    ) {
        assert_eq!(
            targets.ncols, self.ncols,
            "targets must share the column space"
        );
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), targets.nrows);
        let k = sample.len();
        let n = self.ncols;
        scratch.clear();
        scratch.resize(k * n, 0.0);
        for (r, &sr) in sample.iter().enumerate() {
            let (cols, vals) = self.row_parts(sr);
            let row = &mut scratch[r * n..(r + 1) * n];
            for (&j, &v) in cols.iter().zip(vals) {
                row[j] = v;
            }
        }
        for i in 0..targets.nrows {
            let (cols, vals) = targets.row_parts(i);
            for r in 0..k {
                let srow = &scratch[r * n..(r + 1) * n];
                let mut s = 0.0;
                for (&j, &v) in cols.iter().zip(vals) {
                    s += v * srow[j];
                }
                q[(r, i)] = s;
            }
        }
    }

    /// Sampled gram block via a precomputed transpose (`at = self.T`):
    /// `q[r][i] = Σ_j self[sr, j] · at[j, i]`.
    ///
    /// Cost is `Σ_{j ∈ row(sr)} nnz(col j)` per sampled row — for a
    /// uniformly sparse matrix with density `f` that is `f²·m·n` versus
    /// [`Csr::sampled_gram`]'s `f·m·n`, i.e. a `1/f` speedup (≈100× at
    /// 1% density). The scatter-dot variant stays preferable for dense
    /// data; `LocalGram`/`DistGram` pick per density (§Perf).
    pub fn sampled_gram_t(&self, at: &Csr, sample: &[usize], q: &mut Mat) {
        assert_eq!(at.nrows(), self.ncols(), "at must be self.transpose()");
        assert_eq!(at.ncols(), self.nrows(), "at must be self.transpose()");
        self.sampled_gram_t_against(at, sample, q);
    }

    /// [`Csr::sampled_gram_t`] with the output columns restricted to a row
    /// subset of the matrix: `at_targets` is `targets.transpose()` for
    /// some row subset `targets` of the same column space, and
    /// `q[r][u] = ⟨self[sample_r, :], targets[u, :]⟩`.
    ///
    /// As with [`Csr::sampled_gram_blocked_against`], per-element adds
    /// happen in ascending feature order exactly as in the unrestricted
    /// variant, so the restricted block is bitwise equal to a column slice
    /// of the full block.
    pub fn sampled_gram_t_against(&self, at_targets: &Csr, sample: &[usize], q: &mut Mat) {
        assert_eq!(
            at_targets.nrows, self.ncols,
            "at_targets must be a transpose over this matrix's column space"
        );
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), at_targets.ncols);
        for (r, &sr) in sample.iter().enumerate() {
            let qrow = q.row_mut(r);
            qrow.fill(0.0);
            let (cols, vals) = self.row_parts(sr);
            for (&j, &v) in cols.iter().zip(vals) {
                let (rows_i, ws) = at_targets.row_parts(j);
                for (&i, &w) in rows_i.iter().zip(ws) {
                    qrow[i] += v * w;
                }
            }
        }
    }

    /// Gather the given rows into a new CSR (forms `A_S`).
    pub fn gather_rows(&self, rows: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (cols, vals) = self.row_parts(i);
            indices.extend_from_slice(cols);
            data.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        Csr {
            nrows: rows.len(),
            ncols: self.ncols,
            indptr,
            indices,
            data,
        }
    }

    /// Pack the stored entries of the given rows into an interleaved
    /// `(column, value)` f64 stream — the wire format of the sharded
    /// grid layout's fragment exchange (the comm substrate moves `f64`
    /// buffers only; column indices are exact in f64 up to 2⁵³, far
    /// beyond any feature count). The stream is `2·Σ nnz(row)` words,
    /// rows in the given order, entries in stored (ascending-column)
    /// order — so [`Csr::from_packed`] rebuilds rows *verbatim*, which
    /// is what keeps the sharded product bitwise identical to the
    /// replicated one.
    pub fn pack_rows(&self, rows: &[usize]) -> Vec<f64> {
        let total: usize = rows.iter().map(|&i| self.row_nnz(i)).sum();
        let mut out = Vec::with_capacity(2 * total);
        for &i in rows {
            let (cols, vals) = self.row_parts(i);
            for (&j, &v) in cols.iter().zip(vals) {
                out.push(j as f64);
                out.push(v);
            }
        }
        out
    }

    /// Rebuild rows from a [`Csr::pack_rows`] stream: `row_nnz[r]` is the
    /// stored-entry count of output row `r` (exchanged once at setup by
    /// the sharded grid layout, so per-call streams need no headers), and
    /// `packed` the concatenated `(column, value)` pairs. Inverse of
    /// `pack_rows` — the rebuilt rows are bitwise identical to the
    /// originals.
    pub fn from_packed(ncols: usize, row_nnz: &[usize], packed: &[f64]) -> Csr {
        let total: usize = row_nnz.iter().sum();
        assert_eq!(
            packed.len(),
            2 * total,
            "from_packed: stream holds {} words but row_nnz promises {}",
            packed.len(),
            2 * total
        );
        let mut indptr = Vec::with_capacity(row_nnz.len() + 1);
        indptr.push(0usize);
        let mut acc = 0usize;
        for &n in row_nnz {
            acc += n;
            indptr.push(acc);
        }
        let mut indices = Vec::with_capacity(total);
        let mut data = Vec::with_capacity(total);
        for pair in packed.chunks_exact(2) {
            let j = pair[0] as usize;
            assert!(j < ncols, "from_packed: column index {j} out of range");
            indices.push(j);
            data.push(pair[1]);
        }
        Csr {
            nrows: row_nnz.len(),
            ncols,
            indptr,
            indices,
            data,
        }
    }

    /// Slice columns `[c0, c1)`, re-indexing columns to start at zero —
    /// this is the 1D-column partitioning step (each rank keeps `n/P`
    /// features of every sample).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Csr {
        assert!(c0 <= c1 && c1 <= self.ncols);
        let mut indptr = Vec::with_capacity(self.nrows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for i in 0..self.nrows {
            let (cols, vals) = self.row_parts(i);
            // Rows are sorted: binary search the window.
            let lo = cols.partition_point(|&j| j < c0);
            let hi = cols.partition_point(|&j| j < c1);
            for k in lo..hi {
                indices.push(cols[k] - c0);
                data.push(vals[k]);
            }
            indptr.push(indices.len());
        }
        Csr {
            nrows: self.nrows,
            ncols: c1 - c0,
            indptr,
            indices,
            data,
        }
    }

    /// Out-of-place transpose (two-pass counting sort; O(nnz + n)).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.ncols + 1];
        for &j in &self.indices {
            counts[j + 1] += 1;
        }
        for j in 0..self.ncols {
            counts[j + 1] += counts[j];
        }
        let indptr = counts.clone();
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut cursor = counts;
        for i in 0..self.nrows {
            for (j, v) in self.row_iter(i) {
                let dst = cursor[j];
                indices[dst] = i;
                data[dst] = v;
                cursor[j] += 1;
            }
        }
        Csr {
            nrows: self.ncols,
            ncols: self.nrows,
            indptr,
            indices,
            data,
        }
    }

    /// Squared Euclidean norm of every row (cached for the RBF map).
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|i| {
                let (_, vals) = self.row_parts(i);
                vals.iter().map(|v| v * v).sum()
            })
            .collect()
    }

    /// Scale row `i` by `s` in place (used for `diag(y)·A`).
    pub fn scale_row(&mut self, i: usize, s: f64) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        for v in &mut self.data[lo..hi] {
            *v *= s;
        }
    }

    /// Number of stored entries per column (the nonzero histogram used by
    /// the load-imbalance analysis and the projected-scaling engine).
    pub fn col_nnz_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.ncols];
        for &j in &self.indices {
            counts[j] += 1;
        }
        counts
    }

    /// Max nonzeros held by any of `p` equal-width column shards, without
    /// materializing the shards (cheap enough to sweep `p` to 4096).
    pub fn max_shard_nnz(&self, p: usize) -> usize {
        assert!(p > 0);
        let counts = self.col_nnz_counts();
        let width = self.ncols.div_ceil(p);
        (0..p)
            .map(|r| {
                let c0 = (r * width).min(self.ncols);
                let c1 = ((r + 1) * width).min(self.ncols);
                counts[c0..c1].iter().sum()
            })
            .max()
            .unwrap_or(0)
    }

    /// Split into `p` column shards of near-equal width (1D-column layout).
    /// Shard `r` gets columns `[r*ceil(n/p), ...)` — the paper's layout
    /// where each MPI process stores roughly `n/P` features.
    pub fn partition_cols(&self, p: usize) -> Vec<Csr> {
        assert!(p > 0);
        let n = self.ncols;
        let width = n.div_ceil(p);
        (0..p)
            .map(|r| {
                let c0 = (r * width).min(n);
                let c1 = ((r + 1) * width).min(n);
                self.slice_cols(c0, c1)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::gemm_nt;
    use crate::rng::Pcg;

    fn rand_sparse(r: &mut Pcg, m: usize, n: usize, density: f64) -> Csr {
        let mut trips = Vec::new();
        for i in 0..m {
            for j in 0..n {
                if r.next_f64() < density {
                    trips.push((i, j, r.next_gaussian()));
                }
            }
        }
        Csr::from_triplets(m, n, &trips)
    }

    #[test]
    fn dense_roundtrip() {
        let mut r = Pcg::seeded(41);
        let s = rand_sparse(&mut r, 13, 17, 0.3);
        assert_eq!(Csr::from_dense(&s.to_dense()), s);
    }

    #[test]
    fn triplets_sum_duplicates() {
        let s = Csr::from_triplets(2, 2, &[(0, 1, 2.0), (0, 1, 3.0), (1, 0, 1.0)]);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense()[(0, 1)], 5.0);
    }

    #[test]
    fn triplets_drop_cancelled() {
        let s = Csr::from_triplets(1, 2, &[(0, 0, 2.0), (0, 0, -2.0), (0, 1, 1.0)]);
        assert_eq!(s.nnz(), 1);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut r = Pcg::seeded(43);
        for _ in 0..20 {
            let m = r.gen_range(1, 30);
            let n = r.gen_range(1, 30);
            let s = rand_sparse(&mut r, m, n, 0.4);
            let d = s.to_dense();
            let x: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            let mut y1 = vec![0.0; m];
            let mut y2 = vec![0.0; m];
            s.spmv(&x, &mut y1);
            crate::dense::gemv(&d, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spmv_t_matches_dense() {
        let mut r = Pcg::seeded(47);
        for _ in 0..20 {
            let m = r.gen_range(1, 30);
            let n = r.gen_range(1, 30);
            let s = rand_sparse(&mut r, m, n, 0.4);
            let d = s.to_dense();
            let x: Vec<f64> = (0..m).map(|_| r.next_gaussian()).collect();
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            s.spmv_t(&x, &mut y1);
            crate::dense::gemv_t(&d, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn transpose_involution_and_correctness() {
        let mut r = Pcg::seeded(53);
        let s = rand_sparse(&mut r, 11, 7, 0.35);
        let t = s.transpose();
        assert_eq!(t.nrows(), 7);
        assert_eq!(t.to_dense(), s.to_dense().transpose());
        assert_eq!(t.transpose(), s);
    }

    #[test]
    fn sampled_gram_matches_dense_gemm() {
        let mut r = Pcg::seeded(59);
        for _ in 0..10 {
            let m = r.gen_range(2, 25);
            let n = r.gen_range(1, 25);
            let s = rand_sparse(&mut r, m, n, 0.4);
            let d = s.to_dense();
            let k = r.gen_range(1, m);
            let sample = r.sample_without_replacement(m, k);
            let mut q = Mat::zeros(k, m);
            let mut scratch = Vec::new();
            s.sampled_gram(&sample, &mut q, &mut scratch);
            let ds = d.gather_rows(&sample);
            let mut qref = Mat::zeros(k, m);
            gemm_nt(&ds, &d, &mut qref);
            for (a, b) in q.data().iter().zip(qref.data()) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn sampled_gram_blocked_is_bitwise_equal_to_scatter() {
        let mut r = Pcg::seeded(223);
        for density in [0.05, 0.4, 1.0] {
            let m = r.gen_range(3, 30);
            let n = r.gen_range(2, 40);
            let s = rand_sparse(&mut r, m, n, density);
            let k = r.gen_range(1, m);
            let mut sample = r.sample_without_replacement(m, k);
            sample.push(sample[0]); // duplicate row must also match
            let mut q1 = Mat::zeros(k + 1, m);
            let mut q2 = Mat::zeros(k + 1, m);
            let mut sc1 = Vec::new();
            let mut sc2 = Vec::new();
            s.sampled_gram(&sample, &mut q1, &mut sc1);
            s.sampled_gram_blocked(&sample, &mut q2, &mut sc2);
            assert_eq!(q1.data(), q2.data(), "density {density}");
        }
    }

    #[test]
    fn sampled_gram_t_matches_scatter_variant() {
        let mut r = Pcg::seeded(211);
        for density in [0.02, 0.2, 0.7] {
            let m = r.gen_range(4, 30);
            let n = r.gen_range(2, 40);
            let s = rand_sparse(&mut r, m, n, density);
            let at = s.transpose();
            let k = r.gen_range(1, m);
            let sample = r.sample_without_replacement(m, k);
            let mut q1 = Mat::zeros(k, m);
            let mut q2 = Mat::zeros(k, m);
            let mut scratch = Vec::new();
            s.sampled_gram(&sample, &mut q1, &mut scratch);
            s.sampled_gram_t(&at, &sample, &mut q2);
            for (a, b) in q1.data().iter().zip(q2.data()) {
                assert!((a - b).abs() < 1e-12, "density {density}");
            }
        }
    }

    /// The target-restricted variants must return bitwise column slices
    /// of the unrestricted block, on both the blocked and transpose paths
    /// (the grid layout's correctness hinges on this).
    #[test]
    fn sampled_gram_against_is_bitwise_column_slice() {
        let mut r = Pcg::seeded(227);
        for density in [0.05, 0.6] {
            let m = r.gen_range(6, 24);
            let n = r.gen_range(3, 30);
            let s = rand_sparse(&mut r, m, n, density);
            let k = r.gen_range(1, 5);
            let mut sample = r.sample_without_replacement(m, k);
            sample.push(sample[0]); // duplicates must behave too
            // A strided row subset (what a block-cyclic row group owns).
            let targets_rows: Vec<usize> = (0..m).step_by(3).collect();
            let targets = s.gather_rows(&targets_rows);

            let mut q_full = Mat::zeros(sample.len(), m);
            let mut sc = Vec::new();
            s.sampled_gram_blocked(&sample, &mut q_full, &mut sc);

            let mut q_sub = Mat::zeros(sample.len(), targets_rows.len());
            s.sampled_gram_blocked_against(&sample, &targets, &mut q_sub, &mut sc);
            for (rr, _) in sample.iter().enumerate() {
                for (u, &t) in targets_rows.iter().enumerate() {
                    assert_eq!(q_sub[(rr, u)], q_full[(rr, t)], "blocked ({rr},{t})");
                }
            }

            let at_full = s.transpose();
            let mut q_t_full = Mat::zeros(sample.len(), m);
            s.sampled_gram_t(&at_full, &sample, &mut q_t_full);
            let at_sub = targets.transpose();
            let mut q_t_sub = Mat::zeros(sample.len(), targets_rows.len());
            s.sampled_gram_t_against(&at_sub, &sample, &mut q_t_sub);
            for (rr, _) in sample.iter().enumerate() {
                for (u, &t) in targets_rows.iter().enumerate() {
                    assert_eq!(q_t_sub[(rr, u)], q_t_full[(rr, t)], "transpose ({rr},{t})");
                }
            }
        }
    }

    #[test]
    fn slice_cols_reindexes() {
        let s = Csr::from_triplets(2, 6, &[(0, 0, 1.0), (0, 3, 2.0), (1, 4, 3.0)]);
        let sl = s.slice_cols(3, 6);
        assert_eq!(sl.ncols(), 3);
        assert_eq!(sl.to_dense()[(0, 0)], 2.0);
        assert_eq!(sl.to_dense()[(1, 1)], 3.0);
    }

    #[test]
    fn partition_cols_reassembles() {
        let mut r = Pcg::seeded(61);
        let s = rand_sparse(&mut r, 9, 23, 0.3);
        for p in [1, 2, 3, 5, 23, 40] {
            let shards = s.partition_cols(p);
            assert_eq!(shards.len(), p);
            let total_cols: usize = shards.iter().map(|sh| sh.ncols()).sum();
            assert_eq!(total_cols, 23);
            let total_nnz: usize = shards.iter().map(|sh| sh.nnz()).sum();
            assert_eq!(total_nnz, s.nnz());
            // Gram over shards sums to full gram (the allreduce identity).
            let full = {
                let d = s.to_dense();
                let mut g = Mat::zeros(9, 9);
                gemm_nt(&d, &d, &mut g);
                g
            };
            let mut acc = Mat::zeros(9, 9);
            for sh in &shards {
                let d = sh.to_dense();
                let mut g = Mat::zeros(9, 9);
                gemm_nt(&d, &d, &mut g);
                for (a, b) in acc.data_mut().iter_mut().zip(g.data()) {
                    *a += b;
                }
            }
            for (a, b) in acc.data().iter().zip(full.data()) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn max_shard_nnz_matches_materialized_shards() {
        let mut r = Pcg::seeded(101);
        let s = rand_sparse(&mut r, 12, 37, 0.25);
        for p in [1, 2, 3, 5, 8, 37, 64] {
            let expect = s
                .partition_cols(p)
                .iter()
                .map(|sh| sh.nnz())
                .max()
                .unwrap();
            assert_eq!(s.max_shard_nnz(p), expect, "p={p}");
        }
    }

    #[test]
    fn col_nnz_counts_sum_to_nnz() {
        let mut r = Pcg::seeded(103);
        let s = rand_sparse(&mut r, 9, 14, 0.3);
        assert_eq!(s.col_nnz_counts().iter().sum::<usize>(), s.nnz());
    }

    #[test]
    fn row_dot_matches_dense() {
        let mut r = Pcg::seeded(67);
        let s = rand_sparse(&mut r, 10, 15, 0.4);
        let d = s.to_dense();
        for i in 0..10 {
            for k in 0..10 {
                let expect = crate::dense::dot(d.row(i), d.row(k));
                assert!((s.row_dot(i, &s, k) - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn row_norms_and_scale_row() {
        let mut s = Csr::from_triplets(2, 3, &[(0, 0, 3.0), (0, 2, 4.0), (1, 1, 2.0)]);
        assert_eq!(s.row_norms_sq(), vec![25.0, 4.0]);
        s.scale_row(0, -1.0);
        assert_eq!(s.to_dense()[(0, 0)], -3.0);
        assert_eq!(s.row_norms_sq(), vec![25.0, 4.0]);
    }

    /// pack_rows → from_packed must reproduce the selected rows
    /// *bitwise* (the fragment-exchange correctness anchor), including
    /// empty rows and repeats, on dense-ish and sparse data.
    #[test]
    fn pack_rows_roundtrips_bitwise_through_from_packed() {
        let mut r = Pcg::seeded(131);
        for density in [0.0, 0.05, 0.5] {
            let s = rand_sparse(&mut r, 12, 19, density);
            for rows in [vec![0usize, 5, 11], vec![7usize, 7, 2], Vec::new()] {
                let packed = s.pack_rows(&rows);
                let nnz: Vec<usize> = rows.iter().map(|&i| s.row_nnz(i)).collect();
                assert_eq!(packed.len(), 2 * nnz.iter().sum::<usize>());
                let rebuilt = Csr::from_packed(s.ncols(), &nnz, &packed);
                let direct = s.gather_rows(&rows);
                assert_eq!(rebuilt, direct, "density {density} rows {rows:?}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "from_packed")]
    fn from_packed_rejects_mismatched_stream() {
        let _ = Csr::from_packed(4, &[2], &[0.0, 1.0]);
    }

    #[test]
    fn gather_rows_works() {
        let mut r = Pcg::seeded(71);
        let s = rand_sparse(&mut r, 8, 5, 0.5);
        let g = s.gather_rows(&[7, 0, 3]);
        let gd = g.to_dense();
        let sd = s.to_dense();
        assert_eq!(gd.row(0), sd.row(7));
        assert_eq!(gd.row(1), sd.row(0));
        assert_eq!(gd.row(2), sd.row(3));
    }

    #[test]
    fn density_and_empty() {
        let e = Csr::empty(4, 5);
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.density(), 0.0);
        let s = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        assert!((s.density() - 0.5).abs() < 1e-15);
    }
}
