//! The paper's optimization methods.
//!
//! * [`dcd`] — Algorithm 1: Dual Coordinate Descent for K-SVM (L1/L2).
//! * [`dcd_sstep`] — Algorithm 2: s-step DCD for K-SVM.
//! * [`bdcd`] — Algorithm 3: Block Dual Coordinate Descent for K-RR.
//! * [`bdcd_sstep`] — Algorithm 4: s-step BDCD for K-RR.
//! * [`krr_exact`] — closed-form K-RR reference solution (the `α*` used
//!   by the relative-solution-error convergence metric).
//! * [`objective`] — K-SVM dual/primal objectives and duality gap.
//!
//! All solvers are generic over a [`GramOracle`] (defined in
//! [`crate::gram`], re-exported here), which produces rows of the kernel
//! matrix on demand. Every oracle is a thin configuration of the staged
//! gram engine: [`LocalGram`] computes locally, [`DistGram`] computes a
//! partial gram on this rank's 1D-column shard and sum-allreduces it (the
//! paper's parallelization), [`GridGram`] is one cell of a 2D `pr × pc`
//! process grid whose reduce runs over a `pc`-rank subcommunicator (the
//! communication-avoiding refinement), [`NystromGram`] multiplies
//! precomputed low-rank factors, and `runtime::PjrtGram` executes the
//! AOT-compiled JAX/Pallas artifact. The solver code is *identical* in serial and
//! distributed runs — every rank executes the same deterministic updates
//! on replicated state, exactly like the paper's MPI implementation.
//!
//! ### Kernelization note (faithful-to-math vs faithful-to-pseudocode)
//!
//! Algorithm 1 in the paper scales the data first (`Ã = diag(y)·A`) and
//! computes `K(Ã, ·)`. For the linear kernel this equals the dual's
//! `y_i y_j K(a_i, a_j)`; for RBF/polynomial it does not (e.g.
//! `‖y_i a_i − y_j a_j‖ ≠ ‖a_i − a_j‖` when `y_i ≠ y_j`). We implement
//! the mathematically correct `diag(y)·K(A,A)·diag(y)` (scaling applied
//! *after* the kernel map), which matches LIBSVM and the dual derivation;
//! for the linear kernel the two coincide exactly.

#![forbid(unsafe_code)]

mod bdcd;
mod cocoa;
mod dcd;
mod krr_exact;
mod nystrom;
pub mod objective;
mod oracle;

pub use bdcd::{
    bdcd, bdcd_sstep, bdcd_sstep_with_schedule, bdcd_with_schedule, KrrParams, KRR_COORD_STREAM,
};
pub use cocoa::{cocoa_svm, CocoaParams, CocoaResult};
pub use dcd::{
    dcd, dcd_sstep, dcd_sstep_with_schedule, dcd_with_schedule, SvmParams, SvmVariant,
    SVM_COORD_STREAM,
};
pub use krr_exact::{full_kernel_matrix, krr_exact};
pub use nystrom::NystromGram;
pub use oracle::{DistGram, GridGram, LocalGram};

pub use crate::gram::GramOracle;

/// Convergence-trace callback: called after every (inner-)iteration with
/// `(iteration, α)`. Figure benches use it to record duality gap /
/// relative-error series; pass `None` on the hot path.
pub type Trace<'a> = Option<&'a mut dyn FnMut(usize, &[f64])>;
