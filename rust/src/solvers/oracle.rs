//! Gram oracles: on-demand computation of sampled kernel-matrix rows.
//!
//! `gram(sample, q, ledger)` fills `q` (`sample.len() × m`) with
//! `q[r][i] = K(a_{sample_r}, a_i)`. The oracle owns the data layout:
//!
//! * [`LocalGram`] — full matrix on one rank (serial reference).
//! * [`DistGram`] — this rank's 1D-column shard; computes the *partial*
//!   linear gram, sum-allreduces it across ranks (real messages, real
//!   counts), then applies the nonlinear kernel map redundantly —
//!   exactly the communication pattern of the paper's Section 4 analysis.

use crate::comm::{allreduce_sum, AllreduceAlgo, CommStats, Communicator};
use crate::costmodel::{Ledger, Phase};
use crate::dense::Mat;
use crate::kernelfn::Kernel;
use crate::sparse::Csr;

/// Produces sampled rows of the kernel matrix `K(A, A)`.
pub trait GramOracle {
    /// Number of samples `m` (kernel-matrix dimension).
    fn m(&self) -> usize;

    /// Fill `q[r][·]` with kernel row `sample[r]`, recording costs.
    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger);

    /// `K(a_i, a_i)` for all `i` (cheap; used for SVM `η` sanity checks
    /// and objective evaluation).
    fn diag(&self) -> Vec<f64>;

    /// Communication statistics accumulated so far (zero for local).
    fn comm_stats(&self) -> CommStats {
        CommStats::default()
    }
}

/// Density below which the transpose-based gram beats the scatter-dot
/// variant (cost `f²mn` vs `fmn` per sampled row; crossover well below
/// 1.0, with slack for its worse write locality). See §Perf in
/// EXPERIMENTS.md for the measured before/after.
const TRANSPOSE_GRAM_MAX_DENSITY: f64 = 0.25;

/// Serial oracle over the full matrix.
pub struct LocalGram {
    a: Csr,
    /// Cached transpose for the sparse fast path (None for dense data).
    at: Option<Csr>,
    kernel: Kernel,
    row_norms: Vec<f64>,
    scratch: Vec<f64>,
}

impl LocalGram {
    pub fn new(a: Csr, kernel: Kernel) -> Self {
        let row_norms = a.row_norms_sq();
        let at = (a.density() < TRANSPOSE_GRAM_MAX_DENSITY).then(|| a.transpose());
        LocalGram {
            a,
            at,
            kernel,
            row_norms,
            scratch: Vec::new(),
        }
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }
}

impl GramOracle for LocalGram {
    fn m(&self) -> usize {
        self.a.nrows()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.a.nrows());
        ledger.time(Phase::KernelCompute, || {
            match &self.at {
                Some(at) => self.a.sampled_gram_t(at, sample, q),
                None => self.a.sampled_gram(sample, q, &mut self.scratch),
            }
            let sample_norms: Vec<f64> = sample.iter().map(|&i| self.row_norms[i]).collect();
            self.kernel.apply_block(q, &sample_norms, &self.row_norms);
        });
        ledger.add_flops(
            Phase::KernelCompute,
            2.0 * sample.len() as f64 * self.a.nnz() as f64
                + self.kernel.mu() * sample.len() as f64 * self.m() as f64,
        );
        ledger.add_kernel_call(sample.len());
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.m())
            .map(|i| {
                self.kernel
                    .apply_scalar(self.row_norms[i], self.row_norms[i], self.row_norms[i])
            })
            .collect()
    }
}

/// Distributed oracle: this rank holds the column shard `A_p (m × n/P)`.
///
/// The linear gram is additive over column shards,
/// `A_S Aᵀ = Σ_p A_S_p A_pᵀ`, so each rank computes its partial block,
/// the blocks are sum-allreduced, and every rank applies the nonlinear
/// map redundantly (the paper's Theorem 1/2 schedule). RBF needs full
/// row norms, which are themselves a column-shard sum — allreduced once
/// at construction.
pub struct DistGram<'c, C: Communicator> {
    shard: Csr,
    /// Cached shard transpose for the sparse fast path.
    shard_t: Option<Csr>,
    kernel: Kernel,
    /// Full-matrix row norms (allreduced at construction).
    row_norms: Vec<f64>,
    comm: &'c mut C,
    algo: AllreduceAlgo,
    scratch: Vec<f64>,
}

impl<'c, C: Communicator> DistGram<'c, C> {
    /// Build from this rank's column shard. Collective: every rank must
    /// call this at the same time (one allreduce for RBF row norms).
    pub fn new(shard: Csr, kernel: Kernel, comm: &'c mut C, algo: AllreduceAlgo) -> Self {
        let mut row_norms = shard.row_norms_sq();
        allreduce_sum(comm, &mut row_norms, algo);
        let shard_t = (shard.density() < TRANSPOSE_GRAM_MAX_DENSITY).then(|| shard.transpose());
        DistGram {
            shard,
            shard_t,
            kernel,
            row_norms,
            comm,
            algo,
            scratch: Vec::new(),
        }
    }

    pub fn rank(&self) -> usize {
        self.comm.rank()
    }
}

impl<'c, C: Communicator> GramOracle for DistGram<'c, C> {
    fn m(&self) -> usize {
        self.shard.nrows()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.shard.nrows());
        // Partial linear gram on the local shard.
        ledger.time(Phase::KernelCompute, || {
            match &self.shard_t {
                Some(at) => self.shard.sampled_gram_t(at, sample, q),
                None => self.shard.sampled_gram(sample, q, &mut self.scratch),
            }
        });
        ledger.add_flops(
            Phase::KernelCompute,
            2.0 * sample.len() as f64 * self.shard.nnz() as f64,
        );
        // Sum-reduce the partial blocks (the per-iteration allreduce the
        // s-step method amortizes).
        ledger.time(Phase::Allreduce, || {
            allreduce_sum(self.comm, q.data_mut(), self.algo);
        });
        // Redundant nonlinear map.
        ledger.time(Phase::KernelCompute, || {
            let sample_norms: Vec<f64> = sample.iter().map(|&i| self.row_norms[i]).collect();
            self.kernel.apply_block(q, &sample_norms, &self.row_norms);
        });
        ledger.add_flops(
            Phase::KernelCompute,
            self.kernel.mu() * sample.len() as f64 * self.m() as f64,
        );
        ledger.add_kernel_call(sample.len());
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.m())
            .map(|i| {
                self.kernel
                    .apply_scalar(self.row_norms[i], self.row_norms[i], self.row_norms[i])
            })
            .collect()
    }

    fn comm_stats(&self) -> CommStats {
        self.comm.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::data::gen_dense_classification;
    use crate::rng::Pcg;

    #[test]
    fn local_gram_matches_direct_kernel() {
        let ds = gen_dense_classification(20, 6, 0.0, 1);
        let d = ds.a.to_dense();
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let sample = vec![4usize, 17, 4];
            let mut q = Mat::zeros(3, 20);
            let mut ledger = Ledger::new();
            oracle.gram(&sample, &mut q, &mut ledger);
            for (r, &sr) in sample.iter().enumerate() {
                for i in 0..20 {
                    let dot = crate::dense::dot(d.row(sr), d.row(i));
                    let na = crate::dense::dot(d.row(sr), d.row(sr));
                    let nb = crate::dense::dot(d.row(i), d.row(i));
                    let expect = kernel.apply_scalar(dot, na, nb);
                    assert!(
                        (q[(r, i)] - expect).abs() < 1e-10,
                        "{kernel:?} ({r},{i})"
                    );
                }
            }
            assert!(ledger.flops(Phase::KernelCompute) > 0.0);
        }
    }

    #[test]
    fn dist_gram_equals_local_gram_all_kernels() {
        let ds = gen_dense_classification(24, 16, 0.0, 2);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut local = LocalGram::new(ds.a.clone(), kernel);
            let sample = vec![1usize, 13, 22, 7];
            let mut q_ref = Mat::zeros(4, 24);
            local.gram(&sample, &mut q_ref, &mut Ledger::new());

            for p in [2, 3, 4] {
                let shards = ds.shard_cols(p);
                let outs = run_ranks(p, |c| {
                    let shard = shards[c.rank()].clone();
                    let mut dist =
                        DistGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner);
                    let mut q = Mat::zeros(4, 24);
                    let mut ledger = Ledger::new();
                    dist.gram(&sample, &mut q, &mut ledger);
                    (q, ledger.comm)
                });
                for (q, _) in &outs {
                    for (a, b) in q.data().iter().zip(q_ref.data()) {
                        assert!((a - b).abs() < 1e-9, "{kernel:?} p={p}: {a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn dist_gram_counts_allreduce_traffic() {
        let ds = gen_dense_classification(16, 8, 0.0, 3);
        let shards = ds.shard_cols(4);
        let stats = run_ranks(4, |c| {
            let shard = shards[c.rank()].clone();
            let mut dist =
                DistGram::new(shard, Kernel::Linear, c, AllreduceAlgo::RecursiveDoubling);
            let mut q = Mat::zeros(2, 16);
            let mut ledger = Ledger::new();
            dist.gram(&[0, 5], &mut q, &mut ledger);
            dist.comm_stats()
        });
        for s in &stats {
            // 1 norm allreduce (16 words) + 1 gram allreduce (32 words),
            // recursive doubling sends w·log2(4) words each.
            assert_eq!(s.allreduces, 2);
            assert_eq!(s.words, (16 + 32) * 2);
        }
    }

    #[test]
    fn diag_matches_gram_diagonal() {
        let ds = gen_dense_classification(10, 5, 0.0, 4);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let diag = oracle.diag();
            let sample: Vec<usize> = (0..10).collect();
            let mut q = Mat::zeros(10, 10);
            oracle.gram(&sample, &mut q, &mut Ledger::new());
            for i in 0..10 {
                assert!((diag[i] - q[(i, i)]).abs() < 1e-12, "{kernel:?} diag {i}");
            }
        }
    }

    #[test]
    fn sparse_shards_preserve_gram() {
        // Sparse path: uniform sparse data, random sample, p shards.
        let ds = crate::data::gen_uniform_sparse(
            crate::data::SynthParams {
                m: 30,
                n: 200,
                density: 0.05,
                seed: 9,
            },
            crate::data::Task::Classification,
        );
        let mut rng = Pcg::seeded(5);
        let sample = rng.sample_without_replacement(30, 6);
        let kernel = Kernel::paper_rbf();
        let mut local = LocalGram::new(ds.a.clone(), kernel);
        let mut q_ref = Mat::zeros(6, 30);
        local.gram(&sample, &mut q_ref, &mut Ledger::new());
        let shards = ds.shard_cols(5);
        let outs = run_ranks(5, |c| {
            let shard = shards[c.rank()].clone();
            let mut dist = DistGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner);
            let mut q = Mat::zeros(6, 30);
            dist.gram(&sample, &mut q, &mut Ledger::new());
            q
        });
        for q in &outs {
            for (a, b) in q.data().iter().zip(q_ref.data()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
