//! Gram oracles: thin configurations of the staged gram engine
//! ([`crate::gram`]).
//!
//! * [`LocalGram`] — full matrix on one rank: CSR product → no reduction
//!   → kernel epilogue.
//! * [`DistGram`] — this rank's 1D-column shard: partial CSR product →
//!   `allreduce_sum` (real messages, real counts) → redundant kernel
//!   epilogue — exactly the communication pattern of the paper's
//!   Section 4 analysis.
//! * [`GridGram`] — one cell of a 2D `pr × pc` process grid: row-sliced
//!   partial product on this cell's feature shard → column-subcomm
//!   reduce + row-subcomm allgather → redundant kernel epilogue. The
//!   communication-avoiding refinement: the reduce collective has
//!   `pc ≪ P` participants and a `1/pr`-sized payload.
//!
//! All take an optional kernel-row cache (`with_cache`) and an
//! intra-rank worker-thread count for the product stage (`with_opts`);
//! `new` keeps the cache off and runs serially, which reproduces the
//! pre-engine cost accounting count for count. Results are bitwise
//! identical for every cache size and thread count, and a grid solve is
//! bitwise identical to the 1D solve over `pc` ranks (see
//! [`crate::gram`]).

use std::sync::Arc;

use crate::comm::{allreduce_sum, AllreduceAlgo, CommStats, Communicator};
use crate::costmodel::Ledger;
use crate::dense::Mat;
use crate::gram::{
    AllreduceSum, CsrProduct, Epilogue, FragmentSlot, GramEngine, GridProduct, GridReduce,
    GridStorage, Layout, NoReduce, OverlapMode, TRANSPOSE_GRAM_MAX_DENSITY,
};
use crate::kernelfn::Kernel;
use crate::parallel::{transpose_with_pool, ParallelProduct, WorkerPool};
use crate::sparse::Csr;

pub use crate::gram::GramOracle;

/// Serial oracle over the full matrix.
pub struct LocalGram {
    engine: GramEngine<ParallelProduct<CsrProduct>, NoReduce>,
}

impl LocalGram {
    /// Serial oracle: cache off, single-threaded product.
    pub fn new(a: Csr, kernel: Kernel) -> Self {
        Self::with_opts(a, kernel, 0, 1)
    }

    /// `cache_rows > 0` enables the deterministic kernel-row LRU cache.
    pub fn with_cache(a: Csr, kernel: Kernel, cache_rows: usize) -> Self {
        Self::with_opts(a, kernel, cache_rows, 1)
    }

    /// Full configuration: row cache (`cache_rows > 0`) and `threads`
    /// product workers (`>= 1`; the sampled rows of every gram call are
    /// split across them, bitwise-identically for every count).
    pub fn with_opts(a: Csr, kernel: Kernel, cache_rows: usize, threads: usize) -> Self {
        let epilogue = Epilogue::new(kernel, a.row_norms_sq());
        let diag = epilogue.diag();
        // Pool first: the same worker threads that will serve every gram
        // call also build the one-off cached transpose (bitwise equal to
        // the serial build at every thread count).
        assert!(threads >= 1, "ParallelProduct needs at least one thread");
        let mut pool = WorkerPool::new(threads - 1);
        let a = Arc::new(a);
        let at = (a.density() < TRANSPOSE_GRAM_MAX_DENSITY)
            .then(|| Arc::new(transpose_with_pool(&a, &mut pool)));
        let product = ParallelProduct::with_pool(CsrProduct::with_transpose(a, at), pool);
        LocalGram {
            engine: GramEngine::new(
                Layout::Full,
                product,
                NoReduce,
                Some(epilogue),
                diag,
                cache_rows,
            ),
        }
    }

    /// The configured kernel.
    pub fn kernel(&self) -> Kernel {
        self.engine.kernel().expect("local pipeline has an epilogue")
    }

    /// Read-only kernel-row cache residency probe (never touches
    /// recency); schedules cross-check their shadow replica with it.
    pub fn cache_resident(&self, row: usize) -> bool {
        self.engine.cache_resident(row)
    }
}

impl GramOracle for LocalGram {
    fn m(&self) -> usize {
        self.engine.m()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram(sample, q, ledger);
    }

    fn diag(&self) -> Vec<f64> {
        self.engine.diag()
    }
}

/// Distributed oracle: this rank holds the column shard `A_p (m × n/P)`.
///
/// The linear gram is additive over column shards,
/// `A_S Aᵀ = Σ_p A_S_p A_pᵀ`, so each rank computes its partial block,
/// the blocks are sum-allreduced, and every rank applies the nonlinear
/// map redundantly (the paper's Theorem 1/2 schedule). RBF needs full
/// row norms, which are themselves a column-shard sum — allreduced once
/// at construction.
pub struct DistGram<'c, C: Communicator> {
    engine: GramEngine<ParallelProduct<CsrProduct>, AllreduceSum<'c, C>>,
}

impl<'c, C: Communicator> DistGram<'c, C> {
    /// Build from this rank's column shard. Collective: every rank must
    /// call this at the same time (one allreduce for RBF row norms).
    pub fn new(shard: Csr, kernel: Kernel, comm: &'c mut C, algo: AllreduceAlgo) -> Self {
        Self::with_opts(shard, kernel, comm, algo, 0, 1)
    }

    /// Collective; `cache_rows` must be identical on every rank (the
    /// deterministic caches then stay in lockstep, keeping the allreduces
    /// matched — see [`crate::gram`]).
    pub fn with_cache(
        shard: Csr,
        kernel: Kernel,
        comm: &'c mut C,
        algo: AllreduceAlgo,
        cache_rows: usize,
    ) -> Self {
        Self::with_opts(shard, kernel, comm, algo, cache_rows, 1)
    }

    /// Full configuration: cache plus `threads` intra-rank workers for
    /// the partial product — the hybrid P ranks × t threads point.
    /// Unlike `cache_rows`, `threads` may differ across ranks (it
    /// changes no message and no hit/miss decision, only wall time).
    pub fn with_opts(
        shard: Csr,
        kernel: Kernel,
        comm: &'c mut C,
        algo: AllreduceAlgo,
        cache_rows: usize,
        threads: usize,
    ) -> Self {
        let (rank, ranks) = (comm.rank(), comm.size());
        let mut row_norms = shard.row_norms_sq();
        allreduce_sum(comm, &mut row_norms, algo);
        let epilogue = Epilogue::new(kernel, row_norms);
        let diag = epilogue.diag();
        // Pool-first construction, as in LocalGram: the product's own
        // workers build the shard transpose before serving gram calls.
        assert!(threads >= 1, "ParallelProduct needs at least one thread");
        let mut pool = WorkerPool::new(threads - 1);
        let shard = Arc::new(shard);
        let at = (shard.density() < TRANSPOSE_GRAM_MAX_DENSITY)
            .then(|| Arc::new(transpose_with_pool(&shard, &mut pool)));
        let product = ParallelProduct::with_pool(CsrProduct::with_transpose(shard, at), pool);
        let reduce = AllreduceSum::new(comm, algo);
        DistGram {
            engine: GramEngine::new(
                Layout::ColShard { rank, ranks },
                product,
                reduce,
                Some(epilogue),
                diag,
                cache_rows,
            ),
        }
    }

    /// This rank's id.
    pub fn rank(&self) -> usize {
        self.engine.reduce_stage().rank()
    }

    /// Read-only kernel-row cache residency probe (never touches
    /// recency); schedules cross-check their shadow replica with it.
    pub fn cache_resident(&self, row: usize) -> bool {
        self.engine.cache_resident(row)
    }

    /// Select the communication-overlap mode (default
    /// [`OverlapMode::Off`]). Must be identical on every rank.
    /// [`OverlapMode::Exchange`] is inert here (the 1D layout has no
    /// fragment exchange); [`OverlapMode::Pipeline`] makes the s-step
    /// drivers post each block's gram allreduce under the previous
    /// block's updates. Bitwise-invariant either way.
    pub fn set_overlap(&mut self, mode: OverlapMode) {
        self.engine.set_overlap(mode);
    }
}

impl<'c, C: Communicator> GramOracle for DistGram<'c, C> {
    fn m(&self) -> usize {
        self.engine.m()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram(sample, q, ledger);
    }

    fn diag(&self) -> Vec<f64> {
        self.engine.diag()
    }

    fn comm_stats(&self) -> CommStats {
        self.engine.comm_stats()
    }

    fn overlap(&self) -> OverlapMode {
        self.engine.overlap()
    }

    fn gram_start(&mut self, sample: &[usize], ledger: &mut Ledger) {
        self.engine.gram_start(sample, ledger);
    }

    fn gram_finish(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram_finish(sample, q, ledger);
    }
}

/// 2D-grid oracle: this rank is cell `(rank / pc, rank % pc)` of a
/// `pr × pc` process grid over `P = pr·pc` ranks.
///
/// The cell holds feature shard `rank % pc` — the *same* `pc`-way
/// 1D-column split the paper's layout would use over `pc` ranks — for
/// every sample, and computes partial gram entries only for the sample
/// columns its row group owns block-cyclically. The reduction then runs
/// over the column subcommunicator (`pc` ranks, payload `k·m/pr`)
/// followed by an allgather over the row subcommunicator (`pr` ranks),
/// instead of one `P`-rank allreduce of the full `k·m` block.
///
/// Determinism: bitwise identical to [`DistGram`] over `pc` ranks for
/// every `pr`, `row_block`, `cache_rows` and `threads` (see
/// [`crate::gram`]); `Grid{1, P}` reproduces the 1D path exactly.
pub struct GridGram<'c, C: Communicator> {
    engine: GramEngine<ParallelProduct<GridProduct>, GridReduce<'c, C>>,
}

impl<'c, C: Communicator> GridGram<'c, C> {
    /// Build from this cell's feature shard (`shards[rank % pc]` of a
    /// `pc`-way column split). Collective: every rank must call this at
    /// the same time (one column-subcomm allreduce for RBF row norms).
    pub fn new(
        shard: Csr,
        kernel: Kernel,
        comm: &'c mut C,
        algo: AllreduceAlgo,
        pr: usize,
        pc: usize,
    ) -> Self {
        Self::with_opts(
            shard,
            kernel,
            comm,
            algo,
            pr,
            pc,
            crate::gram::DEFAULT_ROW_BLOCK,
            GridStorage::Replicated,
            0,
            1,
        )
    }

    /// Full configuration: block-cyclic `row_block`, storage mode
    /// ([`GridStorage`] — `Sharded` keeps only this cell's row group in
    /// memory and assembles sampled rows through the per-call fragment
    /// exchange; identical on every rank), kernel-row cache
    /// (`cache_rows`, identical on every rank) and `threads` intra-rank
    /// product workers. Collective, like [`Self::new`].
    #[allow(clippy::too_many_arguments)]
    pub fn with_opts(
        shard: Csr,
        kernel: Kernel,
        comm: &'c mut C,
        algo: AllreduceAlgo,
        pr: usize,
        pc: usize,
        row_block: usize,
        storage: GridStorage,
        cache_rows: usize,
        threads: usize,
    ) -> Self {
        let m = shard.nrows();
        // One source of truth for the rank → cell map (shared with the
        // auto-tuner's plan handoff).
        let layout = Layout::grid_for_rank(pr, pc, comm.rank());
        let mut reduce = GridReduce::new(comm, algo, pr, pc, m, row_block);
        let owned_rows = reduce.owned_rows().to_vec();
        // Full row norms are a sum over the pc feature shards — the same
        // collective (and the same bits) as DistGram over pc ranks. The
        // sharded cell first *gathers* the shard-wide per-row norms from
        // the row subcommunicator (verbatim values — bitwise what the
        // full shard would compute locally), so the column allreduce
        // runs on identical inputs in both storage modes.
        // Pool-first construction, as in LocalGram: the product's own
        // workers build the owned-rows transpose before serving gram
        // calls. The path decision stays on the FULL shard's density in
        // both storage modes (the bitwise contract with the 1D product).
        assert!(threads >= 1, "ParallelProduct needs at least one thread");
        let mut pool = WorkerPool::new(threads - 1);
        let (mut row_norms, inner) = match storage {
            GridStorage::Replicated => {
                let norms = shard.row_norms_sq();
                let owned = Arc::new(shard.gather_rows(&owned_rows));
                let owned_t = (shard.density() < TRANSPOSE_GRAM_MAX_DENSITY)
                    .then(|| Arc::new(transpose_with_pool(&owned, &mut pool)));
                (
                    norms,
                    GridProduct::replicated_from_parts(Arc::new(shard), owned, owned_t),
                )
            }
            GridStorage::Sharded => {
                // Keep only the owned row group; the full shard is
                // dropped here — its density (a static scalar also
                // derivable from the exchanged nnz table) is all that
                // survives, so the product path decision matches the
                // replicated cell exactly.
                let density = shard.density();
                let owned = Arc::new(shard.gather_rows(&owned_rows));
                drop(shard);
                let slot = Arc::new(FragmentSlot::new(owned.ncols()));
                let norms = reduce.enable_sharded(owned.clone(), slot.clone());
                let owned_t = (density < TRANSPOSE_GRAM_MAX_DENSITY)
                    .then(|| Arc::new(transpose_with_pool(&owned, &mut pool)));
                (norms, GridProduct::sharded_from_parts(owned, owned_t, m, slot))
            }
        };
        reduce.allreduce_col(&mut row_norms);
        let epilogue = Epilogue::new(kernel, row_norms);
        let diag = epilogue.diag();
        let product = ParallelProduct::with_pool(inner, pool);
        GridGram {
            engine: GramEngine::new(layout, product, reduce, Some(epilogue), diag, cache_rows),
        }
    }

    /// This rank's global id.
    pub fn rank(&self) -> usize {
        self.engine.reduce_stage().rank()
    }

    /// Column-subcommunicator (reduce) traffic.
    pub fn col_stats(&self) -> CommStats {
        self.engine.reduce_stage().col_stats()
    }

    /// Row-subcommunicator (allgather) traffic.
    pub fn row_stats(&self) -> CommStats {
        self.engine.reduce_stage().row_stats()
    }

    /// Fragment-exchange traffic (sharded storage; zero for replicated
    /// cells).
    pub fn exch_stats(&self) -> CommStats {
        self.engine.reduce_stage().exch_stats()
    }

    /// Resident stored entries of this cell's data: the full feature
    /// shard (replicated — the owned rows are a subset of it) or just
    /// the owned row group (sharded) — the number the memory model's
    /// data term counts.
    pub fn resident_nnz(&self) -> usize {
        let inner = self.engine.product().inner();
        match inner.shard() {
            Some(shard) => shard.nnz(),
            None => inner.owned_nnz(),
        }
    }

    /// Read-only kernel-row cache residency probe (never touches
    /// recency); schedules cross-check their shadow replica with it.
    pub fn cache_resident(&self, row: usize) -> bool {
        self.engine.cache_resident(row)
    }

    /// Select the communication-overlap mode (default
    /// [`OverlapMode::Off`]). Must be identical on every rank.
    /// [`OverlapMode::Exchange`] overlaps the sharded storage's fragment
    /// ring with the owned-rows product pass (inert for replicated
    /// cells); [`OverlapMode::Pipeline`] makes the s-step drivers post
    /// each block's column reduce under the previous block's updates.
    /// Bitwise-invariant either way.
    pub fn set_overlap(&mut self, mode: OverlapMode) {
        self.engine.set_overlap(mode);
    }
}

impl<'c, C: Communicator> GramOracle for GridGram<'c, C> {
    fn m(&self) -> usize {
        self.engine.m()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram(sample, q, ledger);
    }

    fn diag(&self) -> Vec<f64> {
        self.engine.diag()
    }

    fn comm_stats(&self) -> CommStats {
        self.engine.comm_stats()
    }

    fn overlap(&self) -> OverlapMode {
        self.engine.overlap()
    }

    fn gram_start(&mut self, sample: &[usize], ledger: &mut Ledger) {
        self.engine.gram_start(sample, ledger);
    }

    fn gram_finish(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram_finish(sample, q, ledger);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_ranks;
    use crate::costmodel::Phase;
    use crate::data::gen_dense_classification;
    use crate::rng::Pcg;

    #[test]
    fn local_gram_matches_direct_kernel() {
        let ds = gen_dense_classification(20, 6, 0.0, 1);
        let d = ds.a.to_dense();
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let sample = vec![4usize, 17, 4];
            let mut q = Mat::zeros(3, 20);
            let mut ledger = Ledger::new();
            oracle.gram(&sample, &mut q, &mut ledger);
            for (r, &sr) in sample.iter().enumerate() {
                for i in 0..20 {
                    let dot = crate::dense::dot(d.row(sr), d.row(i));
                    let na = crate::dense::dot(d.row(sr), d.row(sr));
                    let nb = crate::dense::dot(d.row(i), d.row(i));
                    let expect = kernel.apply_scalar(dot, na, nb);
                    assert!(
                        (q[(r, i)] - expect).abs() < 1e-10,
                        "{kernel:?} ({r},{i})"
                    );
                }
            }
            assert!(ledger.flops(Phase::KernelCompute) > 0.0);
        }
    }

    #[test]
    fn dist_gram_equals_local_gram_all_kernels() {
        let ds = gen_dense_classification(24, 16, 0.0, 2);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut local = LocalGram::new(ds.a.clone(), kernel);
            let sample = vec![1usize, 13, 22, 7];
            let mut q_ref = Mat::zeros(4, 24);
            local.gram(&sample, &mut q_ref, &mut Ledger::new());

            for p in [2, 3, 4] {
                let shards = ds.shard_cols(p);
                let outs = run_ranks(p, |c| {
                    let shard = shards[c.rank()].clone();
                    let mut dist =
                        DistGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner);
                    let mut q = Mat::zeros(4, 24);
                    let mut ledger = Ledger::new();
                    dist.gram(&sample, &mut q, &mut ledger);
                    (q, ledger.comm)
                });
                for (q, _) in &outs {
                    for (a, b) in q.data().iter().zip(q_ref.data()) {
                        assert!((a - b).abs() < 1e-9, "{kernel:?} p={p}: {a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn dist_gram_counts_allreduce_traffic() {
        let ds = gen_dense_classification(16, 8, 0.0, 3);
        let shards = ds.shard_cols(4);
        let stats = run_ranks(4, |c| {
            let shard = shards[c.rank()].clone();
            let mut dist =
                DistGram::new(shard, Kernel::Linear, c, AllreduceAlgo::RecursiveDoubling);
            let mut q = Mat::zeros(2, 16);
            let mut ledger = Ledger::new();
            dist.gram(&[0, 5], &mut q, &mut ledger);
            dist.comm_stats()
        });
        for s in &stats {
            // 1 norm allreduce (16 words) + 1 gram allreduce (32 words),
            // recursive doubling sends w·log2(4) words each.
            assert_eq!(s.allreduces, 2);
            assert_eq!(s.words, (16 + 32) * 2);
        }
    }

    /// Ledger sanity for the cache: hits must reduce the *measured*
    /// `CommStats::words` by exactly the avoided row-sized allreduce
    /// payloads (× the collective's per-rank word factor), skip whole
    /// allreduces on full hits, and leave the block values bitwise
    /// unchanged.
    #[test]
    fn cache_hits_reduce_measured_allreduce_words_exactly() {
        let ds = gen_dense_classification(16, 8, 0.0, 3);
        let m = 16u64;
        let shards = ds.shard_cols(4);
        let run = |cache_rows: usize| {
            let shards = shards.clone();
            run_ranks(4, move |c| {
                let shard = shards[c.rank()].clone();
                let mut dist = DistGram::with_cache(
                    shard,
                    Kernel::Linear,
                    c,
                    AllreduceAlgo::RecursiveDoubling,
                    cache_rows,
                );
                let mut ledger = Ledger::new();
                let mut q1 = Mat::zeros(2, 16);
                dist.gram(&[0, 5], &mut q1, &mut ledger); // cold: 2 misses
                let mut q2 = Mat::zeros(2, 16);
                dist.gram(&[0, 5], &mut q2, &mut ledger); // warm: 2 hits
                let mut q3 = Mat::zeros(2, 16);
                dist.gram(&[5, 7], &mut q3, &mut ledger); // mixed: 1 hit, 1 miss
                (dist.comm_stats(), ledger.cache, q1, q2, q3)
            })
        };
        let uncached = run(0);
        let cached = run(8);
        // Recursive doubling over P=4 sends payload·log2(4) = 2·payload
        // words per rank per allreduce.
        for ((su, cu, u1, u2, u3), (sc, cc, c1, c2, c3)) in
            uncached.iter().zip(&cached)
        {
            assert_eq!(cu.hits, 0);
            assert_eq!(cc.hits, 3);
            assert_eq!(cc.misses, 3);
            // Payload words avoided: m per hit row.
            assert_eq!(cc.words_saved, 3 * m);
            assert_eq!(cc.bytes_saved(), 3 * m * 8);
            // Warm call skipped its allreduce entirely.
            assert_eq!(cc.allreduces_saved, 1);
            assert_eq!(su.allreduces - sc.allreduces, 1);
            // Measured wire words drop by exactly payload × factor.
            assert_eq!(su.words - sc.words, cc.words_saved * 2);
            assert_eq!(cu.words_saved, 0);
            // And the served rows are bitwise identical.
            assert_eq!(u1.data(), c1.data());
            assert_eq!(u2.data(), c2.data());
            assert_eq!(u3.data(), c3.data());
        }
    }

    #[test]
    fn cached_dist_gram_is_bitwise_equal_across_algorithms() {
        let ds = gen_dense_classification(24, 16, 0.0, 9);
        let kernel = Kernel::paper_rbf();
        for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::Linear] {
            for p in [2usize, 3, 4] {
                let shards = ds.shard_cols(p);
                let run = |cache_rows: usize| {
                    let shards = shards.clone();
                    run_ranks(p, move |c| {
                        let shard = shards[c.rank()].clone();
                        let mut dist =
                            DistGram::with_cache(shard, kernel, c, algo, cache_rows);
                        let mut rng = Pcg::seeded(77);
                        let mut out = Vec::new();
                        for _ in 0..12 {
                            let k = rng.gen_range(1, 5);
                            let sample: Vec<usize> =
                                (0..k).map(|_| rng.gen_below(24)).collect();
                            let mut q = Mat::zeros(k, 24);
                            dist.gram(&sample, &mut q, &mut Ledger::new());
                            out.extend_from_slice(q.data());
                        }
                        out
                    })
                };
                let plain = run(0);
                let cached = run(6);
                for (a, b) in plain.iter().zip(&cached) {
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x, y, "{algo:?} p={p}");
                    }
                }
            }
        }
    }

    /// `threads` is a per-rank-local knob: unlike `cache_rows` it may
    /// differ across ranks without desyncing the collectives, and the
    /// blocks stay bitwise identical to the all-serial run.
    #[test]
    fn dist_gram_threads_may_differ_across_ranks() {
        let ds = gen_dense_classification(20, 8, 0.0, 6);
        let kernel = Kernel::paper_rbf();
        let shards = ds.shard_cols(3);
        let sample = vec![4usize, 11, 4, 0];
        let run = |threads_of: fn(usize) -> usize| {
            let shards = shards.clone();
            let sample = &sample;
            run_ranks(3, move |c| {
                let shard = shards[c.rank()].clone();
                let mut dist = DistGram::with_opts(
                    shard,
                    kernel,
                    c,
                    AllreduceAlgo::Rabenseifner,
                    0,
                    threads_of(c.rank()),
                );
                let mut q = Mat::zeros(4, 20);
                dist.gram(sample, &mut q, &mut Ledger::new());
                q
            })
        };
        let serial = run(|_| 1);
        let mixed = run(|rank| rank + 1); // t = 1, 2, 3 per rank
        for (a, b) in serial.iter().zip(&mixed) {
            assert_eq!(a.data(), b.data());
        }
    }

    /// Grid oracle ground truth: blocks (and diag) match the serial
    /// oracle to tolerance for every kernel and factorization, and the
    /// reduce collective runs over pc ranks only.
    #[test]
    fn grid_gram_matches_local_gram_all_kernels() {
        let ds = gen_dense_classification(24, 16, 0.0, 2);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut local = LocalGram::new(ds.a.clone(), kernel);
            let sample = vec![1usize, 13, 22, 7];
            let mut q_ref = Mat::zeros(4, 24);
            local.gram(&sample, &mut q_ref, &mut Ledger::new());
            let diag_ref = local.diag();

            for (pr, pc) in [(2usize, 2usize), (3, 2), (2, 3), (4, 1), (1, 4)] {
                let shards = ds.shard_cols(pc);
                let outs = run_ranks(pr * pc, |c| {
                    let shard = shards[c.rank() % pc].clone();
                    let mut grid =
                        GridGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner, pr, pc);
                    let mut q = Mat::zeros(4, 24);
                    grid.gram(&sample, &mut q, &mut Ledger::new());
                    (q, grid.diag(), grid.col_stats(), grid.row_stats())
                });
                for (q, diag, col, row) in &outs {
                    for (a, b) in q.data().iter().zip(q_ref.data()) {
                        assert!((a - b).abs() < 1e-9, "{kernel:?} {pr}x{pc}: {a} vs {b}");
                    }
                    for (a, b) in diag.iter().zip(&diag_ref) {
                        assert!((a - b).abs() < 1e-9, "{kernel:?} {pr}x{pc} diag");
                    }
                    if pc > 1 {
                        assert!(col.words > 0, "{pr}x{pc}: reduce must move words");
                    } else {
                        assert_eq!(col.words, 0, "{pr}x{pc}: single-shard reduce is free");
                    }
                    if pr > 1 {
                        assert!(row.words > 0, "{pr}x{pc}: allgather must move words");
                    } else {
                        assert_eq!(row.words, 0);
                    }
                }
            }
        }
    }

    /// The grid determinism contract at the oracle level: for every
    /// factorization, the grid block replays the bits of the 1D DistGram
    /// block over pc ranks (and of the serial oracle when pc = 1).
    #[test]
    fn grid_gram_is_bitwise_equal_to_1d_over_pc_ranks() {
        let ds = gen_dense_classification(24, 16, 0.0, 9);
        let kernel = Kernel::paper_rbf();
        let stream: Vec<Vec<usize>> = {
            let mut rng = Pcg::seeded(123);
            (0..8)
                .map(|_| {
                    let k = rng.gen_range(1, 5);
                    (0..k).map(|_| rng.gen_below(24)).collect()
                })
                .collect()
        };
        let run_1d = |p: usize| -> Vec<f64> {
            if p == 1 {
                let mut local = LocalGram::new(ds.a.clone(), kernel);
                let mut out = Vec::new();
                for sample in &stream {
                    let mut q = Mat::zeros(sample.len(), 24);
                    local.gram(sample, &mut q, &mut Ledger::new());
                    out.extend_from_slice(q.data());
                }
                return out;
            }
            let shards = ds.shard_cols(p);
            let outs = run_ranks(p, |c| {
                let shard = shards[c.rank()].clone();
                let mut dist = DistGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner);
                let mut out = Vec::new();
                for sample in &stream {
                    let mut q = Mat::zeros(sample.len(), 24);
                    dist.gram(sample, &mut q, &mut Ledger::new());
                    out.extend_from_slice(q.data());
                }
                out
            });
            outs.into_iter().next().unwrap()
        };
        for (pr, pc) in [(1usize, 3usize), (2, 1), (2, 2), (3, 2), (2, 4), (4, 2)] {
            let reference = run_1d(pc);
            let shards = ds.shard_cols(pc);
            let outs = run_ranks(pr * pc, |c| {
                let shard = shards[c.rank() % pc].clone();
                let mut grid =
                    GridGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner, pr, pc);
                let mut out = Vec::new();
                for sample in &stream {
                    let mut q = Mat::zeros(sample.len(), 24);
                    grid.gram(sample, &mut q, &mut Ledger::new());
                    out.extend_from_slice(q.data());
                }
                out
            });
            for (rank, out) in outs.iter().enumerate() {
                assert_eq!(out, &reference, "{pr}x{pc} rank {rank} must replay 1D@{pc} bits");
            }
        }
    }

    #[test]
    fn diag_matches_gram_diagonal() {
        let ds = gen_dense_classification(10, 5, 0.0, 4);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let diag = oracle.diag();
            let sample: Vec<usize> = (0..10).collect();
            let mut q = Mat::zeros(10, 10);
            oracle.gram(&sample, &mut q, &mut Ledger::new());
            for i in 0..10 {
                assert!((diag[i] - q[(i, i)]).abs() < 1e-12, "{kernel:?} diag {i}");
            }
        }
    }

    #[test]
    fn sparse_shards_preserve_gram() {
        // Sparse path: uniform sparse data, random sample, p shards.
        let ds = crate::data::gen_uniform_sparse(
            crate::data::SynthParams {
                m: 30,
                n: 200,
                density: 0.05,
                seed: 9,
            },
            crate::data::Task::Classification,
        );
        let mut rng = Pcg::seeded(5);
        let sample = rng.sample_without_replacement(30, 6);
        let kernel = Kernel::paper_rbf();
        let mut local = LocalGram::new(ds.a.clone(), kernel);
        let mut q_ref = Mat::zeros(6, 30);
        local.gram(&sample, &mut q_ref, &mut Ledger::new());
        let shards = ds.shard_cols(5);
        let outs = run_ranks(5, |c| {
            let shard = shards[c.rank()].clone();
            let mut dist = DistGram::new(shard, kernel, c, AllreduceAlgo::Rabenseifner);
            let mut q = Mat::zeros(6, 30);
            dist.gram(&sample, &mut q, &mut Ledger::new());
            q
        });
        for q in &outs {
            for (a, b) in q.data().iter().zip(q_ref.data()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
