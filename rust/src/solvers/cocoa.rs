//! CoCoA baseline (Jaggi et al. 2014) — the divide-and-conquer
//! related-work family the paper contrasts against (§2).
//!
//! CoCoA partitions *samples* across `K` workers; each worker runs local
//! dual coordinate descent against a stale shared primal vector and the
//! updates are averaged once per round. Communication drops to one
//! reduce per round, but — unlike the s-step methods — the iterates are
//! *not* equivalent to the sequential algorithm: more local work per
//! round degrades per-update progress (the convergence–performance
//! trade-off the paper's approach avoids). The `ablation_cocoa` bench
//! quantifies exactly that contrast at equal communication budgets.
//!
//! Scope: linear-kernel K-SVM (CoCoA's shared state is the primal
//! `w = Σ α_i y_i a_i ∈ R^n`, which only exists for the linear kernel —
//! the same reason the paper's kernel methods need a different
//! communication structure in the first place).

use crate::costmodel::{Ledger, Phase};
use crate::data::Dataset;
use crate::rng::Pcg;

use super::dcd::SvmVariant;

/// CoCoA configuration.
#[derive(Clone, Copy, Debug)]
pub struct CocoaParams {
    /// Number of workers (sample partitions).
    pub k_workers: usize,
    /// Communication rounds.
    pub rounds: usize,
    /// Local DCD iterations per worker per round.
    pub local_iters: usize,
    /// Soft-margin penalty `C`.
    pub c: f64,
    /// Hinge or squared-hinge loss.
    pub variant: SvmVariant,
    /// Coordinate-stream seed.
    pub seed: u64,
}

/// Result of a CoCoA run.
pub struct CocoaResult {
    /// Final averaged dual solution.
    pub alpha: Vec<f64>,
    /// Shared primal vector `w`.
    pub w: Vec<f64>,
    /// One entry per round: the α snapshot after the reduce (for
    /// convergence-vs-communication plots).
    pub round_alphas: Vec<Vec<f64>>,
}

/// Run CoCoA (averaging variant) for linear K-SVM.
pub fn cocoa_svm(ds: &Dataset, p: &CocoaParams, ledger: &mut Ledger) -> CocoaResult {
    let m = ds.m();
    let n = ds.n();
    assert!(p.k_workers >= 1 && p.k_workers <= m);
    let (nu, omega) = p.variant.nu_omega(p.c);
    let scale = 1.0 / p.k_workers as f64;

    // Static row partition (contiguous blocks, like CoCoA's Spark
    // partitions).
    let bounds: Vec<usize> = (0..=p.k_workers)
        .map(|k| k * m / p.k_workers)
        .collect();
    let row_norms = ds.a.row_norms_sq();

    let mut alpha = vec![0.0; m];
    let mut w = vec![0.0; n];
    let mut rng = Pcg::new(p.seed, 0xC0C0);
    let mut round_alphas = Vec::with_capacity(p.rounds);

    for _round in 0..p.rounds {
        // Each worker solves its local subproblem from the same shared w.
        let mut delta_alpha = vec![0.0; m];
        let mut delta_w_total = vec![0.0; n];
        for k in 0..p.k_workers {
            let (lo, hi) = (bounds[k], bounds[k + 1]);
            if lo == hi {
                continue;
            }
            let mut local_w = w.clone();
            let mut worker_rng = rng.fork(k as u64);
            ledger.time(Phase::Solve, || {
                for _ in 0..p.local_iters {
                    let i = lo + worker_rng.gen_below(hi - lo);
                    // Linear-kernel DCD step against the local view.
                    let (cols, vals) = ds.a.row_parts(i);
                    let mut dot = 0.0;
                    for (&j, &v) in cols.iter().zip(vals) {
                        dot += v * local_w[j];
                    }
                    let a_i = alpha[i] + delta_alpha[i];
                    let g = ds.y[i] * dot - 1.0 + omega * a_i;
                    let eta = row_norms[i] + omega;
                    let proj = (a_i - g).clamp(0.0, nu) - a_i;
                    let theta = if proj != 0.0 {
                        (a_i - g / eta).clamp(0.0, nu) - a_i
                    } else {
                        0.0
                    };
                    if theta != 0.0 {
                        delta_alpha[i] += theta;
                        let yt = ds.y[i] * theta;
                        for (&j, &v) in cols.iter().zip(vals) {
                            local_w[j] += yt * v;
                            delta_w_total[j] += yt * v;
                        }
                    }
                }
            });
            ledger.add_flops(
                Phase::Solve,
                (p.local_iters * (4 * ds.a.nnz() / m + 8)) as f64,
            );
        }
        // Averaging reduce: α += (1/K)Δα, w += (1/K)ΣΔw. One allreduce of
        // n words per round (the whole point of the scheme).
        ledger.time(Phase::Update, || {
            for (a, d) in alpha.iter_mut().zip(&delta_alpha) {
                *a += scale * d;
            }
            for (wj, d) in w.iter_mut().zip(&delta_w_total) {
                *wj += scale * d;
            }
        });
        ledger.comm.allreduces += 1;
        ledger.comm.words += n as u64;
        ledger.comm.rounds += (p.k_workers as f64).log2().ceil() as u64;
        round_alphas.push(alpha.clone());
    }
    CocoaResult {
        alpha,
        w,
        round_alphas,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_classification;
    use crate::kernelfn::Kernel;
    use crate::solvers::objective::SvmObjective;
    use crate::solvers::LocalGram;

    fn setup() -> (Dataset, SvmObjective) {
        let ds = gen_dense_classification(60, 10, 0.05, 4242);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::Linear);
        let obj = SvmObjective::new(&mut oracle, &ds.y, 1.0, SvmVariant::L1);
        (ds, obj)
    }

    #[test]
    fn cocoa_converges_with_one_worker() {
        // K = 1 is plain DCD: must reach a near-optimal objective.
        let (ds, obj) = setup();
        let p = CocoaParams {
            k_workers: 1,
            rounds: 40,
            local_iters: 60,
            c: 1.0,
            variant: SvmVariant::L1,
            seed: 1,
        };
        let res = cocoa_svm(&ds, &p, &mut Ledger::new());
        let gap = obj.duality_gap(&res.alpha);
        assert!(gap < 0.2 * 60.0, "gap {gap} (α=0 gap is 60)");
    }

    #[test]
    fn cocoa_alpha_in_box_and_w_consistent() {
        let (ds, _) = setup();
        let p = CocoaParams {
            k_workers: 4,
            rounds: 10,
            local_iters: 30,
            c: 0.5,
            variant: SvmVariant::L1,
            seed: 2,
        };
        let res = cocoa_svm(&ds, &p, &mut Ledger::new());
        for &a in &res.alpha {
            assert!((-1e-12..=0.5 + 1e-12).contains(&a));
        }
        // w must equal Σ α_i y_i a_i.
        let mut w_expect = vec![0.0; ds.n()];
        for i in 0..ds.m() {
            let c = res.alpha[i] * ds.y[i];
            for (j, v) in ds.a.row_iter(i) {
                w_expect[j] += c * v;
            }
        }
        crate::testkit::assert_close(&res.w, &w_expect, 1e-9, "w identity");
    }

    #[test]
    fn more_local_work_trades_convergence_for_communication() {
        // The related-work trade-off: at an equal number of *updates*,
        // heavy local work with few rounds must end with a worse
        // objective than light local work with many rounds.
        let (ds, obj) = setup();
        let total_updates = 1600;
        let gap_at = |rounds: usize, local: usize| {
            let p = CocoaParams {
                k_workers: 8,
                rounds,
                local_iters: local,
                c: 1.0,
                variant: SvmVariant::L1,
                seed: 3,
            };
            let res = cocoa_svm(&ds, &p, &mut Ledger::new());
            obj.duality_gap(&res.alpha)
        };
        let many_rounds = gap_at(total_updates / (8 * 10), 10);
        let few_rounds = gap_at(total_updates / (8 * 100), 100);
        assert!(
            many_rounds < few_rounds,
            "CoCoA should degrade with more local work: {many_rounds} vs {few_rounds}"
        );
    }

    #[test]
    fn communication_counted_once_per_round() {
        let (ds, _) = setup();
        let mut ledger = Ledger::new();
        let p = CocoaParams {
            k_workers: 4,
            rounds: 7,
            local_iters: 5,
            c: 1.0,
            variant: SvmVariant::L1,
            seed: 4,
        };
        cocoa_svm(&ds, &p, &mut ledger);
        assert_eq!(ledger.comm.allreduces, 7);
        assert_eq!(ledger.comm.words, 7 * ds.n() as u64);
    }
}
