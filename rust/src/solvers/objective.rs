//! K-SVM objectives: dual value, primal value, duality gap, accuracy.
//!
//! The convergence experiments (Figure 1) plot the duality gap
//! `P(w(α)) − D(α)` where `D` is the maximized Lagrangian dual and `P`
//! the primal soft-margin objective evaluated at the primal point
//! recovered from `α`. Both are computed from the y-scaled kernel matrix
//! `Q̃ = diag(y)·K·diag(y)` which is materialized once (m² — convergence
//! datasets only, as in the paper's MATLAB study).

use crate::dense::{gemv, Mat};

use super::dcd::SvmVariant;
use super::krr_exact::full_kernel_matrix;
use super::GramOracle;

/// Cached-kernel objective evaluator for K-SVM.
pub struct SvmObjective {
    /// `Q̃ = diag(y) K diag(y)`.
    qt: Mat,
    c: f64,
    variant: SvmVariant,
    m: usize,
}

impl SvmObjective {
    /// Materialize `Q̃` through the oracle (O(m²) memory).
    pub fn new<O: GramOracle>(oracle: &mut O, y: &[f64], c: f64, variant: SvmVariant) -> Self {
        let m = oracle.m();
        assert_eq!(y.len(), m);
        let mut qt = full_kernel_matrix(oracle);
        for i in 0..m {
            let yi = y[i];
            for (j, v) in qt.row_mut(i).iter_mut().enumerate() {
                *v *= yi * y[j];
            }
        }
        SvmObjective { qt, c, variant, m }
    }

    /// The *minimized* dual objective of Section 3.1:
    /// `1/2 αᵀQ̃α − Σα (+ 1/(4C)·Σα² for L2)`. Zero at `α = 0`, negative
    /// once the solver makes progress.
    pub fn dual_min_value(&self, alpha: &[f64]) -> f64 {
        assert_eq!(alpha.len(), self.m);
        let mut qa = vec![0.0; self.m];
        gemv(&self.qt, alpha, &mut qa);
        let quad: f64 = 0.5 * crate::dense::dot(alpha, &qa);
        let lin: f64 = alpha.iter().sum();
        let reg = match self.variant {
            SvmVariant::L1 => 0.0,
            SvmVariant::L2 => alpha.iter().map(|a| a * a).sum::<f64>() / (4.0 * self.c),
        };
        quad - lin + reg
    }

    /// The maximized dual `D(α) = −dual_min_value(α)`.
    pub fn dual_value(&self, alpha: &[f64]) -> f64 {
        -self.dual_min_value(alpha)
    }

    /// Primal soft-margin objective at the primal point recovered from
    /// `α`: `1/2‖w‖² + C Σ loss(1 − y_i f(x_i))` with hinge (L1) or
    /// squared hinge (L2); `‖w‖² = αᵀQ̃α`, `y_i f(x_i) = (Q̃α)_i`.
    pub fn primal_value(&self, alpha: &[f64]) -> f64 {
        assert_eq!(alpha.len(), self.m);
        let mut qa = vec![0.0; self.m];
        gemv(&self.qt, alpha, &mut qa);
        let wnorm2 = crate::dense::dot(alpha, &qa);
        let loss: f64 = qa
            .iter()
            .map(|&margin| {
                let xi = (1.0 - margin).max(0.0);
                match self.variant {
                    SvmVariant::L1 => xi,
                    SvmVariant::L2 => xi * xi,
                }
            })
            .sum();
        0.5 * wnorm2 + self.c * loss
    }

    /// Duality gap `P(α) − D(α) ≥ 0`; approaches 0 at the optimum.
    pub fn duality_gap(&self, alpha: &[f64]) -> f64 {
        self.primal_value(alpha) - self.dual_value(alpha)
    }

    /// Training accuracy of the decision function implied by `α`
    /// (`sign(f(x_i))` vs `y_i`; `y_i f(x_i) = (Q̃α)_i > 0` ⇔ correct).
    pub fn train_accuracy(&self, alpha: &[f64]) -> f64 {
        let mut qa = vec![0.0; self.m];
        gemv(&self.qt, alpha, &mut qa);
        let correct = qa.iter().filter(|&&v| v > 0.0).count();
        correct as f64 / self.m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Ledger;
    use crate::data::gen_dense_classification;
    use crate::kernelfn::Kernel;
    use crate::solvers::{dcd, LocalGram, SvmParams};

    fn run(variant: SvmVariant, h: usize) -> (SvmObjective, Vec<f64>) {
        let ds = gen_dense_classification(50, 8, 0.05, 31);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let p = SvmParams {
            c: 1.0,
            variant,
            h,
            seed: 17,
        };
        let alpha = dcd(&mut oracle, &ds.y, &p, &mut Ledger::new(), None);
        let obj = SvmObjective::new(&mut oracle, &ds.y, p.c, variant);
        (obj, alpha)
    }

    #[test]
    fn gap_nonnegative_and_decreasing() {
        for variant in [SvmVariant::L1, SvmVariant::L2] {
            let (obj, _) = run(variant, 0);
            let ds = gen_dense_classification(50, 8, 0.05, 31);
            let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
            let mut gaps = Vec::new();
            let mut cb = |k: usize, a: &[f64]| {
                if k % 100 == 0 {
                    gaps.push(obj.duality_gap(a));
                }
            };
            let p = SvmParams {
                c: 1.0,
                variant,
                h: 1500,
                seed: 17,
            };
            dcd(&mut oracle, &ds.y, &p, &mut Ledger::new(), Some(&mut cb));
            assert!(gaps.iter().all(|&g| g >= -1e-9), "{variant:?}: gap negative");
            let first = gaps.first().copied().unwrap();
            let last = gaps.last().copied().unwrap();
            assert!(
                last < first * 0.5,
                "{variant:?}: gap should shrink substantially: {first} → {last}"
            );
        }
    }

    #[test]
    fn gap_zero_at_alpha_zero_is_primal_at_zero() {
        // At α = 0: D = 0 and P = C·Σ loss(1) = C·m (L1) — gap = C·m.
        let (obj, _) = run(SvmVariant::L1, 0);
        let alpha = vec![0.0; 50];
        assert!((obj.duality_gap(&alpha) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn solved_model_classifies_training_data() {
        let (obj, alpha) = run(SvmVariant::L1, 3000);
        let acc = obj.train_accuracy(&alpha);
        // RBF kernel with C=1 on 50 points with 5% label noise: should fit
        // most of the data.
        assert!(acc > 0.85, "training accuracy {acc}");
    }

    #[test]
    fn l2_dual_includes_regularizer() {
        let (obj_l2, _) = run(SvmVariant::L2, 0);
        let alpha = vec![0.1; 50];
        let (obj_l1, _) = run(SvmVariant::L1, 0);
        // Same Q̃, same α: L2's minimized dual exceeds L1's by Σα²/(4C).
        let diff = obj_l2.dual_min_value(&alpha) - obj_l1.dual_min_value(&alpha);
        let expect = 50.0 * 0.01 / 4.0;
        assert!((diff - expect).abs() < 1e-9);
    }
}
