//! Algorithms 1 and 2: (s-step) Dual Coordinate Descent for kernel SVM.

use crate::costmodel::{Ledger, Phase};
use crate::dense::Mat;
use crate::gram::OverlapMode;
use crate::schedule::{Schedule, Uniform};

use super::{GramOracle, Trace};

/// PCG stream id of the SVM coordinate-selection sequence, shared by
/// [`dcd`] and [`dcd_sstep`] (same seed ⇒ same coordinates) — and by
/// the analytic fragment-exchange replica
/// (`coordinator::scaling::gram_call_samples`), which must replay the
/// exact sample stream to count the sharded grid layout's per-call
/// exchange traffic.
pub const SVM_COORD_STREAM: u64 = 0x5D;

/// Hinge-loss variant: `L1` (hinge) or `L2` (squared hinge).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmVariant {
    /// Hinge loss.
    L1,
    /// Squared-hinge loss.
    L2,
}

impl SvmVariant {
    /// `(ν, ω)` from Algorithm 1 line 2: `ν = C, ω = 0` for L1;
    /// `ν = ∞, ω = 1/(2C)` for L2.
    pub fn nu_omega(&self, c: f64) -> (f64, f64) {
        match self {
            SvmVariant::L1 => (c, 0.0),
            SvmVariant::L2 => (f64::INFINITY, 1.0 / (2.0 * c)),
        }
    }

    /// Report tag (`l1` / `l2`).
    pub fn name(&self) -> &'static str {
        match self {
            SvmVariant::L1 => "l1",
            SvmVariant::L2 => "l2",
        }
    }
}

/// K-SVM solver parameters.
#[derive(Clone, Copy, Debug)]
pub struct SvmParams {
    /// Soft-margin penalty `C`.
    pub c: f64,
    /// Hinge or squared-hinge loss.
    pub variant: SvmVariant,
    /// Total (inner) iterations `H`.
    pub h: usize,
    /// Seed for the coordinate-selection stream. DCD and s-step DCD draw
    /// the same sequence from the same seed, which is what makes them
    /// comparable iteration-for-iteration.
    pub seed: u64,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 1000,
            seed: 0xDC0D,
        }
    }
}

/// Scale kernel row `r` for sample `i_r`: `q[r][i] ← y_{i_r}·y_i·q[r][i]`
/// (the `diag(y)·K·diag(y)` dual operator).
#[inline]
fn yscale_rows(q: &mut Mat, sample: &[usize], y: &[f64]) {
    for (r, &sr) in sample.iter().enumerate() {
        let ys = y[sr];
        for (v, &yi) in q.row_mut(r).iter_mut().zip(y) {
            *v *= ys * yi;
        }
    }
}

/// The single-coordinate subproblem (Algorithm 1 lines 10–15): given the
/// current coordinate value `a_i`, gradient `g`, curvature `η` and bound
/// `ν`, return the step `θ`.
#[inline]
fn coordinate_step(a_i: f64, g: f64, eta: f64, nu: f64) -> f64 {
    let proj_g = (a_i - g).clamp(0.0, nu) - a_i;
    if proj_g != 0.0 {
        (a_i - g / eta).clamp(0.0, nu) - a_i
    } else {
        0.0
    }
}

/// Algorithm 1: DCD for K-SVM (L1/L2). Returns the dual solution `α_H`.
///
/// `oracle` produces *unscaled* kernel rows `K(a_i, ·)`; the `y` scaling
/// is applied here (see the module note in [`super`]).
pub fn dcd<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &SvmParams,
    ledger: &mut Ledger,
    trace: Trace,
) -> Vec<f64> {
    let mut sched = Uniform::new(oracle.m(), p.seed, SVM_COORD_STREAM);
    dcd_with_schedule(oracle, y, p, &mut sched, ledger, trace)
}

/// [`dcd`] drawing its coordinates through an explicit [`Schedule`]
/// instead of the built-in uniform stream. With a
/// [`Uniform`] schedule on `(p.seed, SVM_COORD_STREAM)` this is
/// bitwise-identical to [`dcd`]; other schedules change *which*
/// coordinates are visited (and therefore the iterates), never the
/// update arithmetic.
pub fn dcd_with_schedule<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &SvmParams,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let (nu, omega) = p.variant.nu_omega(p.c);
    let mut alpha = vec![0.0; m];
    let mut u = Mat::zeros(1, m);
    let mut sample = Vec::with_capacity(1);

    for k in 0..p.h {
        sched.next_call(1, 1, &mut sample);
        let ik = sample[0];
        // u_k = K(A, a_ik), then y-scaled.
        oracle.gram(&[ik], &mut u, ledger);
        ledger.time(Phase::KernelCompute, || {
            yscale_rows(&mut u, &[ik], y);
        });
        ledger.add_flops(Phase::KernelCompute, 2.0 * m as f64);

        let theta = ledger.time(Phase::Solve, || {
            let urow = u.row(0);
            let eta = urow[ik] + omega;
            let g = crate::dense::dot(urow, &alpha) - 1.0 + omega * alpha[ik];
            coordinate_step(alpha[ik], g, eta, nu)
        });
        ledger.add_flops(Phase::Solve, 2.0 * m as f64 + 4.0);

        ledger.time(Phase::Update, || {
            alpha[ik] += theta;
        });
        ledger.add_flops(Phase::Update, 1.0);

        if let Some(t) = trace.as_deref_mut() {
            t(k + 1, &alpha);
        }
    }
    ledger.iters += p.h as f64;
    alpha
}

/// Algorithm 2: s-step DCD for K-SVM. Mathematically equivalent to
/// [`dcd`] with the same seed (same coordinate sequence), but computes
/// `s` kernel rows per outer iteration — one allreduce per `s` updates in
/// the distributed setting.
pub fn dcd_sstep<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &SvmParams,
    s: usize,
    ledger: &mut Ledger,
    trace: Trace,
) -> Vec<f64> {
    let mut sched = Uniform::new(oracle.m(), p.seed, SVM_COORD_STREAM);
    dcd_sstep_with_schedule(oracle, y, p, s, &mut sched, ledger, trace)
}

/// [`dcd_sstep`] drawing its coordinate blocks through an explicit
/// [`Schedule`] (one `next_call(s_now, 1)` per outer block). Bitwise
/// identical to [`dcd_sstep`] under a [`Uniform`] schedule on
/// `(p.seed, SVM_COORD_STREAM)`.
pub fn dcd_sstep_with_schedule<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &SvmParams,
    s: usize,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    assert!(s >= 1);
    if oracle.overlap() == OverlapMode::Pipeline {
        return dcd_sstep_pipelined(oracle, y, p, s, sched, ledger, trace);
    }
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let (nu, omega) = p.variant.nu_omega(p.c);
    let mut alpha = vec![0.0; m];

    let outer = p.h.div_ceil(s);
    let mut q = Mat::zeros(s, m);
    let mut sample = Vec::with_capacity(s);
    let mut theta = vec![0.0; s];
    let mut done = 0usize;

    for k in 0..outer {
        let s_now = s.min(p.h - done);
        // Draw the next s coordinates from the schedule (the Uniform
        // schedule replays the stream DCD uses, draw for draw).
        sched.next_call(s_now, 1, &mut sample);
        let sample_now = &sample[..s_now];

        // U_k = K(A, A_S): s rows in one oracle call (one allreduce when
        // distributed), then y-scaled.
        let mut q_view = if s_now == s {
            std::mem::replace(&mut q, Mat::zeros(0, 0))
        } else {
            Mat::zeros(s_now, m)
        };
        oracle.gram(sample_now, &mut q_view, ledger);
        ledger.time(Phase::KernelCompute, || {
            yscale_rows(&mut q_view, sample_now, y);
        });
        ledger.add_flops(Phase::KernelCompute, 2.0 * (s_now * m) as f64);

        // Inner loop: s sequential scalar subproblems against the frozen
        // α_sk, with gradient-correction terms for the deferred updates.
        ledger.time(Phase::Solve, || {
            for j in 0..s_now {
                let urow = q_view.row(j);
                let ij = sample_now[j];
                let eta = urow[ij] + omega;
                // ρ_j = α_sk[i_j] + Σ_{t<j} θ_t [i_t = i_j]
                // g_j = u_jᵀα_sk − 1 + ω α_sk[i_j]
                //     + Σ_{t<j} (u_jᵀ e_{i_t}) θ_t + ω Σ_{t<j} θ_t [i_t = i_j]
                let mut rho = alpha[ij];
                let mut g = crate::dense::dot(urow, &alpha) - 1.0 + omega * alpha[ij];
                for t in 0..j {
                    let it = sample_now[t];
                    g += urow[it] * theta[t];
                    if it == ij {
                        rho += theta[t];
                        g += omega * theta[t];
                    }
                }
                theta[j] = coordinate_step(rho, g, eta, nu);
            }
        });
        ledger.add_flops(Phase::Solve, (s_now * (2 * m + 4)) as f64);
        // The C(s,2)-ish correction flops are attributed separately
        // (paper's "gradient correction" breakdown category).
        ledger.add_flops(
            Phase::GradCorr,
            (s_now * s_now.saturating_sub(1)) as f64, // 2 flops × s(s−1)/2
        );

        // Deferred solution update: α_{sk+s} = α_sk + Σ θ_t e_{i_t}.
        ledger.time(Phase::Update, || {
            if let Some(t) = trace.as_deref_mut() {
                // Replay updates one at a time so the trace sees every
                // intermediate α_{sk+j} (used by the Fig 1 overlay).
                for j in 0..s_now {
                    alpha[sample_now[j]] += theta[j];
                    t(k * s + j + 1, &alpha);
                }
            } else {
                for j in 0..s_now {
                    alpha[sample_now[j]] += theta[j];
                }
            }
        });
        ledger.add_flops(Phase::Update, s_now as f64);

        // Reset the gram buffer for the next outer iteration (the paper's
        // "memory reset" breakdown category).
        if s_now == s {
            ledger.time(Phase::MemReset, || {
                q_view.fill(0.0);
            });
            ledger.add_flops(Phase::MemReset, (s_now * m) as f64);
            q = q_view;
        }
        done += s_now;
    }
    ledger.iters += p.h as f64;
    alpha
}

/// [`dcd_sstep`] driven through the split-phase oracle
/// ([`OverlapMode::Pipeline`]): block `k+1`'s coordinates are drawn and
/// its gram reduction *posted* ([`GramOracle::gram_start`]) before block
/// `k`'s inner subproblems run, so the collective's wire time hides
/// under the Solve/GradCorr/Update compute of the previous block. The
/// hidden work is mirrored into [`Ledger::add_hidden_flops`] so the cost
/// model can credit the overlap.
///
/// Bitwise identical to the blocking driver: the coordinate stream is
/// drawn in the same order from the same generator, the cache hit/miss
/// stream is unchanged (`gram_finish(k)` completes before
/// `gram_start(k+1)` classifies), and every gram block, scaling and α
/// update replays the same arithmetic — only the wait moves.
fn dcd_sstep_pipelined<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &SvmParams,
    s: usize,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let (nu, omega) = p.variant.nu_omega(p.c);
    let mut alpha = vec![0.0; m];

    let outer = p.h.div_ceil(s);
    let mut q = Mat::zeros(s, m);
    let mut theta = vec![0.0; s];
    // Every block is full-size except possibly the last.
    let size_of = |k: usize| s.min(p.h - k * s);

    // Prologue: draw block 0 and post its gram. `sample` always holds
    // the in-flight (most recently posted) block's coordinates;
    // `next_sample` is the staging buffer for the block after it.
    let mut sample = Vec::with_capacity(s);
    let mut next_sample = Vec::with_capacity(s);
    sched.next_call(size_of(0), 1, &mut sample);
    oracle.gram_start(&sample[..size_of(0)], ledger);

    for k in 0..outer {
        let s_now = size_of(k);
        let sample_now = &sample[..s_now];
        let mut q_view = if s_now == s {
            std::mem::replace(&mut q, Mat::zeros(0, 0))
        } else {
            Mat::zeros(s_now, m)
        };
        oracle.gram_finish(sample_now, &mut q_view, ledger);
        ledger.time(Phase::KernelCompute, || {
            yscale_rows(&mut q_view, sample_now, y);
        });
        ledger.add_flops(Phase::KernelCompute, 2.0 * (s_now * m) as f64);

        // Draw and post block k+1 *before* block k's subproblems: its
        // reduction is then in flight for the whole inner loop below,
        // whose flops are the overlap window the cost model credits.
        let overlapped = k + 1 < outer;
        if overlapped {
            let s_next = size_of(k + 1);
            sched.next_call(s_next, 1, &mut next_sample);
            oracle.gram_start(&next_sample[..s_next], ledger);
        }

        // Inner loop — identical arithmetic to the blocking driver.
        ledger.time(Phase::Solve, || {
            for j in 0..s_now {
                let urow = q_view.row(j);
                let ij = sample_now[j];
                let eta = urow[ij] + omega;
                let mut rho = alpha[ij];
                let mut g = crate::dense::dot(urow, &alpha) - 1.0 + omega * alpha[ij];
                for t in 0..j {
                    let it = sample_now[t];
                    g += urow[it] * theta[t];
                    if it == ij {
                        rho += theta[t];
                        g += omega * theta[t];
                    }
                }
                theta[j] = coordinate_step(rho, g, eta, nu);
            }
        });
        ledger.add_flops(Phase::Solve, (s_now * (2 * m + 4)) as f64);
        ledger.add_flops(Phase::GradCorr, (s_now * s_now.saturating_sub(1)) as f64);

        ledger.time(Phase::Update, || {
            if let Some(t) = trace.as_deref_mut() {
                for j in 0..s_now {
                    alpha[sample_now[j]] += theta[j];
                    t(k * s + j + 1, &alpha);
                }
            } else {
                for j in 0..s_now {
                    alpha[sample_now[j]] += theta[j];
                }
            }
        });
        ledger.add_flops(Phase::Update, s_now as f64);
        if overlapped {
            ledger.add_hidden_flops(Phase::Solve, (s_now * (2 * m + 4)) as f64);
            ledger.add_hidden_flops(Phase::GradCorr, (s_now * s_now.saturating_sub(1)) as f64);
            ledger.add_hidden_flops(Phase::Update, s_now as f64);
        }

        if s_now == s {
            ledger.time(Phase::MemReset, || {
                q_view.fill(0.0);
            });
            ledger.add_flops(Phase::MemReset, (s_now * m) as f64);
            q = q_view;
        }
        std::mem::swap(&mut sample, &mut next_sample);
    }
    ledger.iters += p.h as f64;
    alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_classification;
    use crate::kernelfn::Kernel;
    use crate::solvers::LocalGram;
    use crate::testkit;

    fn setup(m: usize, n: usize, kernel: Kernel) -> (LocalGram, Vec<f64>) {
        let ds = gen_dense_classification(m, n, 0.1, 77);
        (LocalGram::new(ds.a.clone(), kernel), ds.y)
    }

    #[test]
    fn dcd_alpha_respects_box_constraints() {
        for variant in [SvmVariant::L1, SvmVariant::L2] {
            let (mut oracle, y) = setup(40, 8, Kernel::paper_rbf());
            let p = SvmParams {
                c: 0.5,
                variant,
                h: 300,
                seed: 1,
            };
            let (nu, _) = variant.nu_omega(p.c);
            let alpha = dcd(&mut oracle, &y, &p, &mut Ledger::new(), None);
            for &a in &alpha {
                assert!(a >= -1e-15 && a <= nu + 1e-15, "alpha {a} outside [0, {nu}]");
            }
        }
    }

    #[test]
    fn dcd_makes_progress() {
        // The dual objective must decrease vs the zero vector.
        let (mut oracle, y) = setup(60, 6, Kernel::paper_rbf());
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 500,
            seed: 2,
        };
        let alpha = dcd(&mut oracle, &y, &p, &mut Ledger::new(), None);
        let obj = super::super::objective::SvmObjective::new(&mut oracle, &y, p.c, p.variant);
        assert!(
            obj.dual_min_value(&alpha) < 0.0,
            "objective should improve on α = 0 (value 0)"
        );
    }

    #[test]
    fn sstep_equals_classical_all_kernels_and_variants() {
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            for variant in [SvmVariant::L1, SvmVariant::L2] {
                let (mut o1, y) = setup(50, 10, kernel);
                let (mut o2, _) = setup(50, 10, kernel);
                let p = SvmParams {
                    c: 1.0,
                    variant,
                    h: 240,
                    seed: 3,
                };
                let a_ref = dcd(&mut o1, &y, &p, &mut Ledger::new(), None);
                for s in [2, 3, 8, 16, 240] {
                    let a_s = dcd_sstep(&mut o2, &y, &p, s, &mut Ledger::new(), None);
                    testkit::assert_close(
                        &a_s,
                        &a_ref,
                        1e-10,
                        &format!("{kernel:?} {variant:?} s={s}"),
                    );
                }
            }
        }
    }

    #[test]
    fn sstep_trace_overlays_classical_trace() {
        let (mut o1, y) = setup(30, 6, Kernel::paper_rbf());
        let (mut o2, _) = setup(30, 6, Kernel::paper_rbf());
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 64,
            seed: 5,
        };
        let mut trace1: Vec<Vec<f64>> = Vec::new();
        let mut cb1 = |_k: usize, a: &[f64]| trace1.push(a.to_vec());
        dcd(&mut o1, &y, &p, &mut Ledger::new(), Some(&mut cb1));
        let mut trace2: Vec<Vec<f64>> = Vec::new();
        let mut cb2 = |_k: usize, a: &[f64]| trace2.push(a.to_vec());
        dcd_sstep(&mut o2, &y, &p, 8, &mut Ledger::new(), Some(&mut cb2));
        assert_eq!(trace1.len(), trace2.len());
        for (t1, t2) in trace1.iter().zip(&trace2) {
            testkit::assert_close(t2, t1, 1e-10, "trace step");
        }
    }

    #[test]
    fn sstep_handles_h_not_divisible_by_s() {
        let (mut o1, y) = setup(25, 5, Kernel::Linear);
        let (mut o2, _) = setup(25, 5, Kernel::Linear);
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 37, // not divisible by 8
            seed: 7,
        };
        let a_ref = dcd(&mut o1, &y, &p, &mut Ledger::new(), None);
        let a_s = dcd_sstep(&mut o2, &y, &p, 8, &mut Ledger::new(), None);
        testkit::assert_close(&a_s, &a_ref, 1e-10, "ragged tail");
    }

    #[test]
    fn duplicate_coordinates_within_block_are_corrected() {
        // Tiny m with large s forces duplicate draws inside one block —
        // the ρ/ω correction terms must handle them.
        let (mut o1, y) = setup(4, 3, Kernel::paper_rbf());
        let (mut o2, _) = setup(4, 3, Kernel::paper_rbf());
        for variant in [SvmVariant::L1, SvmVariant::L2] {
            let p = SvmParams {
                c: 2.0,
                variant,
                h: 96,
                seed: 11,
            };
            let a_ref = dcd(&mut o1, &y, &p, &mut Ledger::new(), None);
            let a_s = dcd_sstep(&mut o2, &y, &p, 32, &mut Ledger::new(), None);
            testkit::assert_close(&a_s, &a_ref, 1e-9, &format!("{variant:?} duplicates"));
        }
    }

    #[test]
    fn property_sstep_equivalence_random_configs() {
        testkit::check("dcd sstep ≡ dcd", 12, |g| {
            let m = g.size(5, 40);
            let n = g.size(2, 12);
            let h = g.size(10, 120);
            let s = *g.choose(&[2, 4, 7, 16, 64]);
            let kernel = *g.choose(&[Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()]);
            let variant = *g.choose(&[SvmVariant::L1, SvmVariant::L2]);
            let c = g.f64_range(0.1, 4.0);
            let ds = gen_dense_classification(m, n, 0.1, g.seed);
            let p = SvmParams {
                c,
                variant,
                h,
                seed: g.seed ^ 0xABCD,
            };
            let mut o1 = LocalGram::new(ds.a.clone(), kernel);
            let mut o2 = LocalGram::new(ds.a.clone(), kernel);
            let a_ref = dcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
            let a_s = dcd_sstep(&mut o2, &ds.y, &p, s, &mut Ledger::new(), None);
            testkit::assert_close(&a_s, &a_ref, 1e-9, "prop equivalence");
        });
    }

    /// The pipelined driver must replay the blocking distributed solve
    /// bit for bit — same α, same wire traffic — while actually posting
    /// its gram reductions ahead of the inner loop.
    #[test]
    fn pipelined_sstep_is_bitwise_equal_to_blocking_distributed() {
        use crate::comm::{run_ranks, AllreduceAlgo};
        use crate::solvers::DistGram;
        let ds = gen_dense_classification(24, 8, 0.1, 5);
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 50,
            seed: 9,
        };
        for s in [2usize, 8, 13] {
            let run = |mode: OverlapMode| {
                let shards = ds.shard_cols(3);
                let y = ds.y.clone();
                run_ranks(3, move |c| {
                    let shard = shards[c.rank()].clone();
                    let mut o = DistGram::with_cache(
                        shard,
                        Kernel::paper_rbf(),
                        c,
                        AllreduceAlgo::Rabenseifner,
                        6,
                    );
                    o.set_overlap(mode);
                    let mut ledger = Ledger::new();
                    let alpha = dcd_sstep(&mut o, &y, &p, s, &mut ledger, None);
                    (alpha, o.comm_stats(), ledger.comm_posted)
                })
            };
            let blocking = run(OverlapMode::Off);
            let piped = run(OverlapMode::Pipeline);
            for ((a0, c0, _), (a1, c1, posted)) in blocking.iter().zip(&piped) {
                assert_eq!(a0, a1, "s={s}: α must be bitwise identical");
                assert_eq!(c0, c1, "s={s}: wire traffic must be identical");
                assert!(posted.words > 0, "s={s}: reduces must actually be posted");
            }
        }
    }

    #[test]
    fn ledger_phases_populated() {
        let (mut oracle, y) = setup(20, 4, Kernel::paper_rbf());
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L1,
            h: 64,
            seed: 13,
        };
        let mut ledger = Ledger::new();
        dcd_sstep(&mut oracle, &y, &p, 8, &mut ledger, None);
        assert!(ledger.flops(Phase::KernelCompute) > 0.0);
        assert!(ledger.flops(Phase::Solve) > 0.0);
        assert!(ledger.flops(Phase::GradCorr) > 0.0);
        assert!(ledger.flops(Phase::MemReset) > 0.0);
        assert!(ledger.flops(Phase::Update) > 0.0);
    }
}
