//! Algorithms 3 and 4: (s-step) Block Dual Coordinate Descent for kernel
//! ridge regression.

use crate::costmodel::{Ledger, Phase};
use crate::dense::{cholesky_solve, Mat};
use crate::gram::OverlapMode;
use crate::schedule::{Schedule, Uniform};

use super::{GramOracle, Trace};

/// PCG stream id of the K-RR block-selection sequence, shared by
/// [`bdcd`] and [`bdcd_sstep`] — and by the analytic fragment-exchange
/// replica (`coordinator::scaling::gram_call_samples`), which must
/// replay the exact sample stream to count the sharded grid layout's
/// per-call exchange traffic.
pub const KRR_COORD_STREAM: u64 = 0xBD;

/// K-RR solver parameters.
#[derive(Clone, Copy, Debug)]
pub struct KrrParams {
    /// Ridge penalty `λ`.
    pub lambda: f64,
    /// Block size `b`.
    pub b: usize,
    /// Number of (inner) block iterations `H`.
    pub h: usize,
    /// Coordinate-selection seed (shared by BDCD and s-step BDCD).
    pub seed: u64,
}

impl Default for KrrParams {
    fn default() -> Self {
        KrrParams {
            lambda: 1.0,
            b: 8,
            h: 500,
            seed: 0xB0CD,
        }
    }
}

/// Algorithm 3: BDCD for K-RR. Returns `α_H`.
///
/// Per iteration: sample `b` coordinates without replacement, form the
/// sampled kernel block `U_k = K(A, A_S)` (`b` rows of the kernel
/// matrix), build `G_k = (1/λ)V_kᵀU_k + mI`, solve the `b×b` system and
/// update the sampled coordinates of the replicated `α`.
pub fn bdcd<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &KrrParams,
    ledger: &mut Ledger,
    trace: Trace,
) -> Vec<f64> {
    let mut sched = Uniform::new(oracle.m(), p.seed, KRR_COORD_STREAM);
    bdcd_with_schedule(oracle, y, p, &mut sched, ledger, trace)
}

/// [`bdcd`] drawing its blocks through an explicit [`Schedule`] (one
/// `next_call(1, b)` per iteration). Bitwise identical to [`bdcd`]
/// under a [`Uniform`] schedule on `(p.seed, KRR_COORD_STREAM)`.
pub fn bdcd_with_schedule<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &KrrParams,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert!(p.b >= 1 && p.b <= m, "block size must be in [1, m]");
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let mf = m as f64;
    let inv_lambda = 1.0 / p.lambda;
    let mut alpha = vec![0.0; m];
    let mut q = Mat::zeros(p.b, m);
    let mut sample = Vec::with_capacity(p.b);

    for k in 0..p.h {
        sched.next_call(1, p.b, &mut sample);
        oracle.gram(&sample, &mut q, ledger);

        let delta = ledger.time(Phase::Solve, || {
            // G = (1/λ)VᵀU + mI ; rhs = Vᵀy − mVᵀα − (1/λ)Uᵀα.
            let mut g = Mat::zeros(p.b, p.b);
            for r in 0..p.b {
                for c in 0..p.b {
                    g[(r, c)] = inv_lambda * q[(c, sample[r])];
                }
                g[(r, r)] += mf;
            }
            let rhs: Vec<f64> = (0..p.b)
                .map(|r| {
                    y[sample[r]]
                        - mf * alpha[sample[r]]
                        - inv_lambda * crate::dense::dot(q.row(r), &alpha)
                })
                .collect();
            cholesky_solve(&g, &rhs)
        });
        ledger.add_flops(
            Phase::Solve,
            (2 * p.b * m + p.b * p.b + p.b * p.b * p.b) as f64,
        );

        ledger.time(Phase::Update, || {
            for (r, &i) in sample.iter().enumerate() {
                alpha[i] += delta[r];
            }
        });
        ledger.add_flops(Phase::Update, p.b as f64);

        if let Some(t) = trace.as_deref_mut() {
            t(k + 1, &alpha);
        }
    }
    ledger.iters += p.h as f64;
    alpha
}

/// Algorithm 4: s-step BDCD for K-RR. Computes a factor-`s` larger kernel
/// block `Q_k = K(A, Ω_kᵀA)` per outer iteration (one allreduce), then
/// solves the `s` subproblems sequentially with right-hand-side
/// correction terms for the deferred `α` updates. Mathematically
/// equivalent to [`bdcd`] with the same seed.
pub fn bdcd_sstep<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &KrrParams,
    s: usize,
    ledger: &mut Ledger,
    trace: Trace,
) -> Vec<f64> {
    let mut sched = Uniform::new(oracle.m(), p.seed, KRR_COORD_STREAM);
    bdcd_sstep_with_schedule(oracle, y, p, s, &mut sched, ledger, trace)
}

/// [`bdcd_sstep`] drawing its blocks through an explicit [`Schedule`]
/// (one `next_call(s_now, b)` per outer iteration). Bitwise identical
/// to [`bdcd_sstep`] under a [`Uniform`] schedule on
/// `(p.seed, KRR_COORD_STREAM)`.
pub fn bdcd_sstep_with_schedule<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &KrrParams,
    s: usize,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    assert!(s >= 1);
    if oracle.overlap() == OverlapMode::Pipeline {
        return bdcd_sstep_pipelined(oracle, y, p, s, sched, ledger, trace);
    }
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert!(p.b >= 1 && p.b <= m, "block size must be in [1, m]");
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let mf = m as f64;
    let inv_lambda = 1.0 / p.lambda;
    let mut alpha = vec![0.0; m];

    let b = p.b;
    let outer = p.h.div_ceil(s);
    let mut q = Mat::zeros(s * b, m);
    let mut samples: Vec<Vec<usize>> = vec![Vec::new(); s];
    let mut deltas: Vec<Vec<f64>> = vec![vec![0.0; b]; s];
    let mut flat: Vec<usize> = Vec::with_capacity(s * b);
    let mut done = 0usize;

    for k in 0..outer {
        let s_now = s.min(p.h - done);
        // Draw s blocks from the schedule (the Uniform schedule replays
        // the stream BDCD uses, draw for draw).
        sched.next_call(s_now, b, &mut flat);
        for (j, sample) in samples.iter_mut().take(s_now).enumerate() {
            sample.clear();
            sample.extend_from_slice(&flat[j * b..(j + 1) * b]);
        }

        // Q_k = K(A, Ω_kᵀA): sb kernel rows in one oracle call.
        let mut q_view = if s_now == s {
            std::mem::replace(&mut q, Mat::zeros(0, 0))
        } else {
            Mat::zeros(s_now * b, m)
        };
        oracle.gram(&flat, &mut q_view, ledger);

        // Inner loop: s block subproblems against the frozen α_sk.
        for j in 0..s_now {
            let sj = &samples[j];
            let qj = |r: usize| q_view.row(j * b + r);

            let delta_j = ledger.time(Phase::Solve, || {
                // G_j = (1/λ)V_jᵀU_j + mI.
                let mut g = Mat::zeros(b, b);
                for r in 0..b {
                    for c in 0..b {
                        g[(r, c)] = inv_lambda * q_view[(j * b + c, sj[r])];
                    }
                    g[(r, r)] += mf;
                }
                // Base rhs: V_jᵀy − mV_jᵀα_sk − (1/λ)U_jᵀα_sk.
                let mut rhs: Vec<f64> = (0..b)
                    .map(|r| {
                        y[sj[r]] - mf * alpha[sj[r]] - inv_lambda * crate::dense::dot(qj(r), &alpha)
                    })
                    .collect();
                rhs_corrections(&mut rhs, j, sj, &samples, &deltas, &q_view, b, mf, inv_lambda);
                cholesky_solve(&g, &rhs)
            });
            ledger.add_flops(
                Phase::Solve,
                (2 * b * m + b * b + b * b * b) as f64,
            );
            // C(s,2)-pattern correction cost: 2b² flop-equivalents per
            // (j,t) pair (paper's "gradient correction" category).
            ledger.add_flops(Phase::GradCorr, (j * 2 * b * b) as f64);
            deltas[j][..b].copy_from_slice(&delta_j);
        }

        // Deferred update: α_{sk+s} = α_sk + Σ_t V_t Δα_t.
        ledger.time(Phase::Update, || {
            if let Some(t) = trace.as_deref_mut() {
                for j in 0..s_now {
                    for (r, &i) in samples[j].iter().enumerate() {
                        alpha[i] += deltas[j][r];
                    }
                    t(k * s + j + 1, &alpha);
                }
            } else {
                for j in 0..s_now {
                    for (r, &i) in samples[j].iter().enumerate() {
                        alpha[i] += deltas[j][r];
                    }
                }
            }
        });
        ledger.add_flops(Phase::Update, (s_now * b) as f64);

        if s_now == s {
            ledger.time(Phase::MemReset, || {
                q_view.fill(0.0);
            });
            ledger.add_flops(Phase::MemReset, (s_now * b * m) as f64);
            q = q_view;
        }
        done += s_now;
    }
    ledger.iters += p.h as f64;
    alpha
}

/// [`bdcd_sstep`] driven through the split-phase oracle
/// ([`OverlapMode::Pipeline`]): outer block `k+1`'s coordinates are
/// drawn and its gram reduction *posted* ([`GramOracle::gram_start`])
/// before block `k`'s `s` block subproblems run, so the collective's
/// wire time hides under the Cholesky solves and corrections. Hidden
/// work is mirrored into [`Ledger::add_hidden_flops`] for the cost
/// model. Bitwise identical to the blocking driver — same coordinate
/// stream, same cache stream, same arithmetic; only the wait moves.
fn bdcd_sstep_pipelined<O: GramOracle>(
    oracle: &mut O,
    y: &[f64],
    p: &KrrParams,
    s: usize,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
    mut trace: Trace,
) -> Vec<f64> {
    let m = oracle.m();
    assert_eq!(y.len(), m);
    assert!(p.b >= 1 && p.b <= m, "block size must be in [1, m]");
    assert_eq!(sched.m(), m, "schedule must cover the oracle's rows");
    let mf = m as f64;
    let inv_lambda = 1.0 / p.lambda;
    let mut alpha = vec![0.0; m];

    let b = p.b;
    let outer = p.h.div_ceil(s);
    let mut q = Mat::zeros(s * b, m);
    let mut samples: Vec<Vec<usize>> = vec![Vec::new(); s];
    let mut next_samples: Vec<Vec<usize>> = vec![Vec::new(); s];
    let mut deltas: Vec<Vec<f64>> = vec![vec![0.0; b]; s];
    // Every outer block is full-size except possibly the last.
    let size_of = |k: usize| s.min(p.h - k * s);
    let split = |flat: &[usize], samples: &mut [Vec<usize>], s_now: usize| {
        for (j, sample) in samples.iter_mut().take(s_now).enumerate() {
            sample.clear();
            sample.extend_from_slice(&flat[j * b..(j + 1) * b]);
        }
    };

    // Prologue: draw outer block 0 and post its gram. `samples`/`flat`
    // always hold the in-flight (most recently posted) block.
    let mut flat: Vec<usize> = Vec::with_capacity(s * b);
    let mut next_flat: Vec<usize> = Vec::with_capacity(s * b);
    sched.next_call(size_of(0), b, &mut flat);
    split(&flat, &mut samples, size_of(0));
    oracle.gram_start(&flat, ledger);

    for k in 0..outer {
        let s_now = size_of(k);
        let mut q_view = if s_now == s {
            std::mem::replace(&mut q, Mat::zeros(0, 0))
        } else {
            Mat::zeros(s_now * b, m)
        };
        oracle.gram_finish(&flat, &mut q_view, ledger);

        // Draw and post block k+1 *before* block k's subproblems: its
        // reduction is then in flight for the whole inner loop below.
        let overlapped = k + 1 < outer;
        if overlapped {
            let s_next = size_of(k + 1);
            sched.next_call(s_next, b, &mut next_flat);
            split(&next_flat, &mut next_samples, s_next);
            oracle.gram_start(&next_flat, ledger);
        }

        // Inner loop — identical arithmetic to the blocking driver.
        for j in 0..s_now {
            let sj = &samples[j];
            let qj = |r: usize| q_view.row(j * b + r);

            let delta_j = ledger.time(Phase::Solve, || {
                let mut g = Mat::zeros(b, b);
                for r in 0..b {
                    for c in 0..b {
                        g[(r, c)] = inv_lambda * q_view[(j * b + c, sj[r])];
                    }
                    g[(r, r)] += mf;
                }
                let mut rhs: Vec<f64> = (0..b)
                    .map(|r| {
                        y[sj[r]] - mf * alpha[sj[r]] - inv_lambda * crate::dense::dot(qj(r), &alpha)
                    })
                    .collect();
                rhs_corrections(&mut rhs, j, sj, &samples, &deltas, &q_view, b, mf, inv_lambda);
                cholesky_solve(&g, &rhs)
            });
            ledger.add_flops(
                Phase::Solve,
                (2 * b * m + b * b + b * b * b) as f64,
            );
            ledger.add_flops(Phase::GradCorr, (j * 2 * b * b) as f64);
            if overlapped {
                ledger.add_hidden_flops(
                    Phase::Solve,
                    (2 * b * m + b * b + b * b * b) as f64,
                );
                ledger.add_hidden_flops(Phase::GradCorr, (j * 2 * b * b) as f64);
            }
            deltas[j][..b].copy_from_slice(&delta_j);
        }

        ledger.time(Phase::Update, || {
            if let Some(t) = trace.as_deref_mut() {
                for j in 0..s_now {
                    for (r, &i) in samples[j].iter().enumerate() {
                        alpha[i] += deltas[j][r];
                    }
                    t(k * s + j + 1, &alpha);
                }
            } else {
                for j in 0..s_now {
                    for (r, &i) in samples[j].iter().enumerate() {
                        alpha[i] += deltas[j][r];
                    }
                }
            }
        });
        ledger.add_flops(Phase::Update, (s_now * b) as f64);
        if overlapped {
            ledger.add_hidden_flops(Phase::Update, (s_now * b) as f64);
        }

        if s_now == s {
            ledger.time(Phase::MemReset, || {
                q_view.fill(0.0);
            });
            ledger.add_flops(Phase::MemReset, (s_now * b * m) as f64);
            q = q_view;
        }
        if overlapped {
            std::mem::swap(&mut samples, &mut next_samples);
            std::mem::swap(&mut flat, &mut next_flat);
        }
    }
    ledger.iters += p.h as f64;
    alpha
}

/// Apply the deferred-update correction terms of Algorithm 4 line 15:
/// `rhs −= m Σ_{t<j} V_jᵀV_t Δα_t + (1/λ) Σ_{t<j} U_jᵀV_t Δα_t`.
#[allow(clippy::too_many_arguments)]
fn rhs_corrections(
    rhs: &mut [f64],
    j: usize,
    sj: &[usize],
    samples: &[Vec<usize>],
    deltas: &[Vec<f64>],
    q_view: &Mat,
    b: usize,
    mf: f64,
    inv_lambda: f64,
) {
    for t in 0..j {
        let st = &samples[t];
        let dt = &deltas[t];
        for r in 0..b {
            let mut vv = 0.0; // (V_jᵀV_t Δα_t)[r]
            let mut uv = 0.0; // (U_jᵀV_t Δα_t)[r]
            let qjr = q_view.row(j * b + r);
            for c in 0..b {
                if sj[r] == st[c] {
                    vv += dt[c];
                }
                uv += qjr[st[c]] * dt[c];
            }
            rhs[r] -= mf * vv + inv_lambda * uv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_regression;
    use crate::kernelfn::Kernel;
    use crate::solvers::{krr_exact, LocalGram};
    use crate::testkit;

    fn setup(m: usize, n: usize, kernel: Kernel) -> (LocalGram, Vec<f64>) {
        let ds = gen_dense_regression(m, n, 0.1, 99);
        (LocalGram::new(ds.a.clone(), kernel), ds.y)
    }

    #[test]
    fn bdcd_converges_to_closed_form() {
        for kernel in [Kernel::Linear, Kernel::paper_rbf()] {
            let (mut oracle, y) = setup(40, 6, kernel);
            let p = KrrParams {
                lambda: 1.0,
                b: 8,
                h: 800,
                seed: 1,
            };
            let alpha = bdcd(&mut oracle, &y, &p, &mut Ledger::new(), None);
            let astar = krr_exact(&mut oracle, &y, p.lambda);
            let err = crate::dense::rel_err(&alpha, &astar);
            assert!(err < 1e-6, "{kernel:?}: rel err {err}");
        }
    }

    #[test]
    fn bdcd_b_equals_m_is_one_shot_exact() {
        // With b = m the subproblem *is* the full problem: one iteration
        // reaches the closed-form solution.
        let (mut oracle, y) = setup(25, 5, Kernel::paper_rbf());
        let p = KrrParams {
            lambda: 0.5,
            b: 25,
            h: 1,
            seed: 2,
        };
        let alpha = bdcd(&mut oracle, &y, &p, &mut Ledger::new(), None);
        let astar = krr_exact(&mut oracle, &y, p.lambda);
        let err = crate::dense::rel_err(&alpha, &astar);
        assert!(err < 1e-10, "one-shot err {err}");
    }

    #[test]
    fn sstep_equals_classical_all_kernels() {
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let (mut o1, y) = setup(36, 8, kernel);
            let (mut o2, _) = setup(36, 8, kernel);
            let p = KrrParams {
                lambda: 2.0,
                b: 4,
                h: 120,
                seed: 3,
            };
            let a_ref = bdcd(&mut o1, &y, &p, &mut Ledger::new(), None);
            for s in [2, 3, 8, 16, 120] {
                let a_s = bdcd_sstep(&mut o2, &y, &p, s, &mut Ledger::new(), None);
                testkit::assert_close(&a_s, &a_ref, 1e-9, &format!("{kernel:?} s={s}"));
            }
        }
    }

    #[test]
    fn sstep_trace_overlays_classical() {
        let (mut o1, y) = setup(20, 5, Kernel::paper_rbf());
        let (mut o2, _) = setup(20, 5, Kernel::paper_rbf());
        let p = KrrParams {
            lambda: 1.0,
            b: 3,
            h: 48,
            seed: 5,
        };
        let mut t1: Vec<Vec<f64>> = Vec::new();
        let mut cb1 = |_k: usize, a: &[f64]| t1.push(a.to_vec());
        bdcd(&mut o1, &y, &p, &mut Ledger::new(), Some(&mut cb1));
        let mut t2: Vec<Vec<f64>> = Vec::new();
        let mut cb2 = |_k: usize, a: &[f64]| t2.push(a.to_vec());
        bdcd_sstep(&mut o2, &y, &p, 6, &mut Ledger::new(), Some(&mut cb2));
        assert_eq!(t1.len(), t2.len());
        for (a, b) in t1.iter().zip(&t2) {
            testkit::assert_close(b, a, 1e-9, "krr trace step");
        }
    }

    #[test]
    fn overlapping_blocks_across_inner_steps_are_corrected() {
        // m barely larger than b forces heavy overlap between the s
        // blocks of one outer iteration.
        let (mut o1, y) = setup(6, 4, Kernel::paper_rbf());
        let (mut o2, _) = setup(6, 4, Kernel::paper_rbf());
        let p = KrrParams {
            lambda: 1.0,
            b: 4,
            h: 60,
            seed: 7,
        };
        let a_ref = bdcd(&mut o1, &y, &p, &mut Ledger::new(), None);
        let a_s = bdcd_sstep(&mut o2, &y, &p, 12, &mut Ledger::new(), None);
        testkit::assert_close(&a_s, &a_ref, 1e-9, "overlap correction");
    }

    #[test]
    fn sstep_handles_ragged_tail() {
        let (mut o1, y) = setup(18, 4, Kernel::Linear);
        let (mut o2, _) = setup(18, 4, Kernel::Linear);
        let p = KrrParams {
            lambda: 1.0,
            b: 2,
            h: 23,
            seed: 9,
        };
        let a_ref = bdcd(&mut o1, &y, &p, &mut Ledger::new(), None);
        let a_s = bdcd_sstep(&mut o2, &y, &p, 5, &mut Ledger::new(), None);
        testkit::assert_close(&a_s, &a_ref, 1e-9, "ragged");
    }

    #[test]
    fn property_sstep_equivalence_random_configs() {
        testkit::check("bdcd sstep ≡ bdcd", 10, |g| {
            let m = g.size(6, 30);
            let b = g.size(1, m.min(8));
            let h = g.size(5, 60);
            let s = *g.choose(&[2, 4, 9, 16]);
            let kernel = *g.choose(&[Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()]);
            let lambda = g.f64_range(0.2, 5.0);
            let ds = gen_dense_regression(m, g.size(2, 10), 0.1, g.seed);
            let p = KrrParams {
                lambda,
                b,
                h,
                seed: g.seed ^ 0x1234,
            };
            let mut o1 = LocalGram::new(ds.a.clone(), kernel);
            let mut o2 = LocalGram::new(ds.a.clone(), kernel);
            let a_ref = bdcd(&mut o1, &ds.y, &p, &mut Ledger::new(), None);
            let a_s = bdcd_sstep(&mut o2, &ds.y, &p, s, &mut Ledger::new(), None);
            testkit::assert_close(&a_s, &a_ref, 1e-8, "prop krr equivalence");
        });
    }

    #[test]
    fn large_s_remains_stable() {
        // The paper's headline stability claim: s = 256 still matches.
        let (mut o1, y) = setup(32, 6, Kernel::paper_rbf());
        let (mut o2, _) = setup(32, 6, Kernel::paper_rbf());
        let p = KrrParams {
            lambda: 1.0,
            b: 2,
            h: 512,
            seed: 11,
        };
        let a_ref = bdcd(&mut o1, &y, &p, &mut Ledger::new(), None);
        let a_s = bdcd_sstep(&mut o2, &y, &p, 256, &mut Ledger::new(), None);
        testkit::assert_close(&a_s, &a_ref, 1e-8, "s=256 stability");
    }

    /// The pipelined KRR driver must replay the blocking distributed
    /// solve bit for bit — same α, same wire traffic — while actually
    /// posting its gram reductions ahead of the block subproblems.
    #[test]
    fn pipelined_sstep_is_bitwise_equal_to_blocking_distributed() {
        use crate::comm::{run_ranks, AllreduceAlgo};
        use crate::solvers::DistGram;
        let ds = gen_dense_regression(20, 6, 0.1, 4);
        let p = KrrParams {
            lambda: 1.0,
            b: 3,
            h: 20,
            seed: 8,
        };
        for s in [2usize, 4, 7] {
            let run = |mode: OverlapMode| {
                let shards = ds.shard_cols(3);
                let y = ds.y.clone();
                run_ranks(3, move |c| {
                    let shard = shards[c.rank()].clone();
                    let mut o = DistGram::with_cache(
                        shard,
                        Kernel::paper_rbf(),
                        c,
                        AllreduceAlgo::Rabenseifner,
                        8,
                    );
                    o.set_overlap(mode);
                    let mut ledger = Ledger::new();
                    let alpha = bdcd_sstep(&mut o, &y, &p, s, &mut ledger, None);
                    (alpha, o.comm_stats(), ledger.comm_posted)
                })
            };
            let blocking = run(OverlapMode::Off);
            let piped = run(OverlapMode::Pipeline);
            for ((a0, c0, _), (a1, c1, posted)) in blocking.iter().zip(&piped) {
                assert_eq!(a0, a1, "s={s}: α must be bitwise identical");
                assert_eq!(c0, c1, "s={s}: wire traffic must be identical");
                assert!(posted.words > 0, "s={s}: reduces must actually be posted");
            }
        }
    }

    #[test]
    fn ledger_phases_populated() {
        let (mut oracle, y) = setup(16, 4, Kernel::paper_rbf());
        let p = KrrParams {
            lambda: 1.0,
            b: 2,
            h: 32,
            seed: 13,
        };
        let mut ledger = Ledger::new();
        bdcd_sstep(&mut oracle, &y, &p, 8, &mut ledger, None);
        assert!(ledger.flops(Phase::KernelCompute) > 0.0);
        assert!(ledger.flops(Phase::Solve) > 0.0);
        assert!(ledger.flops(Phase::GradCorr) > 0.0);
        assert!(ledger.flops(Phase::MemReset) > 0.0);
    }
}
