//! Closed-form kernel ridge regression: the `α*` reference used by the
//! convergence experiments (Figure 2's relative solution error).

use crate::costmodel::Ledger;
use crate::dense::{cholesky_solve, Mat};

use super::GramOracle;

/// Materialize the full `m×m` kernel matrix through the oracle.
///
/// O(m²) memory — intended for the convergence datasets (`m ≤ 4177`),
/// exactly like the paper's MATLAB reference.
pub fn full_kernel_matrix<O: GramOracle>(oracle: &mut O) -> Mat {
    let m = oracle.m();
    let sample: Vec<usize> = (0..m).collect();
    let mut k = Mat::zeros(m, m);
    oracle.gram(&sample, &mut k, &mut Ledger::new());
    k
}

/// Solve `((1/λ)K + mI) α* = y` — the exact K-RR solution implied by the
/// stationarity of problem (2) (the paper computes the same reference via
/// matrix factorization).
pub fn krr_exact<O: GramOracle>(oracle: &mut O, y: &[f64], lambda: f64) -> Vec<f64> {
    let m = oracle.m();
    assert_eq!(y.len(), m);
    let mut g = full_kernel_matrix(oracle);
    let inv_lambda = 1.0 / lambda;
    for v in g.data_mut() {
        *v *= inv_lambda;
    }
    for i in 0..m {
        g[(i, i)] += m as f64;
    }
    cholesky_solve(&g, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_regression;
    use crate::dense::gemv;
    use crate::kernelfn::Kernel;
    use crate::solvers::LocalGram;

    #[test]
    fn exact_solution_satisfies_normal_equations() {
        let ds = gen_dense_regression(30, 5, 0.1, 21);
        for kernel in [Kernel::Linear, Kernel::paper_rbf()] {
            let mut oracle = LocalGram::new(ds.a.clone(), kernel);
            let lambda = 1.5;
            let astar = krr_exact(&mut oracle, &ds.y, lambda);
            // Residual of ((1/λ)K + mI)α* − y must vanish.
            let k = full_kernel_matrix(&mut oracle);
            let mut ka = vec![0.0; 30];
            gemv(&k, &astar, &mut ka);
            for i in 0..30 {
                let lhs = ka[i] / lambda + 30.0 * astar[i];
                assert!(
                    (lhs - ds.y[i]).abs() < 1e-8,
                    "{kernel:?} residual at {i}: {lhs} vs {}",
                    ds.y[i]
                );
            }
        }
    }

    #[test]
    fn full_kernel_matrix_is_symmetric_psd_diagonal() {
        let ds = gen_dense_regression(15, 4, 0.1, 22);
        let mut oracle = LocalGram::new(ds.a.clone(), Kernel::paper_rbf());
        let k = full_kernel_matrix(&mut oracle);
        for i in 0..15 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12, "rbf diag");
            for j in 0..15 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12, "symmetry");
                assert!(k[(i, j)] > 0.0 && k[(i, j)] <= 1.0 + 1e-12, "rbf range");
            }
        }
    }
}
