//! Nyström-approximated gram oracle — the paper's stated future-work
//! optimization ("approximating the sampled kernel matrix (for example
//! using the Nyström method) ... at the expense of weaker convergence").
//!
//! With `l` landmark rows `L`, the kernel matrix is approximated as
//! `K̂ = C W⁺ Cᵀ` where `C = K(A, A_L) (m×l)` and `W = K(A_L, A_L)`.
//! A sampled row block becomes `K̂(S, ·) = (C[S,:] W⁺) Cᵀ`, so the
//! per-iteration kernel cost drops from `O(k·nnz(A))` to `O(k·l·m)`
//! after an `O(l·nnz(A) + l³)` setup — a win when `l ≪ nnz(A)/m`
//! (e.g. wide microarray data). The `ablation_nystrom` bench measures
//! the accuracy-vs-flops trade-off as `l` varies.
//!
//! Engine configuration: [`LowRankProduct`] over the precomputed factors
//! (finished kernel values, so no epilogue) → no reduction; the setup
//! math (landmark sampling, Cholesky of `W`) lives here.

use crate::costmodel::Ledger;
use crate::dense::{Cholesky, Mat};
use crate::gram::{GramEngine, Layout, LowRankProduct, NoReduce};
use crate::kernelfn::Kernel;
use crate::parallel::ParallelProduct;
use crate::rng::Pcg;
use crate::sparse::Csr;

use super::{GramOracle, LocalGram};

/// Gram oracle over the rank-`l` Nyström approximation of `K`.
pub struct NystromGram {
    engine: GramEngine<ParallelProduct<LowRankProduct>, NoReduce>,
}

impl NystromGram {
    /// Build from data + kernel with `l` uniformly sampled landmarks.
    /// `jitter` regularizes `W` (standard practice; keeps the
    /// factorization stable when landmarks are nearly dependent).
    pub fn new(a: &Csr, kernel: Kernel, l: usize, jitter: f64, seed: u64) -> NystromGram {
        Self::with_opts(a, kernel, l, jitter, seed, 0, 1)
    }

    /// Same, with the engine's kernel-row cache enabled for
    /// `cache_rows > 0`.
    pub fn with_cache(
        a: &Csr,
        kernel: Kernel,
        l: usize,
        jitter: f64,
        seed: u64,
        cache_rows: usize,
    ) -> NystromGram {
        Self::with_opts(a, kernel, l, jitter, seed, cache_rows, 1)
    }

    /// Full configuration: cache plus `threads` workers splitting the
    /// sampled rows of the low-rank product (bitwise-invariant).
    #[allow(clippy::too_many_arguments)]
    pub fn with_opts(
        a: &Csr,
        kernel: Kernel,
        l: usize,
        jitter: f64,
        seed: u64,
        cache_rows: usize,
        threads: usize,
    ) -> NystromGram {
        let m = a.nrows();
        assert!(l >= 1 && l <= m, "landmarks must be in [1, m]");
        let mut rng = Pcg::new(seed, 0x4E75);
        let landmarks = rng.sample_without_replacement(m, l);

        // C = K(A, A_L) via the exact oracle (setup cost, off the
        // iteration path).
        let mut exact = LocalGram::new(a.clone(), kernel);
        let mut c_t = Mat::zeros(l, m); // rows = landmark kernel rows
        exact.gram(&landmarks, &mut c_t, &mut Ledger::new());

        // W = C[L, :] (l×l), regularized.
        let mut w = Mat::zeros(l, l);
        for r in 0..l {
            for c in 0..l {
                w[(r, c)] = c_t[(r, landmarks[c])];
            }
            w[(r, r)] += jitter;
        }
        let chol = Cholesky::new(&w).unwrap_or_else(|| {
            // Fall back to a heavier jitter if the landmark gram is not
            // numerically SPD.
            let mut w2 = w.clone();
            for r in 0..l {
                w2[(r, r)] += 1e-6 * (1.0 + w[(r, r)].abs());
            }
            Cholesky::new(&w2).expect("landmark gram not factorizable")
        });

        // cw[i][:] = W⁻¹ C[i,:]ᵀ, i.e. solve per row of C (= column of
        // c_t).
        let mut cw = Mat::zeros(m, l);
        let mut col = vec![0.0; l];
        for i in 0..m {
            for r in 0..l {
                col[r] = c_t[(r, i)];
            }
            chol.solve_in_place(&mut col);
            cw.row_mut(i).copy_from_slice(&col);
        }

        // Approximate diagonal: K̂_ii = c_iᵀ W⁻¹ c_i.
        let diag = (0..m)
            .map(|i| {
                let mut s = 0.0;
                for r in 0..l {
                    s += cw[(i, r)] * c_t[(r, i)];
                }
                s
            })
            .collect();

        NystromGram {
            engine: GramEngine::new(
                Layout::Full,
                ParallelProduct::new(LowRankProduct::new(cw, c_t), threads),
                NoReduce,
                None,
                diag,
                cache_rows,
            ),
        }
    }

    /// Effective approximation rank `l`.
    pub fn rank(&self) -> usize {
        self.engine.product().inner().rank()
    }

    /// Frobenius-relative error of the approximation against the exact
    /// kernel (O(m²·l); diagnostics only).
    pub fn approx_error(&mut self, a: &Csr, kernel: Kernel) -> f64 {
        let m = self.engine.m();
        let mut exact = LocalGram::new(a.clone(), kernel);
        let full: Vec<usize> = (0..m).collect();
        let mut k_exact = Mat::zeros(m, m);
        exact.gram(&full, &mut k_exact, &mut Ledger::new());
        let mut k_hat = Mat::zeros(m, m);
        self.engine.gram(&full, &mut k_hat, &mut Ledger::new());
        let mut num = 0.0;
        let mut den = 0.0;
        for (x, y) in k_hat.data().iter().zip(k_exact.data()) {
            num += (x - y) * (x - y);
            den += y * y;
        }
        (num / den.max(f64::MIN_POSITIVE)).sqrt()
    }
}

impl GramOracle for NystromGram {
    fn m(&self) -> usize {
        self.engine.m()
    }

    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.engine.gram(sample, q, ledger);
    }

    fn diag(&self) -> Vec<f64> {
        self.engine.diag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Phase;
    use crate::data::gen_dense_classification;
    use crate::solvers::{dcd, SvmParams, SvmVariant};

    fn dataset() -> Csr {
        gen_dense_classification(50, 6, 0.0, 777).a
    }

    #[test]
    fn full_rank_nystrom_is_exact() {
        let a = dataset();
        for kernel in [Kernel::Linear, Kernel::paper_rbf()] {
            let mut ny = NystromGram::new(&a, kernel, 50, 0.0, 1);
            let err = ny.approx_error(&a, kernel);
            assert!(err < 1e-6, "{kernel:?}: full-rank error {err}");
        }
    }

    #[test]
    fn approximation_error_decreases_with_rank() {
        let a = dataset();
        let kernel = Kernel::paper_rbf();
        let errs: Vec<f64> = [5usize, 15, 40]
            .iter()
            .map(|&l| NystromGram::new(&a, kernel, l, 1e-10, 2).approx_error(&a, kernel))
            .collect();
        assert!(
            errs[0] > errs[1] && errs[1] > errs[2],
            "error should fall with rank: {errs:?}"
        );
    }

    #[test]
    fn nystrom_dcd_approximates_exact_dcd() {
        // Train K-SVM through the approximate oracle; the solution must
        // land near the exact-oracle solution at high rank. The RBF gram
        // must have a decaying spectrum for low rank to make sense, so
        // features are scaled to unit-order pairwise distances (a
        // near-identity gram — unscaled gaussians — is the worst case
        // for *any* low-rank method).
        let mut ds = gen_dense_classification(40, 5, 0.05, 888);
        {
            let mut a = ds.a.to_dense();
            for v in a.data_mut() {
                *v /= (5.0f64).sqrt();
            }
            ds.a = Csr::from_dense(&a);
        }
        let kernel = Kernel::paper_rbf();
        let p = SvmParams {
            c: 1.0,
            variant: SvmVariant::L2,
            h: 600,
            seed: 9,
        };
        let mut exact = LocalGram::new(ds.a.clone(), kernel);
        let a_exact = dcd(&mut exact, &ds.y, &p, &mut Ledger::new(), None);
        let mut ny = NystromGram::new(&ds.a, kernel, 38, 1e-10, 3);
        let a_ny = dcd(&mut ny, &ds.y, &p, &mut Ledger::new(), None);
        let dev = crate::dense::rel_err(&a_ny, &a_exact);
        assert!(dev < 0.05, "high-rank nystrom deviation {dev}");
    }

    #[test]
    fn cached_nystrom_is_bitwise_equal_to_uncached() {
        let a = dataset();
        let kernel = Kernel::paper_rbf();
        let mut plain = NystromGram::new(&a, kernel, 20, 1e-10, 4);
        let mut cached = NystromGram::with_cache(&a, kernel, 20, 1e-10, 4, 8);
        let mut rng = crate::rng::Pcg::seeded(3);
        for _ in 0..15 {
            let k = rng.gen_range(1, 6);
            let sample: Vec<usize> = (0..k).map(|_| rng.gen_below(50)).collect();
            let mut q1 = Mat::zeros(k, 50);
            let mut q2 = Mat::zeros(k, 50);
            plain.gram(&sample, &mut q1, &mut Ledger::new());
            cached.gram(&sample, &mut q2, &mut Ledger::new());
            assert_eq!(q1.data(), q2.data());
        }
    }

    #[test]
    fn diag_matches_gram_diagonal() {
        let a = dataset();
        let mut ny = NystromGram::new(&a, Kernel::paper_rbf(), 20, 1e-10, 4);
        let diag = ny.diag();
        let sample: Vec<usize> = (0..50).collect();
        let mut q = Mat::zeros(50, 50);
        ny.gram(&sample, &mut q, &mut Ledger::new());
        for i in 0..50 {
            assert!((diag[i] - q[(i, i)]).abs() < 1e-9);
        }
    }

    #[test]
    fn kernel_flops_scale_with_rank_not_nnz() {
        let a = dataset(); // 50×6 dense ⇒ nnz = 300
        let mut ny = NystromGram::new(&a, Kernel::paper_rbf(), 10, 1e-10, 5);
        let mut ledger = Ledger::new();
        let mut q = Mat::zeros(4, 50);
        ny.gram(&[1, 2, 3, 4], &mut q, &mut ledger);
        let expect = 2.0 * 4.0 * 10.0 * 50.0;
        assert_eq!(ledger.flops(Phase::KernelCompute), expect);
    }
}
