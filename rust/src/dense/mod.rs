//! Dense linear-algebra substrate.
//!
//! The paper's reference implementation leans on Intel MKL for the dense
//! BLAS pieces (gram blocks, small `b×b` solves). We build the required
//! subset from scratch: a row-major matrix type, GEMM/GEMV, Cholesky and
//! LU factorizations with solves, and the norms used by the convergence
//! metrics. Everything is `f64`; the f32 fast path lives in the PJRT
//! runtime (L1/L2 artifacts).

#![forbid(unsafe_code)]

mod mat;
mod factor;

pub use factor::{cholesky_solve, lu_solve, Cholesky, Lu};
pub use mat::Mat;

/// `y ← A x` for row-major `A (m×n)`.
pub fn gemv(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.ncols(), x.len(), "gemv: dim mismatch");
    assert_eq!(a.nrows(), y.len(), "gemv: dim mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        let row = a.row(i);
        *yi = dot(row, x);
    }
}

/// `y ← Aᵀ x` for row-major `A (m×n)`, accumulating column-wise.
pub fn gemv_t(a: &Mat, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.nrows(), x.len(), "gemv_t: dim mismatch");
    assert_eq!(a.ncols(), y.len(), "gemv_t: dim mismatch");
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (yj, &aij) in y.iter_mut().zip(row) {
            *yj += xi * aij;
        }
    }
}

/// Dot product with 4-way unrolled accumulation (better ILP and slightly
/// better rounding than a single serial accumulator).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in 4 * chunks..a.len() {
        tail += a[i] * b[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `C ← A Bᵀ` (`A: m×k`, `B: n×k`, `C: m×n`). This is the shape of every
/// gram-block product in the solvers (`A_S Aᵀ` with both operands stored
/// row-major), so it gets the tuned loop: row×row dot products are fully
/// contiguous.
pub fn gemm_nt(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.ncols(), b.ncols(), "gemm_nt: inner dim");
    assert_eq!(c.nrows(), a.nrows(), "gemm_nt: rows");
    assert_eq!(c.ncols(), b.nrows(), "gemm_nt: cols");
    for i in 0..a.nrows() {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (j, cij) in crow.iter_mut().enumerate() {
            *cij = dot(arow, b.row(j));
        }
    }
}

/// `C ← A B` (`A: m×k`, `B: k×n`, `C: m×n`), ikj loop order so the inner
/// loop streams rows of `B` and `C`.
pub fn gemm_nn(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.ncols(), b.nrows(), "gemm_nn: inner dim");
    assert_eq!(c.nrows(), a.nrows(), "gemm_nn: rows");
    assert_eq!(c.ncols(), b.ncols(), "gemm_nn: cols");
    c.fill(0.0);
    let n = b.ncols();
    for i in 0..a.nrows() {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(k);
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Euclidean norm.
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// `y ← y + alpha x`.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Relative two-norm distance `‖a − b‖ / max(‖b‖, ε)`.
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (&ai, &bi) in a.iter().zip(b) {
        num += (ai - bi) * (ai - bi);
        den += bi * bi;
    }
    num.sqrt() / den.sqrt().max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg;

    fn rand_mat(r: &mut Pcg, m: usize, n: usize) -> Mat {
        let mut a = Mat::zeros(m, n);
        for v in a.data_mut() {
            *v = r.next_gaussian();
        }
        a
    }

    #[test]
    fn gemv_matches_naive() {
        let mut r = Pcg::seeded(1);
        for _ in 0..20 {
            let m = r.gen_range(1, 30);
            let n = r.gen_range(1, 30);
            let a = rand_mat(&mut r, m, n);
            let x: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            let mut y = vec![0.0; m];
            gemv(&a, &x, &mut y);
            for i in 0..m {
                let naive: f64 = (0..n).map(|j| a[(i, j)] * x[j]).sum();
                assert!((y[i] - naive).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gemv_t_matches_naive() {
        let mut r = Pcg::seeded(2);
        for _ in 0..20 {
            let m = r.gen_range(1, 30);
            let n = r.gen_range(1, 30);
            let a = rand_mat(&mut r, m, n);
            let x: Vec<f64> = (0..m).map(|_| r.next_gaussian()).collect();
            let mut y = vec![0.0; n];
            gemv_t(&a, &x, &mut y);
            for j in 0..n {
                let naive: f64 = (0..m).map(|i| a[(i, j)] * x[i]).sum();
                assert!((y[j] - naive).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let mut r = Pcg::seeded(3);
        for _ in 0..10 {
            let m = r.gen_range(1, 20);
            let k = r.gen_range(1, 20);
            let n = r.gen_range(1, 20);
            let a = rand_mat(&mut r, m, k);
            let b = rand_mat(&mut r, n, k);
            let mut c = Mat::zeros(m, n);
            gemm_nt(&a, &b, &mut c);
            for i in 0..m {
                for j in 0..n {
                    let naive: f64 = (0..k).map(|t| a[(i, t)] * b[(j, t)]).sum();
                    assert!((c[(i, j)] - naive).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn gemm_nn_matches_gemm_nt_via_transpose() {
        let mut r = Pcg::seeded(4);
        for _ in 0..10 {
            let m = r.gen_range(1, 15);
            let k = r.gen_range(1, 15);
            let n = r.gen_range(1, 15);
            let a = rand_mat(&mut r, m, k);
            let b = rand_mat(&mut r, k, n);
            let bt = b.transpose();
            let mut c1 = Mat::zeros(m, n);
            let mut c2 = Mat::zeros(m, n);
            gemm_nn(&a, &b, &mut c1);
            gemm_nt(&a, &bt, &mut c2);
            for (x, y) in c1.data().iter().zip(c2.data()) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dot_is_accurate() {
        let a: Vec<f64> = (0..1001).map(|i| (i as f64) * 0.25).collect();
        let b: Vec<f64> = (0..1001).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let v = vec![1.0, -2.0, 3.0];
        assert_eq!(rel_err(&v, &v), 0.0);
    }

    #[test]
    fn axpy_and_nrm2() {
        let x = vec![3.0, 4.0];
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
