//! Small dense factorizations: Cholesky and LU with partial pivoting.
//!
//! These back the `b×b` subproblem solves in BDCD (`G Δα = rhs`, where
//! `G = (1/λ) VᵀU + mI` is symmetric positive definite) and the `m×m`
//! closed-form K-RR solve used as the convergence reference.

use super::Mat;

/// Cholesky factorization `A = L Lᵀ` of a symmetric positive-definite
/// matrix (lower triangle stored).
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor `a` (reads the lower triangle). Returns `None` if a
    /// non-positive pivot is encountered (not SPD, up to roundoff).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        let n = a.nrows();
        assert_eq!(n, a.ncols(), "Cholesky: square required");
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // Split-borrow the two rows we need.
                let s = {
                    let (ri, rj) = (l.row(i), l.row(j));
                    super::dot(&ri[..j], &rj[..j])
                };
                if i == j {
                    let d = a[(i, i)] - s;
                    if d <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = d.sqrt();
                } else {
                    l[(i, j)] = (a[(i, j)] - s) / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Solve `A x = b` in place.
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let n = self.l.nrows();
        assert_eq!(b.len(), n);
        // Forward: L z = b
        for i in 0..n {
            let s = super::dot(&self.l.row(i)[..i], &b[..i]);
            b[i] = (b[i] - s) / self.l[(i, i)];
        }
        // Backward: Lᵀ x = z
        for i in (0..n).rev() {
            let mut s = 0.0;
            for k in i + 1..n {
                s += self.l[(k, i)] * b[k];
            }
            b[i] = (b[i] - s) / self.l[(i, i)];
        }
    }

    /// Solve returning a fresh vector.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }
}

/// LU factorization with partial pivoting, `P A = L U`.
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
}

impl Lu {
    /// Factor `a`. Returns `None` on exact singularity.
    pub fn new(a: &Mat) -> Option<Lu> {
        let n = a.nrows();
        assert_eq!(n, a.ncols(), "LU: square required");
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot search.
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax == 0.0 {
                return None;
            }
            if p != k {
                piv.swap(p, k);
                // Swap the full rows.
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = t;
                }
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != 0.0 {
                    // Row update: row_i -= m * row_k (tail only).
                    for j in k + 1..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= m * v;
                    }
                }
            }
        }
        Some(Lu { lu, piv })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.nrows();
        assert_eq!(b.len(), n);
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // Forward: L z = Pb (unit diagonal).
        for i in 0..n {
            let s = super::dot(&self.lu.row(i)[..i], &x[..i]);
            x[i] -= s;
        }
        // Backward: U x = z.
        for i in (0..n).rev() {
            let mut s = 0.0;
            for k in i + 1..n {
                s += self.lu[(i, k)] * x[k];
            }
            x[i] = (x[i] - s) / self.lu[(i, i)];
        }
        x
    }
}

/// One-shot SPD solve via Cholesky, falling back to LU if the matrix is
/// not numerically SPD (can happen with aggressive kernel parameters).
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Vec<f64> {
    match Cholesky::new(a) {
        Some(ch) => ch.solve(b),
        None => lu_solve(a, b),
    }
}

/// One-shot general solve via partially-pivoted LU. Panics on singular
/// input (the solvers only pass regularized, nonsingular systems).
pub fn lu_solve(a: &Mat, b: &[f64]) -> Vec<f64> {
    Lu::new(a).expect("lu_solve: singular matrix").solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::{gemm_nt, gemv};
    use crate::rng::Pcg;

    /// Random SPD matrix `B Bᵀ + n·I`.
    fn rand_spd(r: &mut Pcg, n: usize) -> Mat {
        let mut b = Mat::zeros(n, n);
        for v in b.data_mut() {
            *v = r.next_gaussian();
        }
        let mut a = Mat::zeros(n, n);
        gemm_nt(&b, &b, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn cholesky_recovers_solution() {
        let mut r = Pcg::seeded(31);
        for _ in 0..20 {
            let n = r.gen_range(1, 40);
            let a = rand_spd(&mut r, n);
            let xstar: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            let mut b = vec![0.0; n];
            gemv(&a, &xstar, &mut b);
            let x = Cholesky::new(&a).expect("SPD").solve(&b);
            for (xi, xs) in x.iter().zip(&xstar) {
                assert!((xi - xs).abs() < 1e-8, "{xi} vs {xs}");
            }
        }
    }

    #[test]
    fn lu_recovers_solution_nonsymmetric() {
        let mut r = Pcg::seeded(37);
        for _ in 0..20 {
            let n = r.gen_range(1, 40);
            let mut a = Mat::zeros(n, n);
            for v in a.data_mut() {
                *v = r.next_gaussian();
            }
            // Diagonal dominance to keep conditioning sane.
            for i in 0..n {
                a[(i, i)] += n as f64;
            }
            let xstar: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
            let mut b = vec![0.0; n];
            gemv(&a, &xstar, &mut b);
            let x = Lu::new(&a).expect("nonsingular").solve(&b);
            for (xi, xs) in x.iter().zip(&xstar) {
                assert!((xi - xs).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::new(&a).is_none());
    }

    #[test]
    fn pivoting_handles_zero_leading_entry() {
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let x = Lu::new(&a).unwrap().solve(&[2.0, 3.0]);
        assert!((x[0] - 3.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }

    #[test]
    fn cholesky_solve_falls_back_to_lu() {
        // Symmetric but indefinite: cholesky_solve must still solve it.
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        let x = cholesky_solve(&a, &[3.0, 3.0]);
        // A x = b -> x = [1, 1]
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 1.0).abs() < 1e-12);
    }
}
