//! Row-major dense matrix type.

use std::fmt;
use std::ops::{Index, IndexMut};

/// A row-major dense `f64` matrix.
///
/// Row-major is the natural layout for the solvers: gram blocks are built
/// row-by-row (one row per sampled coordinate) and all hot products are
/// row×row dots.
#[derive(Clone, PartialEq)]
pub struct Mat {
    nrows: usize,
    ncols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// An `m×n` matrix of zeros.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Mat {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    /// Build from a row-major data vector.
    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), nrows * ncols, "Mat::from_vec: length mismatch");
        Mat { nrows, ncols, data }
    }

    /// Build from a closure over `(i, j)`.
    pub fn from_fn(nrows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(nrows * ncols);
        for i in 0..nrows {
            for j in 0..ncols {
                data.push(f(i, j));
            }
        }
        Mat { nrows, ncols, data }
    }

    /// The `n×n` identity.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Number of rows.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Borrow row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.nrows);
        &self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.nrows);
        &mut self.data[i * self.ncols..(i + 1) * self.ncols]
    }

    /// Full backing slice (row-major).
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing slice (row-major).
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Set every entry to `v`.
    pub fn fill(&mut self, v: f64) {
        self.data.fill(v);
    }

    /// Copy column `j` into a fresh vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        assert!(j < self.ncols);
        (0..self.nrows).map(|i| self[(i, j)]).collect()
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.ncols, self.nrows);
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Gather the given rows into a new matrix (used to form `A_S`).
    pub fn gather_rows(&self, rows: &[usize]) -> Mat {
        let mut out = Mat::zeros(rows.len(), self.ncols);
        for (dst, &src) in rows.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Slice columns `[c0, c1)` into a new matrix (1D-column partitioning).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.ncols);
        let mut out = Mat::zeros(self.nrows, c1 - c0);
        for i in 0..self.nrows {
            out.row_mut(i)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Squared Euclidean norm of each row (cached for the RBF kernel map).
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.nrows)
            .map(|i| super::dot(self.row(i), self.row(i)))
            .collect()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        &self.data[i * self.ncols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        &mut self.data[i * self.ncols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.nrows, self.ncols)?;
        for i in 0..self.nrows.min(8) {
            writeln!(f, "  {:?}", &self.row(i)[..self.ncols.min(8)])?;
        }
        if self.nrows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn gather_rows_picks_rows() {
        let m = Mat::from_fn(4, 2, |i, _| i as f64);
        let g = m.gather_rows(&[3, 1]);
        assert_eq!(g.row(0), &[3.0, 3.0]);
        assert_eq!(g.row(1), &[1.0, 1.0]);
    }

    #[test]
    fn slice_cols_works() {
        let m = Mat::from_fn(2, 5, |_, j| j as f64);
        let s = m.slice_cols(1, 4);
        assert_eq!(s.ncols(), 3);
        assert_eq!(s.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn eye_and_row_norms() {
        let e = Mat::eye(3);
        assert_eq!(e.row_norms_sq(), vec![1.0, 1.0, 1.0]);
    }
}
