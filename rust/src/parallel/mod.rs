//! Intra-rank threaded execution: a persistent deterministic worker
//! pool and the [`ParallelProduct`] adapter that splits the sampled rows
//! of any product stage across worker threads.
//!
//! The s-step methods buy back communication time, which leaves the
//! sampled gram product as the per-iteration wall on a multicore node
//! (the same observation that drives the hybrid MPI×threads setups of
//! the communication-avoiding literature). This module adds the missing
//! axis: `t` worker threads *inside* one rank, composing with the
//! column-sharded [`crate::solvers::DistGram`] ranks for hybrid
//! `P ranks × t threads` scaling.
//!
//! ### Determinism
//!
//! Every [`ProductStage`] computes each output row independently of the
//! other rows in the call, with a fixed per-entry summation order — the
//! engine's cache-transparency contract
//! (see [`crate::gram`]). Row partitioning therefore commutes with the
//! computation: each sampled row is computed by exactly one worker, with
//! exactly the arithmetic the serial stage would perform, so the
//! assembled block is **bitwise identical for every thread count**. The
//! partition itself is a pure function of `(rows, threads)` (contiguous
//! near-equal ranges), no work stealing, no clock — a run with `t = 8`
//! replays the bits of a run with `t = 1`. Pinned by
//! `rust/tests/threaded_product_props.rs`. The same split (and the same
//! guarantee) now also covers the pointwise kernel epilogue via
//! [`ProductStage::apply_epilogue`].
//!
//! ### The pool
//!
//! [`WorkerPool`] spawns its threads once and reuses them for every
//! `run` call — a solve issues thousands of gram calls, and respawning
//! `t − 1` OS threads per call is pure per-iteration latency (the φ-like
//! term the overlap work is trying to hide). Job 0 always runs on the
//! calling thread, so `t = 1` never touches the pool, and job order is
//! the partition order — results come back in job order, exactly like
//! the scoped [`scoped_run`] it replaces on the hot path (which is kept
//! for one-shot callers).

#![deny(unsafe_op_in_unsafe_fn)]

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::dense::Mat;
use crate::gram::{BlockKind, Epilogue, ProductCost, ProductStage};
use crate::sparse::Csr;

/// Contiguous near-equal partition bounds: `bounds[i]..bounds[i+1]` is
/// worker `i`'s range. `parts + 1` entries, monotone, covering `0..n`.
pub fn partition_bounds(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts >= 1, "partition into at least one part");
    (0..=parts).map(|i| i * n / parts).collect()
}

/// Contiguous *weighted* partition bounds: split `0..weights.len()`
/// into `parts` ranges whose weight sums are near-equal — the
/// nnz-balanced row split for skewed sparse matrices, where equal
/// *counts* leave one worker holding all the heavy rows.
///
/// Boundary `i` is the smallest index whose weight prefix reaches
/// `total·i/parts` (exact integer arithmetic, no float), so the result
/// is monotone, covers `0..n`, and is a **pure function of
/// `(weights, parts)`** — invariant to threads, cache state, and
/// everything else ambient, as the bitwise-determinism contract
/// requires of a layout decision. No range's weight exceeds
/// `total/parts + max(weights)` (each boundary overshoots its target
/// by less than one row). All-zero weights fall back to
/// [`partition_bounds`].
pub fn partition_by_weight(weights: &[u64], parts: usize) -> Vec<usize> {
    assert!(parts >= 1, "partition into at least one part");
    let n = weights.len();
    let total: u128 = weights.iter().map(|&w| u128::from(w)).sum();
    if total == 0 {
        return partition_bounds(n, parts);
    }
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    let mut prefix: u128 = 0;
    let mut idx = 0usize;
    for part in 1..parts {
        let target = total * part as u128 / parts as u128;
        while idx < n && prefix < target {
            prefix += u128::from(weights[idx]);
            idx += 1;
        }
        bounds.push(idx);
    }
    bounds.push(n);
    bounds
}

/// Run one job per worker on scoped threads and return the results in
/// worker order. Job 0 runs on the calling thread (no spawn for the
/// single-worker case). Panics in any worker propagate.
///
/// One-shot helper; repeated callers should hold a [`WorkerPool`]
/// instead and skip the per-call spawns.
pub fn scoped_run<T, F>(mut jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    assert!(!jobs.is_empty(), "scoped_run needs at least one job");
    if jobs.len() == 1 {
        let job = jobs.pop().expect("one job");
        return vec![job()];
    }
    let first = jobs.remove(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = jobs.into_iter().map(|job| scope.spawn(job)).collect();
        let mut out = Vec::with_capacity(handles.len() + 1);
        out.push(first());
        for h in handles {
            out.push(h.join().expect("parallel worker panicked"));
        }
        out
    })
}

/// A job shipped to a persistent worker, with its borrows erased — see
/// the safety argument in [`WorkerPool::run`].
type Job = Box<dyn FnOnce() + Send + 'static>;

struct WorkerHandle {
    /// `None` once the pool is shutting down (dropping the sender is the
    /// worker's exit signal).
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<std::thread::Result<()>>,
    join: Option<JoinHandle<()>>,
}

/// Persistent worker threads, spawned once and reused across calls.
///
/// `run` dispatches jobs 1.. to the workers, runs job 0 on the calling
/// thread, and blocks until every dispatched job has reported done — so
/// jobs may freely borrow the caller's stack even though the worker
/// threads themselves are `'static`. Panics inside any job are caught on
/// the worker, relayed over the done channel, and re-raised on the
/// caller *after* all jobs finish (the workers hold borrows into the
/// caller's frame, so unwinding early would be unsound).
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
}

impl WorkerPool {
    /// Spawn `extra_workers` persistent threads (`run` can then execute
    /// up to `extra_workers + 1` jobs per call). Zero is fine: the pool
    /// degenerates to running everything on the caller.
    pub fn new(extra_workers: usize) -> WorkerPool {
        let workers = (0..extra_workers)
            .map(|i| {
                let (job_tx, job_rx) = channel::<Job>();
                let (done_tx, done_rx) = channel::<std::thread::Result<()>>();
                let join = std::thread::Builder::new()
                    .name(format!("kcd-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = job_rx.recv() {
                            let result = catch_unwind(AssertUnwindSafe(job));
                            if done_tx.send(result).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn pool worker");
                WorkerHandle {
                    job_tx: Some(job_tx),
                    done_rx,
                    join: Some(join),
                }
            })
            .collect();
        WorkerPool { workers }
    }

    /// Number of persistent worker threads (excluding the caller).
    pub fn extra_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `jobs` (at most `extra_workers + 1` of them): job 0 on the
    /// calling thread, the rest on the persistent workers. Returns the
    /// results in job order. Blocks until every job has finished, then
    /// propagates any panic.
    pub fn run<T, F>(&mut self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        assert!(!jobs.is_empty(), "WorkerPool::run needs at least one job");
        assert!(
            jobs.len() <= self.workers.len() + 1,
            "WorkerPool::run: {} jobs but only {} workers + the caller",
            jobs.len(),
            self.workers.len()
        );
        let n = jobs.len();
        let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let (first_slot, rest_slots) = slots.split_at_mut(1);

        let mut iter = jobs.into_iter();
        let first = iter.next().expect("nonempty");
        let mut dispatched: Vec<&WorkerHandle> = Vec::with_capacity(n - 1);
        for ((job, slot), worker) in iter.zip(rest_slots.iter_mut()).zip(&self.workers) {
            let boxed: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                *slot = Some(job());
            });
            // SAFETY: lifetime erasure only. `run` does not return (and
            // does not unwind) until this worker reports the job done via
            // `done_rx` below, so every borrow captured by the job — the
            // result slot and whatever the caller's closure holds —
            // strictly outlives its execution on the worker thread.
            let boxed: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(boxed)
            };
            worker
                .job_tx
                .as_ref()
                .expect("pool is shutting down")
                .send(boxed)
                .expect("pool worker died");
            dispatched.push(worker);
        }

        // Job 0 on the calling thread. Catch its panic so we still drain
        // every worker before unwinding (they borrow our frame).
        let first_result = catch_unwind(AssertUnwindSafe(|| {
            first_slot[0] = Some(first());
        }));
        let mut worker_panic = None;
        for w in dispatched {
            match w.done_rx.recv().expect("pool worker died") {
                Ok(()) => {}
                Err(p) => worker_panic = Some(p),
            }
        }
        if let Err(p) = first_result {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every job ran"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            w.job_tx = None; // closes the channel; the worker loop exits
        }
        for w in &mut self.workers {
            if let Some(h) = w.join.take() {
                let _ = h.join();
            }
        }
    }
}

/// Below this nnz the threaded transpose falls back to the serial
/// counting sort: each worker allocates an `O(ncols)` count array, so
/// tiny matrices pay more in setup than the scatter costs.
const PARALLEL_TRANSPOSE_MIN_NNZ: usize = 1 << 13;

/// Transpose `a` on the pool's workers, **bitwise identical to
/// [`Csr::transpose`]** for every worker count — the construction-time
/// half of ROADMAP item 5's compute overheads (the per-call half being
/// the nnz-balanced product split).
///
/// Three phases, all deterministic:
///
/// 1. each worker counting-sorts a contiguous, nnz-balanced range of
///    input rows into a private sub-transpose (own counts / row-ids /
///    values, ascending source row within each column);
/// 2. the caller sums the per-worker column counts into the global
///    `indptr` (serial, `O(t·ncols)`);
/// 3. workers own contiguous, nnz-balanced output *column* ranges —
///    disjoint `indices`/`data` spans split at `indptr` boundaries —
///    and concatenate each column's per-range slabs in range order.
///
/// Row ranges ascend in source row and each worker scatters its rows
/// ascending, so every output column lists source rows in ascending
/// order — exactly the serial counting sort's order, hence equal
/// `indptr` / `indices` / `data` arrays (pinned by tests at every
/// worker count).
pub fn transpose_with_pool(a: &Csr, pool: &mut WorkerPool) -> Csr {
    let t = pool.extra_workers() + 1;
    if t == 1 || a.nnz() < PARALLEL_TRANSPOSE_MIN_NNZ {
        return a.transpose();
    }
    let (nrows, ncols, nnz) = (a.nrows(), a.ncols(), a.nnz());
    // Phase 1: per-worker sub-transposes over nnz-balanced row ranges.
    let row_w: Vec<u64> = (0..nrows).map(|i| a.row_nnz(i) as u64).collect();
    let rb = partition_by_weight(&row_w, t);
    let locals: Vec<(Vec<usize>, Vec<usize>, Vec<f64>)> = {
        let jobs: Vec<_> = (0..t)
            .map(|w| {
                let (r0, r1) = (rb[w], rb[w + 1]);
                move || {
                    let mut counts = vec![0usize; ncols + 1];
                    for i in r0..r1 {
                        let (cols, _) = a.row_parts(i);
                        for &j in cols {
                            counts[j + 1] += 1;
                        }
                    }
                    for j in 0..ncols {
                        counts[j + 1] += counts[j];
                    }
                    let sub_nnz = counts[ncols];
                    let mut rows = vec![0usize; sub_nnz];
                    let mut vals = vec![0.0f64; sub_nnz];
                    let mut cursor = counts.clone();
                    for i in r0..r1 {
                        for (j, v) in a.row_iter(i) {
                            let dst = cursor[j];
                            rows[dst] = i;
                            vals[dst] = v;
                            cursor[j] += 1;
                        }
                    }
                    (counts, rows, vals)
                }
            })
            .collect();
        pool.run(jobs)
    };
    // Phase 2: global column counts → indptr.
    let mut indptr = vec![0usize; ncols + 1];
    for j in 0..ncols {
        let col: usize = locals.iter().map(|(c, _, _)| c[j + 1] - c[j]).sum();
        indptr[j + 1] = indptr[j] + col;
    }
    debug_assert_eq!(indptr[ncols], nnz);
    // Phase 3: concatenate slabs into nnz-balanced output column ranges.
    let col_w: Vec<u64> = (0..ncols)
        .map(|j| (indptr[j + 1] - indptr[j]) as u64)
        .collect();
    let cb = partition_by_weight(&col_w, t);
    let mut indices = vec![0usize; nnz];
    let mut data = vec![0.0f64; nnz];
    {
        let mut idx_rest: &mut [usize] = &mut indices;
        let mut val_rest: &mut [f64] = &mut data;
        let mut jobs = Vec::with_capacity(t);
        for w in 0..t {
            let (c0, c1) = (cb[w], cb[w + 1]);
            let span = indptr[c1] - indptr[c0];
            let (idx_chunk, idx_tail) = std::mem::take(&mut idx_rest).split_at_mut(span);
            let (val_chunk, val_tail) = std::mem::take(&mut val_rest).split_at_mut(span);
            idx_rest = idx_tail;
            val_rest = val_tail;
            let locals = &locals;
            jobs.push(move || {
                let mut out = 0usize;
                for j in c0..c1 {
                    for (counts, rows, vals) in locals {
                        let (lo, hi) = (counts[j], counts[j + 1]);
                        let len = hi - lo;
                        idx_chunk[out..out + len].copy_from_slice(&rows[lo..hi]);
                        val_chunk[out..out + len].copy_from_slice(&vals[lo..hi]);
                        out += len;
                    }
                }
                debug_assert_eq!(out, span);
            });
        }
        pool.run(jobs);
    }
    Csr::new(ncols, nrows, indptr, indices, data)
}

/// Threaded adapter around any [`ProductStage`]: splits the sampled rows
/// of each `compute` call across `threads` workers.
///
/// Each worker owns a replica of the inner stage: the stages need
/// `&mut self` only for private scratch, and their bulk data (the CSR
/// matrix / low-rank factors) is `Arc`-shared, so replication costs
/// refcounts, not copies, and the hot path needs no synchronization.
/// Worker `i` computes the contiguous row range `bounds[i]..bounds[i+1]`
/// into its own sub-block, which is then copied into the caller's output
/// rows. With `threads = 1` (or a single sampled row) the call
/// short-circuits to the inner stage — no dispatch, no copy.
///
/// The `threads − 1` helper threads are spawned once (at construction)
/// and pinned for the adapter's lifetime in a [`WorkerPool`]; each
/// `compute` or `apply_epilogue` call reuses them.
///
/// Cost accounting is the worker-order sum of the per-worker costs,
/// which for every stage in the crate equals the serial cost exactly
/// (per-row costs are additive).
pub struct ParallelProduct<P> {
    /// One replica per worker; `workers[0]` doubles as the serial path.
    workers: Vec<P>,
    pool: WorkerPool,
}

impl<P: ProductStage + Clone> ParallelProduct<P> {
    /// Wrap `inner` with `threads` workers (`threads >= 1`).
    pub fn new(inner: P, threads: usize) -> ParallelProduct<P> {
        assert!(threads >= 1, "ParallelProduct needs at least one thread");
        Self::with_pool(inner, WorkerPool::new(threads - 1))
    }

    /// Wrap `inner` around an already-spawned pool (worker count
    /// `pool.extra_workers() + 1`). This is the construction path for
    /// oracles that first use the pool to build the stage's cached
    /// transpose ([`transpose_with_pool`]) — the same threads then
    /// serve every `compute` call, so the one-off construction cost
    /// parallelizes like the solve itself.
    pub fn with_pool(inner: P, pool: WorkerPool) -> ParallelProduct<P> {
        let threads = pool.extra_workers() + 1;
        let mut workers = Vec::with_capacity(threads);
        for _ in 1..threads {
            workers.push(inner.clone());
        }
        workers.push(inner);
        ParallelProduct { workers, pool }
    }
}

impl<P> ParallelProduct<P> {
    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// The original inner stage (the replica the serial path uses is
    /// identical — all workers are clones of this one).
    pub fn inner(&self) -> &P {
        self.workers.last().expect("at least one worker")
    }
}

impl<P: ProductStage + Send> ProductStage for ParallelProduct<P> {
    fn m(&self) -> usize {
        self.workers[0].m()
    }

    fn kind(&self) -> BlockKind {
        self.workers[0].kind()
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        let k = sample.len();
        let t = self.workers.len().min(k).max(1);
        if t == 1 {
            return self.workers[0].compute(sample, q);
        }
        let m = q.ncols();
        // nnz-balanced split when the stage can price its sampled rows
        // ([`ProductStage::sample_cost`]); row-count-balanced otherwise.
        // Pure layout: each row is still computed once, serially, by
        // exactly one worker, so the assembled block is bitwise
        // independent of which split was chosen.
        let bounds = match self.workers[0].sample_cost(sample) {
            Some(w) => {
                debug_assert_eq!(w.len(), k, "one weight per sampled row");
                partition_by_weight(&w, t)
            }
            None => partition_bounds(k, t),
        };
        // Hand each worker its row range and the matching contiguous
        // slice of the row-major output (disjoint by construction).
        let mut rest: &mut [f64] = q.data_mut();
        let mut jobs = Vec::with_capacity(t);
        for (i, worker) in self.workers.iter_mut().take(t).enumerate() {
            let rows = &sample[bounds[i]..bounds[i + 1]];
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows.len() * m);
            rest = tail;
            jobs.push(move || {
                let mut sub = Mat::zeros(rows.len(), m);
                let cost = worker.compute(rows, &mut sub);
                chunk.copy_from_slice(sub.data());
                cost
            });
        }
        let costs = self.pool.run(jobs);
        let mut total = ProductCost {
            flops: 0.0,
            rows_charged: 0,
        };
        for c in costs {
            total.flops += c.flops;
            total.rows_charged += c.rows_charged;
        }
        total
    }

    /// The epilogue over the same worker split as the product: each
    /// worker applies the pointwise kernel map to its contiguous run of
    /// whole rows. Per-element map ⇒ bitwise identical to the serial
    /// pass for every thread count.
    fn apply_epilogue(&mut self, epilogue: &Epilogue, rows: &[usize], q: &mut Mat) {
        let k = rows.len();
        let t = self.workers.len().min(k).max(1);
        if t == 1 {
            epilogue.apply(rows, q);
            return;
        }
        let m = q.ncols();
        let bounds = partition_bounds(k, t);
        let mut rest: &mut [f64] = q.data_mut();
        let mut jobs = Vec::with_capacity(t);
        for i in 0..t {
            let rr = &rows[bounds[i]..bounds[i + 1]];
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rr.len() * m);
            rest = tail;
            jobs.push(move || epilogue.apply_chunk(rr, chunk));
        }
        self.pool.run(jobs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_dense_classification, gen_uniform_sparse, SynthParams, Task};
    use crate::gram::CsrProduct;
    use crate::kernelfn::Kernel;
    use crate::rng::Pcg;

    #[test]
    fn partition_bounds_cover_and_are_monotone() {
        for n in [0usize, 1, 5, 7, 64] {
            for parts in [1usize, 2, 3, 8, 11] {
                let b = partition_bounds(n, parts);
                assert_eq!(b.len(), parts + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[parts], n);
                for i in 0..parts {
                    assert!(b[i] <= b[i + 1]);
                    // Near-equal: no range exceeds ceil(n/parts).
                    assert!(b[i + 1] - b[i] <= n.div_ceil(parts));
                }
            }
        }
    }

    #[test]
    fn scoped_run_returns_in_worker_order() {
        let jobs: Vec<_> = (0..7).map(|i| move || i * 10).collect();
        assert_eq!(scoped_run(jobs), vec![0, 10, 20, 30, 40, 50, 60]);
        let one = vec![|| 42];
        assert_eq!(scoped_run(one), vec![42]);
    }

    #[test]
    fn worker_pool_reuses_threads_across_calls() {
        let mut pool = WorkerPool::new(3);
        assert_eq!(pool.extra_workers(), 3);
        for round in 0..50 {
            // Jobs borrow the caller's stack — the data below lives in
            // this frame, not in a 'static.
            let base = vec![round; 4];
            let jobs: Vec<_> = (0..4).map(|i| {
                let base = &base;
                move || base[i] * 10 + i
            }).collect();
            let out = pool.run(jobs);
            let expect: Vec<usize> = (0..4).map(|i| round * 10 + i).collect();
            assert_eq!(out, expect);
        }
        // Fewer jobs than workers is fine, including the 1-job case.
        assert_eq!(pool.run(vec![|| 7]), vec![7]);
    }

    #[test]
    fn worker_pool_propagates_job_panics_and_survives() {
        let mut pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("worker job failed")),
                Box::new(|| 3),
            ];
            pool.run(jobs)
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // The pool is still usable after a job panicked.
        let out = pool.run(vec![|| 10, || 20, || 30]);
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    #[should_panic(expected = "jobs but only")]
    fn worker_pool_rejects_more_jobs_than_threads() {
        let mut pool = WorkerPool::new(1);
        let _ = pool.run(vec![|| 0, || 1, || 2]);
    }

    #[test]
    fn parallel_product_is_bitwise_identical_to_serial() {
        let dense = gen_dense_classification(30, 8, 0.0, 21).a;
        let sparse = gen_uniform_sparse(
            SynthParams {
                m: 26,
                n: 120,
                density: 0.05,
                seed: 9,
            },
            Task::Classification,
        )
        .a;
        for a in [dense, sparse] {
            let m = a.nrows();
            let mut rng = Pcg::seeded(5);
            // Duplicate-heavy with-replacement samples, incl. k < t.
            let samples: Vec<Vec<usize>> = (0..8)
                .map(|_| {
                    let k = rng.gen_range(1, 10);
                    (0..k).map(|_| rng.gen_below(m / 2 + 1)).collect()
                })
                .collect();
            let mut serial = CsrProduct::new(a.clone());
            for t in [1usize, 2, 3, 8, 16] {
                let mut par = ParallelProduct::new(CsrProduct::new(a.clone()), t);
                assert_eq!(par.threads(), t);
                assert_eq!(par.m(), serial.m());
                assert_eq!(par.kind(), serial.kind());
                for sample in &samples {
                    let mut q_ref = Mat::zeros(sample.len(), m);
                    let cost_ref = serial.compute(sample, &mut q_ref);
                    let mut q = Mat::zeros(sample.len(), m);
                    let cost = par.compute(sample, &mut q);
                    assert_eq!(q.data(), q_ref.data(), "t={t} sample {sample:?}");
                    assert_eq!(cost.rows_charged, cost_ref.rows_charged);
                    assert_eq!(cost.flops, cost_ref.flops, "additive exact counts");
                }
            }
        }
    }

    #[test]
    fn threaded_epilogue_is_bitwise_identical_to_serial() {
        let a = gen_dense_classification(24, 6, 0.0, 33).a;
        let m = a.nrows();
        let norms = a.row_norms_sq();
        let mut rng = Pcg::seeded(17);
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let ep = Epilogue::new(kernel, norms.clone());
            for t in [1usize, 2, 3, 8] {
                let mut par = ParallelProduct::new(CsrProduct::new(a.clone()), t);
                for _ in 0..4 {
                    let k = rng.gen_range(1, 9);
                    let rows: Vec<usize> = (0..k).map(|_| rng.gen_below(m)).collect();
                    let mut q = Mat::zeros(k, m);
                    par.compute(&rows, &mut q);
                    let mut q_ref = q.clone();
                    ep.apply(&rows, &mut q_ref);
                    par.apply_epilogue(&ep, &rows, &mut q);
                    assert_eq!(q.data(), q_ref.data(), "{kernel:?} t={t}");
                }
            }
        }
    }

    #[test]
    fn single_row_and_more_threads_than_rows_work() {
        let a = gen_dense_classification(12, 4, 0.0, 3).a;
        let mut serial = CsrProduct::new(a.clone());
        let mut par = ParallelProduct::new(CsrProduct::new(a), 8);
        let mut q_ref = Mat::zeros(1, 12);
        serial.compute(&[5], &mut q_ref);
        let mut q = Mat::zeros(1, 12);
        par.compute(&[5], &mut q);
        assert_eq!(q.data(), q_ref.data());
    }

    #[test]
    #[should_panic(expected = "at least one thread")]
    fn zero_threads_is_rejected() {
        let a = gen_dense_classification(4, 2, 0.0, 1).a;
        let _ = ParallelProduct::new(CsrProduct::new(a), 0);
    }

    #[test]
    fn partition_by_weight_covers_and_is_monotone() {
        let mut rng = Pcg::seeded(71);
        for n in [0usize, 1, 5, 64, 257] {
            let weights: Vec<u64> = (0..n).map(|_| rng.gen_below(100) as u64).collect();
            for parts in [1usize, 2, 3, 8, 11] {
                let b = partition_by_weight(&weights, parts);
                assert_eq!(b.len(), parts + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[parts], n);
                for i in 0..parts {
                    assert!(b[i] <= b[i + 1]);
                }
                // Balance: no range exceeds the perfect share by more
                // than one row's weight.
                let total: u64 = weights.iter().sum();
                let max_w = weights.iter().copied().max().unwrap_or(0);
                for i in 0..parts {
                    let w: u64 = weights[b[i]..b[i + 1]].iter().sum();
                    assert!(
                        w <= total / parts as u64 + max_w + 1,
                        "part {i} weight {w} vs share {} + max {max_w}",
                        total / parts as u64
                    );
                }
            }
        }
        // All-zero weights fall back to the count split.
        assert_eq!(partition_by_weight(&[0, 0, 0, 0], 2), partition_bounds(4, 2));
    }

    /// The ISSUE acceptance property: on a skewed matrix the weighted
    /// split's worst-loaded worker is strictly better than the
    /// row-count split's, at every worker count 2..=8.
    #[test]
    fn weighted_split_strictly_improves_skewed_imbalance() {
        // One pathologically heavy head row + a light tail.
        let mut weights = vec![1u64; 64];
        weights[0] = 1000;
        weights[1] = 500;
        let max_load = |bounds: &[usize]| -> u64 {
            bounds
                .windows(2)
                .map(|w| weights[w[0]..w[1]].iter().sum())
                .max()
                .unwrap()
        };
        for parts in 2..=8usize {
            let uniform = max_load(&partition_bounds(weights.len(), parts));
            let weighted = max_load(&partition_by_weight(&weights, parts));
            assert!(
                weighted < uniform,
                "parts={parts}: weighted {weighted} !< uniform {uniform}"
            );
        }
    }

    fn assert_csr_equal(a: &Csr, b: &Csr, tag: &str) {
        assert_eq!(a.nrows(), b.nrows(), "{tag}: nrows");
        assert_eq!(a.ncols(), b.ncols(), "{tag}: ncols");
        assert_eq!(a.nnz(), b.nnz(), "{tag}: nnz");
        for i in 0..a.nrows() {
            let (ci, vi) = a.row_parts(i);
            let (cj, vj) = b.row_parts(i);
            assert_eq!(ci, cj, "{tag}: row {i} indices");
            // Bitwise, not approximate: the stored arrays must be equal.
            let vi_bits: Vec<u64> = vi.iter().map(|v| v.to_bits()).collect();
            let vj_bits: Vec<u64> = vj.iter().map(|v| v.to_bits()).collect();
            assert_eq!(vi_bits, vj_bits, "{tag}: row {i} values");
        }
    }

    /// The pooled transpose replays the serial counting sort's arrays
    /// exactly — above the serial-fallback threshold (so the 3-phase
    /// path actually runs) and below it, at every worker count.
    #[test]
    fn pooled_transpose_is_bitwise_identical_to_serial() {
        // ~12k nnz: well above PARALLEL_TRANSPOSE_MIN_NNZ.
        let big = gen_uniform_sparse(
            SynthParams {
                m: 200,
                n: 300,
                density: 0.2,
                seed: 77,
            },
            Task::Classification,
        )
        .a;
        assert!(big.nnz() >= PARALLEL_TRANSPOSE_MIN_NNZ, "test must hit the threaded path");
        // Small: exercises the serial fallback.
        let small = gen_uniform_sparse(
            SynthParams {
                m: 30,
                n: 50,
                density: 0.1,
                seed: 78,
            },
            Task::Classification,
        )
        .a;
        for a in [big, small] {
            let want = a.transpose();
            for extra in [0usize, 1, 2, 3, 7] {
                let mut pool = WorkerPool::new(extra);
                let got = transpose_with_pool(&a, &mut pool);
                assert_csr_equal(&got, &want, &format!("t={}", extra + 1));
            }
        }
    }

    /// A skewed matrix (heavy head rows, empty columns) through the
    /// threaded path: the nnz-balanced row ranges and column ranges
    /// must still reproduce the serial arrays bit for bit.
    #[test]
    fn pooled_transpose_handles_skew_and_empty_columns() {
        let mut rng = Pcg::seeded(91);
        let mut trips = Vec::new();
        // Two dense head rows over the first half of the columns...
        for i in 0..2usize {
            for j in 0..3000usize {
                trips.push((i, j, rng.next_gaussian()));
            }
        }
        // ...then a sparse tail; columns 6000.. stay empty.
        for i in 2..400usize {
            for _ in 0..10 {
                trips.push((i, rng.gen_below(6000), rng.next_gaussian()));
            }
        }
        let a = Csr::from_triplets(400, 7000, &trips);
        assert!(a.nnz() >= PARALLEL_TRANSPOSE_MIN_NNZ);
        let want = a.transpose();
        for extra in [1usize, 3, 7] {
            let mut pool = WorkerPool::new(extra);
            let got = transpose_with_pool(&a, &mut pool);
            assert_csr_equal(&got, &want, &format!("skew t={}", extra + 1));
        }
    }

    /// `with_pool` + a pool-built transpose is the oracle construction
    /// path; its compute must replay `new`'s bits (which replays
    /// serial's, per the tests above).
    #[test]
    fn with_pool_construction_matches_new() {
        let a = gen_uniform_sparse(
            SynthParams {
                m: 24,
                n: 100,
                density: 0.08,
                seed: 13,
            },
            Task::Classification,
        )
        .a;
        let mut reference = ParallelProduct::new(CsrProduct::new(a.clone()), 3);
        let mut pool = WorkerPool::new(2);
        let at = Some(std::sync::Arc::new(transpose_with_pool(&a, &mut pool)));
        let mut pooled =
            ParallelProduct::with_pool(CsrProduct::with_transpose(std::sync::Arc::new(a), at), pool);
        assert_eq!(pooled.threads(), 3);
        let sample = vec![1usize, 7, 7, 20, 3];
        let mut q_ref = Mat::zeros(sample.len(), reference.m());
        reference.compute(&sample, &mut q_ref);
        let mut q = Mat::zeros(sample.len(), pooled.m());
        pooled.compute(&sample, &mut q);
        assert_eq!(q.data(), q_ref.data());
    }
}
