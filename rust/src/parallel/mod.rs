//! Intra-rank threaded execution: a small deterministic scoped-thread
//! pool and the [`ParallelProduct`] adapter that splits the sampled rows
//! of any product stage across worker threads.
//!
//! The s-step methods buy back communication time, which leaves the
//! sampled gram product as the per-iteration wall on a multicore node
//! (the same observation that drives the hybrid MPI×threads setups of
//! the communication-avoiding literature). This module adds the missing
//! axis: `t` worker threads *inside* one rank, composing with the
//! column-sharded [`crate::solvers::DistGram`] ranks for hybrid
//! `P ranks × t threads` scaling.
//!
//! ### Determinism
//!
//! Every [`ProductStage`] computes each output row independently of the
//! other rows in the call, with a fixed per-entry summation order — the
//! engine's cache-transparency contract
//! (see [`crate::gram`]). Row partitioning therefore commutes with the
//! computation: each sampled row is computed by exactly one worker, with
//! exactly the arithmetic the serial stage would perform, so the
//! assembled block is **bitwise identical for every thread count**. The
//! partition itself is a pure function of `(rows, threads)` (contiguous
//! near-equal ranges), no work stealing, no clock — a run with `t = 8`
//! replays the bits of a run with `t = 1`. Pinned by
//! `rust/tests/threaded_product_props.rs`.
//!
//! The pool is built on `std::thread::scope` (rayon is unavailable in
//! the offline build): workers borrow their inputs and output chunks
//! directly from the caller's stack, and worker 0 runs on the calling
//! thread, so `t = 1` never spawns.

use crate::dense::Mat;
use crate::gram::{BlockKind, ProductCost, ProductStage};

/// Contiguous near-equal partition bounds: `bounds[i]..bounds[i+1]` is
/// worker `i`'s range. `parts + 1` entries, monotone, covering `0..n`.
pub fn partition_bounds(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts >= 1, "partition into at least one part");
    (0..=parts).map(|i| i * n / parts).collect()
}

/// Run one job per worker on scoped threads and return the results in
/// worker order. Job 0 runs on the calling thread (no spawn for the
/// single-worker case). Panics in any worker propagate.
pub fn scoped_run<T, F>(mut jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    assert!(!jobs.is_empty(), "scoped_run needs at least one job");
    if jobs.len() == 1 {
        let job = jobs.pop().expect("one job");
        return vec![job()];
    }
    let first = jobs.remove(0);
    std::thread::scope(|scope| {
        let handles: Vec<_> = jobs.into_iter().map(|job| scope.spawn(job)).collect();
        let mut out = Vec::with_capacity(handles.len() + 1);
        out.push(first());
        for h in handles {
            out.push(h.join().expect("parallel worker panicked"));
        }
        out
    })
}

/// Threaded adapter around any [`ProductStage`]: splits the sampled rows
/// of each `compute` call across `threads` workers.
///
/// Each worker owns a replica of the inner stage: the stages need
/// `&mut self` only for private scratch, and their bulk data (the CSR
/// matrix / low-rank factors) is `Arc`-shared, so replication costs
/// refcounts, not copies, and the hot path needs no synchronization.
/// Worker `i` computes the contiguous row range `bounds[i]..bounds[i+1]`
/// into its own sub-block, which is then copied into the caller's output
/// rows. With `threads = 1` (or a single sampled row) the call
/// short-circuits to the inner stage — no spawn, no copy.
///
/// Cost accounting is the worker-order sum of the per-worker costs,
/// which for every stage in the crate equals the serial cost exactly
/// (per-row costs are additive).
pub struct ParallelProduct<P> {
    /// One replica per worker; `workers[0]` doubles as the serial path.
    workers: Vec<P>,
}

impl<P: ProductStage + Clone> ParallelProduct<P> {
    /// Wrap `inner` with `threads` workers (`threads >= 1`).
    pub fn new(inner: P, threads: usize) -> ParallelProduct<P> {
        assert!(threads >= 1, "ParallelProduct needs at least one thread");
        let mut workers = Vec::with_capacity(threads);
        for _ in 1..threads {
            workers.push(inner.clone());
        }
        workers.push(inner);
        ParallelProduct { workers }
    }
}

impl<P> ParallelProduct<P> {
    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// The original inner stage (the replica the serial path uses is
    /// identical — all workers are clones of this one).
    pub fn inner(&self) -> &P {
        self.workers.last().expect("at least one worker")
    }
}

impl<P: ProductStage + Send> ProductStage for ParallelProduct<P> {
    fn m(&self) -> usize {
        self.workers[0].m()
    }

    fn kind(&self) -> BlockKind {
        self.workers[0].kind()
    }

    fn compute(&mut self, sample: &[usize], q: &mut Mat) -> ProductCost {
        let k = sample.len();
        let t = self.workers.len().min(k).max(1);
        if t == 1 {
            return self.workers[0].compute(sample, q);
        }
        let m = q.ncols();
        let bounds = partition_bounds(k, t);
        // Hand each worker its row range and the matching contiguous
        // slice of the row-major output (disjoint by construction).
        let mut rest: &mut [f64] = q.data_mut();
        let mut jobs = Vec::with_capacity(t);
        for (i, worker) in self.workers.iter_mut().take(t).enumerate() {
            let rows = &sample[bounds[i]..bounds[i + 1]];
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows.len() * m);
            rest = tail;
            jobs.push(move || {
                let mut sub = Mat::zeros(rows.len(), m);
                let cost = worker.compute(rows, &mut sub);
                chunk.copy_from_slice(sub.data());
                cost
            });
        }
        let costs = scoped_run(jobs);
        let mut total = ProductCost {
            flops: 0.0,
            rows_charged: 0,
        };
        for c in costs {
            total.flops += c.flops;
            total.rows_charged += c.rows_charged;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_dense_classification, gen_uniform_sparse, SynthParams, Task};
    use crate::gram::CsrProduct;
    use crate::rng::Pcg;

    #[test]
    fn partition_bounds_cover_and_are_monotone() {
        for n in [0usize, 1, 5, 7, 64] {
            for parts in [1usize, 2, 3, 8, 11] {
                let b = partition_bounds(n, parts);
                assert_eq!(b.len(), parts + 1);
                assert_eq!(b[0], 0);
                assert_eq!(b[parts], n);
                for i in 0..parts {
                    assert!(b[i] <= b[i + 1]);
                    // Near-equal: no range exceeds ceil(n/parts).
                    assert!(b[i + 1] - b[i] <= n.div_ceil(parts));
                }
            }
        }
    }

    #[test]
    fn scoped_run_returns_in_worker_order() {
        let jobs: Vec<_> = (0..7).map(|i| move || i * 10).collect();
        assert_eq!(scoped_run(jobs), vec![0, 10, 20, 30, 40, 50, 60]);
        let one = vec![|| 42];
        assert_eq!(scoped_run(one), vec![42]);
    }

    #[test]
    fn parallel_product_is_bitwise_identical_to_serial() {
        let dense = gen_dense_classification(30, 8, 0.0, 21).a;
        let sparse = gen_uniform_sparse(
            SynthParams {
                m: 26,
                n: 120,
                density: 0.05,
                seed: 9,
            },
            Task::Classification,
        )
        .a;
        for a in [dense, sparse] {
            let m = a.nrows();
            let mut rng = Pcg::seeded(5);
            // Duplicate-heavy with-replacement samples, incl. k < t.
            let samples: Vec<Vec<usize>> = (0..8)
                .map(|_| {
                    let k = rng.gen_range(1, 10);
                    (0..k).map(|_| rng.gen_below(m / 2 + 1)).collect()
                })
                .collect();
            let mut serial = CsrProduct::new(a.clone());
            for t in [1usize, 2, 3, 8, 16] {
                let mut par = ParallelProduct::new(CsrProduct::new(a.clone()), t);
                assert_eq!(par.threads(), t);
                assert_eq!(par.m(), serial.m());
                assert_eq!(par.kind(), serial.kind());
                for sample in &samples {
                    let mut q_ref = Mat::zeros(sample.len(), m);
                    let cost_ref = serial.compute(sample, &mut q_ref);
                    let mut q = Mat::zeros(sample.len(), m);
                    let cost = par.compute(sample, &mut q);
                    assert_eq!(q.data(), q_ref.data(), "t={t} sample {sample:?}");
                    assert_eq!(cost.rows_charged, cost_ref.rows_charged);
                    assert_eq!(cost.flops, cost_ref.flops, "additive exact counts");
                }
            }
        }
    }

    #[test]
    fn single_row_and_more_threads_than_rows_work() {
        let a = gen_dense_classification(12, 4, 0.0, 3).a;
        let mut serial = CsrProduct::new(a.clone());
        let mut par = ParallelProduct::new(CsrProduct::new(a), 8);
        let mut q_ref = Mat::zeros(1, 12);
        serial.compute(&[5], &mut q_ref);
        let mut q = Mat::zeros(1, 12);
        par.compute(&[5], &mut q);
        assert_eq!(q.data(), q_ref.data());
    }

    #[test]
    #[should_panic(expected = "at least one thread")]
    fn zero_threads_is_rejected() {
        let a = gen_dense_classification(4, 2, 0.0, 1).a;
        let _ = ParallelProduct::new(CsrProduct::new(a), 0);
    }
}
