//! A minimal property-testing framework (proptest is unavailable in the
//! offline build).
//!
//! `check(name, cases, |g| ...)` runs a property against `cases` randomly
//! generated inputs drawn through the [`Gen`] handle. On failure it re-runs
//! the property with the failing seed to confirm, then panics with the
//! seed so the case can be replayed exactly (`Gen::replay(seed)`).

#![forbid(unsafe_code)]

use crate::rng::Pcg;

/// Random input source handed to properties.
pub struct Gen {
    rng: Pcg,
    /// Seed that reproduces this case.
    pub seed: u64,
}

impl Gen {
    /// Rebuild the generator for a failing seed (for debugging).
    pub fn replay(seed: u64) -> Gen {
        Gen {
            rng: Pcg::new(seed, 0xC0FFEE),
            seed,
        }
    }

    /// Size in `[lo, hi)` — use for dimensions.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range(lo, hi)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard normal.
    pub fn gaussian(&mut self) -> f64 {
        self.rng.next_gaussian()
    }

    /// Vector of standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.next_gaussian()).collect()
    }

    /// Boolean with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_below(xs.len())]
    }

    /// Distinct indices from `[0, m)`.
    pub fn sample(&mut self, m: usize, k: usize) -> Vec<usize> {
        self.rng.sample_without_replacement(m, k)
    }

    /// Access the underlying PCG (for generators not covered above).
    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Run `prop` against `cases` random inputs. Panics (with replay seed) on
/// the first failing case. The property signals failure by panicking.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    // Derive per-case seeds from the property name so adding properties
    // does not shift the cases other properties see.
    let mut root = {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Pcg::new(h, 0x7E57)
    };
    for case in 0..cases {
        let seed = root.next_u64();
        let mut g = Gen::replay(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Worker-thread count for thread-aware tests: the `THREADS` environment
/// variable, defaulting to 1. The CI matrix runs the suite once with
/// `THREADS=4`, so every property that folds `env_threads()` into its
/// thread-count sweep gets exercised with real intra-rank parallelism on
/// that lane (results are bitwise thread-count-invariant, so assertions
/// are unchanged).
pub fn env_threads() -> usize {
    std::env::var("THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Row-group count for grid-aware tests: the `GRID` environment
/// variable, defaulting to 1 — the grid analog of [`env_threads`]. The
/// CI matrix runs one lane with `GRID=4` (paired with `THREADS=4`), so
/// every property that folds `env_grid_rows()` into its `(pr, pc)`
/// sweep exercises a row-group count its hard-coded factorizations do
/// not already cover. Results are bitwise `pr`-invariant (a
/// `Grid{pr, pc}` solve replays the 1D solve over `pc` ranks), so
/// assertions are unchanged.
pub fn env_grid_rows() -> usize {
    std::env::var("GRID")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&g| g >= 1)
        .unwrap_or(1)
}

/// Grid-cell storage mode for grid-aware tests: the `GRID_STORAGE`
/// environment variable (`replicated` / `sharded`), defaulting to
/// `Replicated` — the storage analog of [`env_grid_rows`]. The CI
/// matrix runs one lane with `GRID_STORAGE=sharded`, so every property
/// that folds `env_grid_storage()` into its storage sweep exercises the
/// memory-sharded cells and their fragment exchange with real
/// subcommunicator traffic. Results are bitwise storage-invariant, so
/// assertions are unchanged.
pub fn env_grid_storage() -> crate::gram::GridStorage {
    std::env::var("GRID_STORAGE")
        .ok()
        .and_then(|s| crate::gram::GridStorage::parse(s.trim()))
        .unwrap_or(crate::gram::GridStorage::Replicated)
}

/// Communication-overlap mode for overlap-aware tests: the `OVERLAP`
/// environment variable (`off` / `exchange` / `pipeline`), defaulting
/// to `Off` — the overlap analog of [`env_grid_storage`]. The CI matrix
/// runs one lane with `OVERLAP=exchange` (paired with the sharded-grid
/// lane, where the fragment exchange has a substrate), so every
/// property that folds `env_overlap()` into its overlap sweep exercises
/// the nonblocking collectives under real subcommunicator traffic.
/// Results are bitwise overlap-invariant, so assertions are unchanged.
pub fn env_overlap() -> crate::gram::OverlapMode {
    std::env::var("OVERLAP")
        .ok()
        .and_then(|s| crate::gram::OverlapMode::parse(s.trim()))
        .unwrap_or(crate::gram::OverlapMode::Off)
}

/// Coordinate-schedule kind for schedule-aware tests: the `SCHEDULE`
/// environment variable (`uniform` / `shuffle` / `locality`),
/// defaulting to `Uniform` — the schedule analog of [`env_overlap`].
/// The CI matrix runs one lane with `SCHEDULE=locality` (on the
/// sharded-grid lane, where the exchange-minimizing scoring has a
/// substrate), so every property that folds `env_schedule()` into its
/// schedule sweep exercises the locality-aware sampler under real
/// cache and fragment-exchange pressure. A fixed schedule spec is
/// bitwise invariant to threads/cache/storage/overlap, so assertions
/// are unchanged.
pub fn env_schedule() -> crate::schedule::ScheduleSpec {
    std::env::var("SCHEDULE")
        .ok()
        .and_then(|s| crate::schedule::ScheduleKind::parse(s.trim()))
        .map(crate::schedule::ScheduleSpec::of)
        .unwrap_or_default()
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol}, scale {scale})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("count", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn check_is_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("det", 10, |g| a.push(g.size(0, 1000)));
        check("det", 10, |g| b.push(g.size(0, 1000)));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 10, |g| {
            let x = g.size(0, 100);
            assert!(x < 90, "x too big: {x}");
        });
    }

    #[test]
    fn env_threads_is_at_least_one() {
        // Whatever the environment says (including the CI THREADS lane
        // and malformed values), the result is a usable worker count.
        assert!(env_threads() >= 1);
    }

    #[test]
    fn env_grid_rows_is_at_least_one() {
        // Same contract as env_threads: the CI GRID lane (or malformed
        // values) must always yield a usable row-group count.
        assert!(env_grid_rows() >= 1);
    }

    #[test]
    fn env_grid_storage_yields_a_valid_mode() {
        // Whatever the environment says (including the CI
        // GRID_STORAGE=sharded lane and malformed values), the result
        // is one of the two real storage modes.
        let s = env_grid_storage();
        assert!(matches!(
            s,
            crate::gram::GridStorage::Replicated | crate::gram::GridStorage::Sharded
        ));
    }

    #[test]
    fn env_overlap_yields_a_valid_mode() {
        // Whatever the environment says (including the CI
        // OVERLAP=exchange lane and malformed values), the result is
        // one of the three real overlap modes.
        let m = env_overlap();
        assert!(crate::gram::OverlapMode::all().contains(&m));
    }

    #[test]
    fn env_schedule_yields_a_valid_spec() {
        // Whatever the environment says (including the CI
        // SCHEDULE=locality lane and malformed values), the result is
        // a spec whose kind round-trips through the CLI name set.
        let spec = env_schedule();
        assert_eq!(
            crate::schedule::ScheduleKind::parse(spec.kind.name()),
            Some(spec.kind)
        );
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "eq");
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(&[1.0], &[2.0], 1e-9, "far");
    }
}
