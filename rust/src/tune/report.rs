//! Tuner report writers: the human-readable ranked table and the
//! machine-readable JSON report behind `kcd tune [--json]`.

use crate::coordinator::report::Table;

use super::{Candidate, CrossCheck, TunedPlan};

/// Ranked-plan table: the top `top` candidates, best first, with the
/// predicted time split into the Hockney terms and the traffic counts
/// the prediction weighted (`words` / `rounds` are exactly the analytic
/// ledger's critical-path counts — the numbers cross-validation
/// compares against measured execution).
pub fn tune_table(plan: &TunedPlan, top: usize) -> Table {
    let mut t = Table::new(vec![
        "rank", "layout", "storage", "rb", "overlap", "sched", "t", "s", "total (s)",
        "compute (s)", "bandwidth (s)", "latency (s)", "bound", "words", "rounds", "mem (MB)",
        "fit",
    ]);
    for (i, c) in plan.candidates.iter().take(top.max(1)).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            c.layout_tag(),
            c.storage_tag().to_string(),
            c.row_block.to_string(),
            c.overlap.name().to_string(),
            c.schedule.kind.name().to_string(),
            c.t.to_string(),
            c.s.to_string(),
            format!("{:.4e}", c.predicted.total_secs()),
            format!("{:.3e}", c.predicted.compute_secs),
            format!("{:.3e}", c.predicted.bandwidth_secs),
            format!("{:.3e}", c.predicted.latency_secs),
            c.predicted.dominant().to_string(),
            c.ledger.comm.words.to_string(),
            c.ledger.comm.rounds.to_string(),
            format!("{:.2}", c.mem_words() as f64 * 8.0 / 1e6),
            if c.mem_feasible { "yes" } else { "OVER" }.to_string(),
        ]);
    }
    t
}

/// Machine-readable report: the ranked plan (top `top` candidates) as a
/// single JSON object, with the optional measured cross-validation of
/// the winner attached when one was run.
pub fn tune_json(plan: &TunedPlan, top: usize, xval: Option<&CrossCheck>) -> String {
    let mut out = String::from("{");
    out.push_str(&format!("\"dataset\":{},", json_str(&plan.dataset)));
    out.push_str(&format!("\"problem\":{},", json_str(plan.problem.name())));
    out.push_str(&format!("\"machine\":{},", json_str(plan.machine.name)));
    out.push_str(&format!(
        "\"alpha\":{},\"beta\":{},\"gamma\":{},\"cores_per_rank\":{},",
        json_f64(plan.machine.phi),
        json_f64(plan.machine.beta),
        json_f64(plan.machine.gamma),
        plan.machine.cores_per_rank
    ));
    out.push_str(&format!(
        "\"p\":{},\"h\":{},\"algo\":{},",
        plan.p,
        plan.h,
        json_str(plan.algo.name())
    ));
    out.push_str(&format!("\"candidates_total\":{},", plan.candidates.len()));
    out.push_str("\"candidates\":[");
    for (i, c) in plan.candidates.iter().take(top.max(1)).enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&candidate_json(c, i + 1));
    }
    out.push(']');
    if let Some(x) = xval {
        out.push_str(&format!(",\"cross_validation\":{}", xval_json(x)));
    }
    out.push('}');
    out
}

fn candidate_json(c: &Candidate, rank: usize) -> String {
    format!(
        "{{\"rank\":{rank},\"pr\":{},\"pc\":{},\"t\":{},\"s\":{},\
         \"storage\":{},\"row_block\":{},\"overlap\":{},\"schedule\":{},\
         \"mem_words\":{},\"mem_feasible\":{},\
         \"predicted\":{{\"total_secs\":{},\"compute_secs\":{},\
         \"bandwidth_secs\":{},\"latency_secs\":{},\"bound\":{}}},\
         \"traffic\":{{\"words\":{},\"rounds\":{},\"msgs\":{},\"allreduces\":{},\
         \"exchange_words\":{},\"exchange_rounds\":{},\
         \"posted_words\":{},\"posted_rounds\":{}}},\
         \"theorem\":{{\"flops\":{},\"words\":{},\"msgs\":{}}}}}",
        c.pr,
        c.pc,
        c.t,
        c.s,
        json_str(c.storage.name()),
        c.row_block,
        json_str(c.overlap.name()),
        json_str(c.schedule.label().as_str()),
        c.mem_words(),
        c.mem_feasible,
        json_f64(c.predicted.total_secs()),
        json_f64(c.predicted.compute_secs),
        json_f64(c.predicted.bandwidth_secs),
        json_f64(c.predicted.latency_secs),
        json_str(c.predicted.dominant()),
        c.ledger.comm.words,
        c.ledger.comm.rounds,
        c.ledger.comm.msgs,
        c.ledger.comm.allreduces,
        c.ledger.comm_exch.words,
        c.ledger.comm_exch.rounds,
        c.ledger.comm_posted.words,
        c.ledger.comm_posted.rounds,
        json_f64(c.theorem.flops),
        json_f64(c.theorem.words),
        json_f64(c.theorem.msgs),
    )
}

fn xval_json(x: &CrossCheck) -> String {
    format!(
        "{{\"traffic_exact\":{},\"flops_rel_err\":{},\
         \"predicted\":{{\"words\":{},\"rounds\":{}}},\
         \"measured\":{{\"words\":{},\"rounds\":{}}}}}",
        x.traffic_exact(),
        json_f64(x.flops_rel_err),
        x.predicted.words,
        x.predicted.rounds,
        x.measured.words,
        x.measured.rounds,
    )
}

/// JSON string literal (escapes quotes, backslashes and control bytes —
/// dataset names can come from file stems).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: finite f64s in `e` notation (valid JSON); non-finite
/// values (which the model never produces, but a report writer must not
/// emit invalid JSON for) degrade to null.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ProblemSpec;
    use crate::costmodel::MachineProfile;
    use crate::kernelfn::Kernel;
    use crate::solvers::SvmVariant;
    use crate::tune::{tune, TuneRequest};

    fn small_plan() -> TunedPlan {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let mut req = TuneRequest::new(4, 16);
        req.s_list = vec![4];
        req.t_list = vec![1, 2];
        tune(
            &ds,
            Kernel::paper_rbf(),
            &ProblemSpec::Svm {
                c: 1.0,
                variant: SvmVariant::L1,
            },
            &req,
            &MachineProfile::cray_ex(),
        )
    }

    #[test]
    fn table_ranks_best_first_and_respects_top() {
        let plan = small_plan();
        let full = tune_table(&plan, usize::MAX).markdown();
        assert!(full.contains("| 1 "), "{full}");
        assert!(full.contains("compute (s)"), "{full}");
        let truncated = tune_table(&plan, 2).markdown();
        assert_eq!(truncated.lines().count(), 2 + 2, "{truncated}");
        // top = 0 still shows the winner instead of an empty table.
        assert_eq!(tune_table(&plan, 0).markdown().lines().count(), 3);
    }

    #[test]
    fn json_is_well_formed_and_carries_the_split() {
        let plan = small_plan();
        let js = tune_json(&plan, 3, None);
        assert!(js.starts_with('{') && js.ends_with('}'), "{js}");
        for key in [
            "\"dataset\":",
            "\"machine\":\"cray-ex\"",
            "\"alpha\":",
            "\"candidates\":[",
            "\"compute_secs\":",
            "\"bandwidth_secs\":",
            "\"latency_secs\":",
            "\"traffic\":",
            "\"theorem\":",
            "\"storage\":",
            "\"row_block\":",
            "\"mem_words\":",
            "\"mem_feasible\":",
            "\"exchange_words\":",
            "\"overlap\":",
            "\"schedule\":",
            "\"posted_words\":",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
        assert!(!js.contains("cross_validation"));
        // Balanced braces/brackets (cheap well-formedness proxy; the
        // escaper guarantees no stray quotes).
        let balance = |open: char, close: char| {
            js.chars().filter(|&c| c == open).count() == js.chars().filter(|&c| c == close).count()
        };
        assert!(balance('{', '}'), "{js}");
        assert!(balance('[', ']'), "{js}");
    }

    #[test]
    fn json_escapes_hostile_names() {
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny"), "\"x\\u000ay\"");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_f64(0.5), "5e-1");
    }
}
