//! Least-squares calibration of the Hockney coefficients `(α, β, γ)`
//! from measured microbench timings (`kcd tune --calibrate`).
//!
//! The tuner's counts are exact (cross-validated against measured
//! traffic word for word), but the coefficients that turn counts into
//! seconds were named guesses ([`MachineProfile::cray_ex`] /
//! [`MachineProfile::cloud`]). This module closes the loop: given a
//! suite of [`Observation`]s — each a measured wall-clock time paired
//! with the *same analytic counts the cost model charges* (flops for
//! the gram kernels, words and rounds for the collectives) — [`fit`]
//! solves the weighted least-squares problem
//!
//! ```text
//!   secs_i ≈ γ·flops_i + β·words_i + α·rounds_i
//! ```
//!
//! and [`apply`] grafts the fitted coefficients onto a base profile.
//!
//! Division of labor (the detlint ambient-nondeterminism contract):
//! the *sampling* — everything that touches `Instant::now` — lives in
//! [`crate::bench_harness::calibrate`], the allowlisted timing module.
//! This module is pure arithmetic on already-collected numbers, so it
//! is unit-testable on synthetic timings (planted coefficients are
//! recovered to 1e-9) and stays inside the deterministic core.
//!
//! Weighting: each observation is scaled by `1/secs_i`, so the solver
//! minimizes *relative* error — a 100 ms gram bench and a 20 µs
//! latency bench then pull on the fit with equal force, which is what
//! keeps the small-payload rounds from being drowned out by the flops
//! term. Degenerate suites (a term never exercised, collinear designs,
//! non-positive results) are hard errors naming the coefficient, in
//! the `Config::try_*` spirit: never a silent fallback.

use crate::costmodel::MachineProfile;

/// One calibration measurement: a wall-clock median paired with the
/// analytic counts of the benched operation.
#[derive(Clone, Debug)]
pub struct Observation {
    /// Bench label (diagnostics only).
    pub name: String,
    /// Flop-equivalents per iteration (the `ProductCost::flops` charge).
    pub flops: f64,
    /// Critical-path f64 words moved per iteration (max over ranks).
    pub words: f64,
    /// Critical-path message rounds per iteration (max over ranks).
    pub rounds: f64,
    /// Measured seconds per iteration (median over samples).
    pub secs: f64,
}

/// The fitted Hockney coefficients, in the cost model's units.
#[derive(Clone, Copy, Debug)]
pub struct FittedCoefficients {
    /// Seconds per flop (`MachineProfile::gamma`).
    pub gamma: f64,
    /// Seconds per f64 word moved (`MachineProfile::beta`).
    pub beta: f64,
    /// Seconds per message round (`MachineProfile::phi`; spelled
    /// `alpha` everywhere user-facing, like the `--machine` overrides).
    pub alpha: f64,
    /// Root-mean-square *relative* residual of the fit
    /// (`sqrt(mean((pred/measured − 1)²))`) — the suite's self-report
    /// of how well three coefficients explain the timings.
    pub rel_residual: f64,
}

/// Index of each coefficient in the normal-equation system, with the
/// user-facing spelling used in error messages.
const TERMS: [(&str, fn(&Observation) -> f64); 3] = [
    ("gamma", |o| o.flops),
    ("beta", |o| o.words),
    ("alpha", |o| o.rounds),
];

/// Fit `(γ, β, α)` to `obs` by weighted least squares (weights
/// `1/secs`, minimizing relative error). Pure: no clock, no RNG, no
/// I/O — synthetic timings in, coefficients out.
///
/// Hard errors (naming the offender, never guessing): an observation
/// with non-finite or non-positive `secs`; a coefficient whose count
/// column is all zero (the suite never exercised it); a singular
/// normal system (collinear design); a non-positive or non-finite
/// fitted coefficient (the timings contradict the model).
pub fn fit(obs: &[Observation]) -> Result<FittedCoefficients, String> {
    if obs.len() < 3 {
        return Err(format!(
            "calibration needs at least 3 observations to fit (alpha, beta, gamma); got {}",
            obs.len()
        ));
    }
    for o in obs {
        if !o.secs.is_finite() || o.secs <= 0.0 {
            return Err(format!(
                "calibration observation '{}' has invalid seconds {} \
                 (expected a positive finite measurement)",
                o.name, o.secs
            ));
        }
    }
    for (name, count) in TERMS {
        if obs.iter().all(|o| count(o) == 0.0) {
            return Err(format!(
                "calibration suite never exercised '{name}' \
                 (its count column is all zero); cannot fit it"
            ));
        }
    }
    // Normal equations M c = b with rows x_i = counts_i / secs_i and
    // targets y_i = 1 (relative-error weighting).
    let mut m = [[0.0f64; 3]; 3];
    let mut b = [0.0f64; 3];
    for o in obs {
        let x = [o.flops / o.secs, o.words / o.secs, o.rounds / o.secs];
        for r in 0..3 {
            for c in 0..3 {
                m[r][c] += x[r] * x[c];
            }
            b[r] += x[r];
        }
    }
    let c = solve3(m, b).ok_or_else(|| {
        "calibration design is singular (the suite's flops/words/rounds \
         columns are collinear); add observations that vary the terms \
         independently"
            .to_string()
    })?;
    for (i, (name, _)) in TERMS.iter().enumerate() {
        if !c[i].is_finite() || c[i] <= 0.0 {
            return Err(format!(
                "calibration fit produced a non-positive '{name}' ({:e}); \
                 the timings contradict the cost model — rerun without \
                 --quick, or on a quieter machine",
                c[i]
            ));
        }
    }
    let mut sq = 0.0;
    for o in obs {
        let pred = c[0] * o.flops + c[1] * o.words + c[2] * o.rounds;
        let rel = pred / o.secs - 1.0;
        sq += rel * rel;
    }
    Ok(FittedCoefficients {
        gamma: c[0],
        beta: c[1],
        alpha: c[2],
        rel_residual: (sq / obs.len() as f64).sqrt(),
    })
}

/// Graft fitted coefficients onto `base`: `(γ, β, φ)` are replaced by
/// the measurements, while the unmeasured shape parameters
/// (`mu_scale`, `blas1_penalty`, `iter_overhead`, `cores_per_rank`)
/// carry over from the base profile. The result is tagged
/// `calibrated` and round-trips bit-for-bit through
/// [`MachineProfile::save`] / [`MachineProfile::load`].
pub fn apply(base: &MachineProfile, fitted: &FittedCoefficients) -> MachineProfile {
    MachineProfile {
        name: "calibrated",
        gamma: fitted.gamma,
        beta: fitted.beta,
        phi: fitted.alpha,
        ..*base
    }
}

/// Solve the 3×3 system `m x = b` by Gaussian elimination with partial
/// pivoting; `None` when (numerically) singular.
fn solve3(m: [[f64; 3]; 3], b: [f64; 3]) -> Option<[f64; 3]> {
    let mut a = [[0.0f64; 4]; 3];
    for r in 0..3 {
        a[r][..3].copy_from_slice(&m[r]);
        a[r][3] = b[r];
    }
    for col in 0..3 {
        let pivot = (col..3).max_by(|&i, &j| {
            a[i][col]
                .abs()
                .partial_cmp(&a[j][col].abs())
                .expect("finite pivots")
        })?;
        if a[pivot][col].abs() == 0.0 {
            return None;
        }
        a.swap(col, pivot);
        for r in col + 1..3 {
            let f = a[r][col] / a[col][col];
            for c in col..4 {
                a[r][c] -= f * a[col][c];
            }
        }
    }
    let mut x = [0.0f64; 3];
    for r in (0..3).rev() {
        let mut v = a[r][3];
        for c in r + 1..3 {
            v -= a[r][c] * x[c];
        }
        if a[r][r] == 0.0 || !a[r][r].is_finite() {
            return None;
        }
        x[r] = v / a[r][r];
    }
    if x.iter().any(|v| !v.is_finite()) {
        return None;
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn planted(name: &str, flops: f64, words: f64, rounds: f64) -> Observation {
        let (g, b, a) = (2.5e-10, 4.0e-9, 5.0e-6);
        Observation {
            name: name.to_string(),
            flops,
            words,
            rounds,
            secs: g * flops + b * words + a * rounds,
        }
    }

    /// The ISSUE acceptance test: exact synthetic timings from a
    /// planted `(α, β, γ)` are recovered to 1e-9 relative.
    #[test]
    fn fit_recovers_planted_coefficients() {
        let obs = vec![
            planted("gram/small", 1.0e8, 0.0, 0.0),
            planted("gram/large", 4.0e9, 0.0, 0.0),
            planted("comm/tiny", 0.0, 256.0, 16.0),
            planted("comm/mid", 0.0, 65_536.0, 32.0),
            planted("comm/big", 0.0, 4.0e6, 64.0),
            planted("mixed", 2.0e8, 1.0e5, 8.0),
        ];
        let f = fit(&obs).expect("well-posed suite");
        assert!((f.gamma / 2.5e-10 - 1.0).abs() < 1e-9, "gamma {:e}", f.gamma);
        assert!((f.beta / 4.0e-9 - 1.0).abs() < 1e-9, "beta {:e}", f.beta);
        assert!((f.alpha / 5.0e-6 - 1.0).abs() < 1e-9, "alpha {:e}", f.alpha);
        assert!(f.rel_residual < 1e-9, "residual {:e}", f.rel_residual);
    }

    /// A term the suite never exercised is a hard error naming it.
    #[test]
    fn missing_term_is_named_error() {
        let obs = vec![
            planted("a", 1.0e8, 0.0, 4.0),
            planted("b", 2.0e8, 0.0, 8.0),
            planted("c", 4.0e8, 0.0, 2.0),
        ];
        let err = fit(&obs).unwrap_err();
        assert!(err.contains("beta"), "{err}");
    }

    /// Timings that force a negative coefficient are rejected, not
    /// silently clamped. The three exact equations below solve to
    /// `alpha = −1`.
    #[test]
    fn negative_coefficient_is_named_error() {
        let mk = |name: &str, f, w, r, secs| Observation {
            name: name.into(),
            flops: f,
            words: w,
            rounds: r,
            secs,
        };
        let obs = vec![
            mk("x", 1.0, 0.0, 1.0, 1.0),
            mk("y", 0.0, 1.0, 1.0, 1.0),
            mk("z", 1.0, 1.0, 1.0, 3.0),
        ];
        let err = fit(&obs).unwrap_err();
        assert!(err.contains("alpha"), "{err}");
    }

    /// Non-positive measured seconds are a hard error naming the bench.
    #[test]
    fn bad_seconds_is_named_error() {
        let mut obs = vec![
            planted("ok", 1.0e8, 1.0, 1.0),
            planted("ok2", 2.0e8, 2.0, 2.0),
            planted("broken", 1.0e8, 4.0, 1.0),
        ];
        obs[2].secs = 0.0;
        let err = fit(&obs).unwrap_err();
        assert!(err.contains("broken"), "{err}");
    }

    /// A collinear design (every observation the same direction) is a
    /// singularity error, not NaN coefficients.
    #[test]
    fn collinear_design_is_singular_error() {
        let obs: Vec<Observation> = (1..=4)
            .map(|i| planted(&format!("s{i}"), 1.0e8 * i as f64, 1.0e4 * i as f64, 8.0 * i as f64))
            .collect();
        let err = fit(&obs).unwrap_err();
        assert!(err.contains("singular") || err.contains("collinear"), "{err}");
    }

    /// `apply` replaces exactly the measured coefficients and keeps the
    /// base profile's shape parameters.
    #[test]
    fn apply_grafts_onto_base() {
        let base = MachineProfile::cloud();
        let f = FittedCoefficients {
            gamma: 1.0e-10,
            beta: 2.0e-9,
            alpha: 3.0e-6,
            rel_residual: 0.0,
        };
        let p = apply(&base, &f);
        assert_eq!(p.name, "calibrated");
        assert_eq!(p.gamma, 1.0e-10);
        assert_eq!(p.beta, 2.0e-9);
        assert_eq!(p.phi, 3.0e-6);
        assert_eq!(p.mu_scale, base.mu_scale);
        assert_eq!(p.blas1_penalty, base.blas1_penalty);
        assert_eq!(p.iter_overhead, base.iter_overhead);
        assert_eq!(p.cores_per_rank, base.cores_per_rank);
    }
}
