//! Cost-model auto-tuner: pick `(pr, pc, t, s)` for a machine profile.
//!
//! The paper's central trade-off is *tunable*: the s-step variants buy a
//! `1/s` latency reduction at the price of extra bandwidth and flops
//! (Theorems 1–2), the 2D grid trades a smaller reduce payload for a row
//! allgather, and intra-rank threads cut only the kernel phase — so the
//! best configuration depends on the machine's `(α, β, γ)` profile.
//! Prior work (Devarakonda et al., 2016) leaves this parameter selection
//! to hand sweeps; this module turns the cost model from a reporting
//! tool into a decision subsystem.
//!
//! The tuner enumerates the feasible configuration space for a problem:
//!
//! * `(pr, pc)` over the factorizations of the rank count `P`,
//! * `t` over thread counts up to [`MachineProfile::cores_per_rank`],
//! * `s` over a user-bounded range (powers of two by default),
//! * grid storage over both [`crate::gram::GridStorage`] modes —
//!   pricing the sharded layout's fragment-exchange traffic against its
//!   `1/pr` memory footprint, with an optional per-rank memory budget
//!   (`--mem-limit`) ranking infeasible candidates strictly last,
//! * `row_block` over [`ROW_BLOCK_CANDIDATES`] on grid layouts,
//! * communication overlap over the applicable
//!   [`crate::gram::OverlapMode`]s — `exchange` where a sharded grid
//!   has a fragment exchange to hide, `pipeline` where an s-step inner
//!   loop can run under a posted reduce; the analytic replicas price
//!   the posted/hidden split through
//!   [`MachineProfile::overlap_saved`](crate::costmodel::MachineProfile),
//!
//! scores every candidate with the *same analytic count replicas the
//! scaling harness cross-validates against measured execution*
//! ([`analytic_ledger`] / [`grid_analytic_ledger`], which are pinned
//! bitwise to real `CommStats` in `coordinator::scaling` tests), and
//! ranks them by [`MachineProfile::predict`] — a per-candidate time
//! split into latency / bandwidth / compute terms, so the choice is
//! explainable, not just a number.
//!
//! Trust story: a prediction is only as good as its counts, so
//! [`cross_validate`] replays a candidate against *measured*
//! ranks and compares traffic word for word (see the `tune` CLI
//! subcommand and `rust/tests/tune_props.rs`). The closed-form
//! Theorem-1/2 costs (with [`ProblemDims::reduce_ranks`] set to the
//! candidate's reduce-collective participant count `pc`) ride along on
//! every candidate as an order-of-magnitude sanity anchor.

#![forbid(unsafe_code)]

pub mod calibrate;
mod report;
mod xval;

pub use report::{tune_json, tune_table};
pub use xval::{cross_validate, CrossCheck};

use crate::comm::AllreduceAlgo;
use crate::coordinator::scaling::{analytic_ledger, grid_analytic_ledger};
use crate::coordinator::{ProblemSpec, SolverSpec};
use crate::costmodel::{
    bdcd_cost, bdcd_sstep_cost, dcd_cost, dcd_sstep_cost, AlgoCost, Ledger, MachineProfile,
    Predicted, ProblemDims,
};
use crate::data::Dataset;
use crate::gram::{GridStorage, Layout, OverlapMode};
use crate::kernelfn::Kernel;
use crate::schedule::{ScheduleKind, ScheduleSpec};

/// Block-cyclic row-block candidates for grid layouts (the ROADMAP
/// `row_block` follow-on): a small deterministic set spanning pure
/// cyclic (1), the default (4) and a coarse block (16). 1D candidates
/// ignore the knob and carry the default.
pub const ROW_BLOCK_CANDIDATES: [usize; 3] = [1, 4, 16];

/// The configuration space the tuner searches, plus the run parameters
/// every candidate shares (`h`, allreduce algorithm, row block, seed).
#[derive(Clone, Debug)]
pub struct TuneRequest {
    /// Total rank count `P` the launch will use; `(pr, pc)` candidates
    /// are its factorizations.
    pub p: usize,
    /// Total inner iterations `H` of the planned run.
    pub h: usize,
    /// Upper bound of the default power-of-two `s` grid (ignored when
    /// [`Self::s_list`] is non-empty). Candidates are further capped at
    /// `h` — an `s` beyond the iteration budget is infeasible.
    pub s_max: usize,
    /// Upper bound on candidate thread counts; additionally capped at
    /// the machine's [`MachineProfile::cores_per_rank`] (threads beyond
    /// the core budget cannot speed the kernel phase up).
    pub t_max: usize,
    /// Explicit `s` candidates (empty → powers of two up to
    /// [`Self::s_max`]). `1` (the classical method) is always a
    /// candidate either way.
    pub s_list: Vec<usize>,
    /// Explicit `t` candidates (empty → powers of two up to the
    /// effective cap, plus the cap itself).
    pub t_list: Vec<usize>,
    /// Allreduce algorithm of the planned run (the analytic traffic
    /// replica mirrors it exactly).
    pub algo: AllreduceAlgo,
    /// Block-cyclic row block of grid candidates. [`tune`] additionally
    /// enumerates [`ROW_BLOCK_CANDIDATES`]; this value joins the set
    /// (so an explicit `--row-block` is always considered).
    pub row_block: usize,
    /// Per-rank memory budget in f64 words (`--mem-limit`, converted
    /// from MB by the CLI): candidates whose
    /// [`crate::costmodel::Ledger::mem_per_rank`] exceeds it are marked
    /// infeasible and ranked strictly after every feasible candidate —
    /// never silently dropped, so the report can show *why* a faster
    /// configuration was rejected. `None` disables the filter.
    pub mem_limit_words: Option<u64>,
    /// Coordinate-stream seed used by measured cross-validation replays
    /// ([`cross_validate`]) — and by the sharded-storage candidates'
    /// fragment-exchange traffic replica, which replays the exact
    /// sample stream (`coordinator::scaling::gram_call_samples`).
    pub seed: u64,
}

impl TuneRequest {
    /// A request with the default candidate grids: `s` powers of two up
    /// to 256, `t` powers of two up to the machine's core budget.
    pub fn new(p: usize, h: usize) -> TuneRequest {
        TuneRequest {
            p,
            h,
            s_max: 256,
            t_max: usize::MAX,
            s_list: Vec::new(),
            t_list: Vec::new(),
            algo: AllreduceAlgo::Rabenseifner,
            row_block: crate::gram::DEFAULT_ROW_BLOCK,
            mem_limit_words: None,
            seed: 0x5EED,
        }
    }

    /// Resolved row-block candidates: [`ROW_BLOCK_CANDIDATES`] plus the
    /// request's own `row_block`, sorted and deduplicated.
    pub fn row_block_candidates(&self) -> Vec<usize> {
        let mut out = ROW_BLOCK_CANDIDATES.to_vec();
        out.push(self.row_block.max(1));
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Resolved `s` candidates: sorted, deduplicated, `1 ≤ s ≤ h`, and
    /// always containing the classical `s = 1`.
    pub fn s_candidates(&self) -> Vec<usize> {
        let mut out: Vec<usize> = if self.s_list.is_empty() {
            let mut v = Vec::new();
            let mut s = 1usize;
            while s <= self.s_max.min(self.h) {
                v.push(s);
                match s.checked_mul(2) {
                    Some(next) => s = next,
                    None => break,
                }
            }
            v
        } else {
            self.s_list
                .iter()
                .copied()
                .filter(|s| (1..=self.h).contains(s))
                .collect()
        };
        out.push(1);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Resolved `t` candidates for `machine`: sorted, deduplicated,
    /// `1 ≤ t ≤ min(t_max, cores_per_rank)`, and always containing the
    /// serial `t = 1`. The default grid is powers of two up to the cap
    /// plus the cap itself (so a 12-core budget tries 1, 2, 4, 8, 12).
    pub fn t_candidates(&self, machine: &MachineProfile) -> Vec<usize> {
        let cap = self.t_max.min(machine.cores_per_rank).max(1);
        let mut out: Vec<usize> = if self.t_list.is_empty() {
            let mut v = Vec::new();
            let mut t = 1usize;
            while t <= cap {
                v.push(t);
                match t.checked_mul(2) {
                    Some(next) => t = next,
                    None => break,
                }
            }
            v.push(cap);
            v
        } else {
            self.t_list.iter().copied().filter(|t| (1..=cap).contains(t)).collect()
        };
        out.push(1);
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// One scored configuration: a `(pr, pc, t, s)` point with its analytic
/// count ledger, the Hockney prediction derived from it, and the
/// closed-form Theorem-1/2 cost as a sanity anchor.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Row-group count of the 2D grid (`1` = the paper's 1D layout).
    pub pr: usize,
    /// Feature-shard count; the reduce collective's participant count.
    pub pc: usize,
    /// Intra-rank worker threads for the gram product.
    pub t: usize,
    /// s-step block size (`1` = classical).
    pub s: usize,
    /// Grid-cell storage mode (`Replicated` for 1D candidates, where
    /// the knob is meaningless).
    pub storage: GridStorage,
    /// Block-cyclic row-block size (the default for 1D candidates).
    pub row_block: usize,
    /// Communication-overlap mode. Only modes with a substrate on this
    /// candidate's shape are enumerated (`Off` for the rest — an inert
    /// mode scores identically and would just pad the report).
    pub overlap: OverlapMode,
    /// Coordinate schedule ([`ScheduleSpec`]). The locality-aware kind
    /// is enumerated only where it has traffic to save — sharded grid
    /// candidates, whose fragment-exchange replica replays the
    /// schedule's exact sample stream (its `groups`/`group_block` are
    /// preset to the candidate's `pr`/`row_block`); everywhere else the
    /// count replica is schedule-invariant and only `Uniform` is scored.
    pub schedule: ScheduleSpec,
    /// False when the request's `--mem-limit` budget is smaller than
    /// this candidate's per-rank memory model — the candidate then ranks
    /// after every feasible one.
    pub mem_feasible: bool,
    /// Predicted time, split into compute / bandwidth / latency.
    pub predicted: Predicted,
    /// The analytic count replica backing the prediction — the same
    /// ledger shape measured execution produces, so its traffic fields
    /// can be compared to real `CommStats` word for word.
    pub ledger: Ledger,
    /// Closed-form Theorem-1/2 leading-order cost with
    /// [`ProblemDims::reduce_ranks`] `= pc` (the candidate's reduce
    /// collective), for order-of-magnitude cross-checks.
    pub theorem: AlgoCost,
}

impl Candidate {
    /// The `SolverSpec::grid` value of this candidate: `None` for the
    /// 1D layout, `Some((pr, pc))` for a genuine grid.
    pub fn grid(&self) -> Option<(usize, usize)> {
        if self.pr > 1 {
            Some((self.pr, self.pc))
        } else {
            None
        }
    }

    /// Total rank count this candidate was scored for.
    pub fn ranks(&self) -> usize {
        self.pr * self.pc
    }

    /// Solver spec that runs this candidate (see
    /// [`SolverSpec::from_candidate`]).
    pub fn solver_spec(&self, h: usize, seed: u64, cache_rows: usize) -> SolverSpec {
        SolverSpec::from_candidate(self, h, seed, cache_rows)
    }

    /// The gram-engine layout of rank `rank` under this candidate
    /// (read-only handoff to [`crate::gram`]).
    pub fn layout_for_rank(&self, rank: usize) -> Layout {
        if self.pr > 1 {
            Layout::grid_for_rank(self.pr, self.pc, rank)
        } else if self.ranks() > 1 {
            Layout::ColShard {
                rank,
                ranks: self.ranks(),
            }
        } else {
            Layout::Full
        }
    }

    /// Report tag for this candidate's layout: `1d` or `grid-PRxPC`
    /// (one formatter shared by the table, JSON and CLI reports).
    pub fn layout_tag(&self) -> String {
        match self.grid() {
            Some((pr, pc)) => format!("grid-{pr}x{pc}"),
            None => "1d".to_string(),
        }
    }

    /// Report tag for the storage mode: `-` for 1D candidates (the knob
    /// does not apply), else [`GridStorage::name`].
    pub fn storage_tag(&self) -> &'static str {
        match self.grid() {
            Some(_) => self.storage.name(),
            None => "-",
        }
    }

    /// Per-rank resident memory of this candidate in f64 words (the
    /// ledger's model — identical to what a measured run reports).
    pub fn mem_words(&self) -> u64 {
        self.ledger.mem_per_rank()
    }

    /// The equivalent `kcd` command line — the tune → train handoff.
    /// Carries the tuned *configuration* only; the `tune` CLI appends
    /// the data/problem context flags (dataset, scale, kernel, problem
    /// parameters) so the printed line runs exactly what was tuned.
    pub fn cli_hint(&self, problem: &ProblemSpec, h: usize) -> String {
        let cmd = match problem {
            ProblemSpec::Svm { .. } => "train-svm",
            ProblemSpec::Krr { .. } => "train-krr",
        };
        let mut out = format!("kcd {cmd} --p {}", self.ranks());
        if let Some((pr, pc)) = self.grid() {
            out.push_str(&format!(" --grid {pr}x{pc}"));
            if self.storage != GridStorage::Replicated {
                out.push_str(&format!(" --grid-storage {}", self.storage.name()));
            }
            if self.row_block != crate::gram::DEFAULT_ROW_BLOCK {
                out.push_str(&format!(" --row-block {}", self.row_block));
            }
        }
        if self.t > 1 {
            out.push_str(&format!(" --threads {}", self.t));
        }
        if self.overlap != OverlapMode::Off {
            out.push_str(&format!(" --overlap {}", self.overlap.name()));
        }
        if self.schedule.kind != ScheduleKind::Uniform {
            out.push_str(&format!(" --schedule {}", self.schedule.kind.name()));
        }
        out.push_str(&format!(" --s {} --h {h}", self.s));
        out
    }
}

/// A ranked tuning plan: every feasible candidate, best first.
#[derive(Clone, Debug)]
pub struct TunedPlan {
    /// Rank count the plan was tuned for.
    pub p: usize,
    /// Inner-iteration budget every candidate shares.
    pub h: usize,
    /// Allreduce algorithm every candidate shares.
    pub algo: AllreduceAlgo,
    /// The machine profile the predictions were weighted with.
    pub machine: MachineProfile,
    /// The problem the plan was tuned for.
    pub problem: ProblemSpec,
    /// Dataset name (reports only).
    pub dataset: String,
    /// All candidates, memory-feasible ones first, then by predicted
    /// total time (ties broken deterministically by
    /// `(pr, storage, row_block, overlap, schedule, t, s)` — the ranking
    /// is invariant under candidate enumeration order).
    pub candidates: Vec<Candidate>,
}

impl TunedPlan {
    /// The predicted-best candidate. The plan always has at least one
    /// candidate (`pr = pc = t = s = 1` is always feasible).
    pub fn best(&self) -> &Candidate {
        &self.candidates[0]
    }
}

/// Every `(pr, pc)` with `pr · pc = p`, ascending in `pr`.
pub fn factorizations(p: usize) -> Vec<(usize, usize)> {
    (1..=p)
        .filter(|pr| p % pr == 0)
        .map(|pr| (pr, p / pr))
        .collect()
}

/// Overlap modes worth scoring for a candidate shape: `Off` always;
/// `Exchange` only where a sharded grid has a fragment exchange to hide
/// (`pr > 1`); `Pipeline` only where the s-step drivers pipeline
/// (`s > 1`) and the posted reduce collective has more than one
/// participant (`pc > 1` — 1D candidates carry `pc = p`). Inert modes
/// score identically to `Off` and are excluded rather than ranked.
pub fn overlap_candidates(pr: usize, pc: usize, storage: GridStorage, s: usize) -> Vec<OverlapMode> {
    let mut out = vec![OverlapMode::Off];
    if pr > 1 && storage == GridStorage::Sharded {
        out.push(OverlapMode::Exchange);
    }
    if s > 1 && pc > 1 {
        out.push(OverlapMode::Pipeline);
    }
    out
}

/// Coordinate schedules worth scoring for a candidate shape: `Uniform`
/// always; `LocalityAware` only where the analytic count replica can
/// tell the difference — sharded grids (`pr > 1`), whose fragment
/// exchange replays the schedule's exact sample stream. Elsewhere the
/// replica is sample-count–only, so a non-uniform schedule scores
/// identically to `Uniform` and is excluded rather than ranked (the
/// same inert-axis rule as [`overlap_candidates`]). The locality spec
/// is preset to the candidate's shape: `groups = pr` (one exchange
/// group per row band) and `group_block = row_block` (pack blocks the
/// engine tiles by).
pub fn schedule_candidates(pr: usize, row_block: usize, storage: GridStorage) -> Vec<ScheduleSpec> {
    let mut out = vec![ScheduleSpec::default()];
    if pr > 1 && storage == GridStorage::Sharded {
        let mut locality = ScheduleSpec::of(ScheduleKind::LocalityAware);
        locality.groups = pr;
        locality.group_block = row_block;
        out.push(locality);
    }
    out
}

/// Enumerate, score and rank the feasible configuration space (see the
/// module docs). Deterministic: the returned ranking depends only on
/// the resolved candidate sets, never on enumeration order.
pub fn tune(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    req: &TuneRequest,
    machine: &MachineProfile,
) -> TunedPlan {
    assert!(req.p >= 1, "need at least one rank");
    assert!(req.h >= 1, "need at least one iteration");
    assert!(req.row_block >= 1, "row block must be at least 1");
    let s_cands = req.s_candidates();
    let t_cands = req.t_candidates(machine);
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let rb_cands = req.row_block_candidates();
    let density = ds.a.density();
    let mu = kernel.mu();
    let mut candidates =
        Vec::with_capacity(factorizations(req.p).len() * s_cands.len() * t_cands.len());
    for (pr, pc) in factorizations(req.p) {
        // 1D candidates have no storage/row-block axes; grids enumerate
        // both storage modes (the memory-vs-exchange-traffic trade this
        // tuner now prices) and the small row-block set.
        let storages: &[GridStorage] = if pr == 1 {
            &[GridStorage::Replicated]
        } else {
            &[GridStorage::Replicated, GridStorage::Sharded]
        };
        let row_blocks: &[usize] = if pr == 1 {
            std::slice::from_ref(&req.row_block)
        } else {
            &rb_cands
        };
        for &storage in storages {
            for &row_block in row_blocks {
                for &s in &s_cands {
                    let dims = ProblemDims {
                        m: ds.m(),
                        n: ds.n(),
                        f: density,
                        mu,
                        p: req.p,
                        reduce_ranks: pc,
                        h: req.h,
                    };
                    let theorem = match (problem, s) {
                        (ProblemSpec::Svm { .. }, 1) => dcd_cost(&dims),
                        (ProblemSpec::Svm { .. }, s) => dcd_sstep_cost(&dims, s),
                        (ProblemSpec::Krr { .. }, 1) => bdcd_cost(&dims, b),
                        (ProblemSpec::Krr { .. }, s) => bdcd_sstep_cost(&dims, b, s),
                    };
                    // The count replica depends on (pr, s, storage,
                    // row_block, overlap, schedule) only; threads are a
                    // pure wall-time knob, so score each ledger once
                    // per t.
                    for overlap in overlap_candidates(pr, pc, storage, s) {
                        for schedule in schedule_candidates(pr, row_block, storage) {
                            let ledger = if pr == 1 {
                                analytic_ledger(
                                    ds, kernel, problem, s, req.h, req.p, req.algo, overlap,
                                )
                            } else {
                                grid_analytic_ledger(
                                    ds,
                                    kernel,
                                    problem,
                                    s,
                                    req.h,
                                    pr,
                                    pc,
                                    row_block,
                                    storage,
                                    &schedule,
                                    req.seed,
                                    req.algo,
                                    overlap,
                                )
                            };
                            let mem_feasible = match req.mem_limit_words {
                                Some(limit) => ledger.mem_per_rank() <= limit,
                                None => true,
                            };
                            for &t in &t_cands {
                                let predicted = machine.predict(&ledger, t);
                                candidates.push(Candidate {
                                    pr,
                                    pc,
                                    t,
                                    s,
                                    storage,
                                    row_block,
                                    overlap,
                                    schedule,
                                    mem_feasible,
                                    predicted,
                                    ledger: ledger.clone(),
                                    theorem,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    rank_candidates(&mut candidates);
    TunedPlan {
        p: req.p,
        h: req.h,
        algo: req.algo,
        machine: *machine,
        problem: *problem,
        dataset: ds.name.clone(),
        candidates,
    }
}

/// Sort candidates: memory-feasible ones strictly first (the
/// `--mem-limit` filter — infeasible candidates stay visible at the
/// bottom instead of vanishing), then by predicted total time, ties
/// broken by `(pr, storage, row_block, overlap, schedule, t, s)`
/// ascending — a total order over the candidate keys, so the ranking
/// cannot depend on enumeration order. `Off` sorts before the
/// overlapped modes and `Uniform` before the locality-aware schedule,
/// so a zero-benefit mode never displaces the simpler configuration.
fn rank_candidates(candidates: &mut [Candidate]) {
    let storage_key = |c: &Candidate| match c.storage {
        GridStorage::Replicated => 0u8,
        GridStorage::Sharded => 1u8,
    };
    let overlap_key = |c: &Candidate| match c.overlap {
        OverlapMode::Off => 0u8,
        OverlapMode::Exchange => 1u8,
        OverlapMode::Pipeline => 2u8,
    };
    let schedule_key = |c: &Candidate| match c.schedule.kind {
        ScheduleKind::Uniform => 0u8,
        ScheduleKind::ShuffledEpochs => 1u8,
        ScheduleKind::LocalityAware => 2u8,
    };
    candidates.sort_unstable_by(|a, b| {
        b.mem_feasible
            .cmp(&a.mem_feasible)
            .then_with(|| {
                a.predicted
                    .total_secs()
                    .total_cmp(&b.predicted.total_secs())
            })
            .then_with(|| a.pr.cmp(&b.pr))
            .then_with(|| storage_key(a).cmp(&storage_key(b)))
            .then_with(|| a.row_block.cmp(&b.row_block))
            .then_with(|| overlap_key(a).cmp(&overlap_key(b)))
            .then_with(|| schedule_key(a).cmp(&schedule_key(b)))
            .then_with(|| a.t.cmp(&b.t))
            .then_with(|| a.s.cmp(&b.s))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SvmVariant;

    fn svm() -> ProblemSpec {
        ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        }
    }

    #[test]
    fn factorizations_cover_all_divisor_pairs() {
        assert_eq!(factorizations(1), vec![(1, 1)]);
        assert_eq!(factorizations(6), vec![(1, 6), (2, 3), (3, 2), (6, 1)]);
        assert_eq!(factorizations(7), vec![(1, 7), (7, 1)]);
        for p in 1..=24usize {
            for (pr, pc) in factorizations(p) {
                assert_eq!(pr * pc, p);
            }
        }
    }

    #[test]
    fn candidate_sets_are_bounded_sorted_and_contain_identity() {
        let mut req = TuneRequest::new(8, 64);
        req.s_max = 32;
        let s = req.s_candidates();
        assert_eq!(s, vec![1, 2, 4, 8, 16, 32]);
        // h caps the grid below s_max.
        req.h = 5;
        assert_eq!(req.s_candidates(), vec![1, 2, 4]);
        // Explicit lists are filtered, deduped, and still contain 1.
        req.h = 64;
        req.s_list = vec![32, 8, 8, 900, 0];
        assert_eq!(req.s_candidates(), vec![1, 8, 32]);

        let m = MachineProfile::cray_ex(); // 16 cores
        let req = TuneRequest::new(8, 64);
        assert_eq!(req.t_candidates(&m), vec![1, 2, 4, 8, 16]);
        let mut req12 = TuneRequest::new(8, 64);
        req12.t_max = 12;
        assert_eq!(req12.t_candidates(&m), vec![1, 2, 4, 8, 12]);
        let mut explicit = TuneRequest::new(8, 64);
        explicit.t_list = vec![64, 3, 1, 3];
        // 64 exceeds the 16-core budget and is dropped.
        assert_eq!(explicit.t_candidates(&m), vec![1, 3]);
    }

    #[test]
    fn plan_covers_space_and_best_is_min_total() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let mut req = TuneRequest::new(6, 16);
        req.s_list = vec![4];
        req.t_list = vec![1, 4];
        let machine = MachineProfile::cray_ex();
        let plan = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        // 1D: (s=1 → off) + (s=4 → off, pipeline) = 3 ledgers × 2 t = 6.
        // Grids 2x3 and 3x2: replicated 3 row blocks × (1 + 2)
        // overlap-by-s = 9 (uniform only), sharded 3 × (2 + 3) = 15
        // (exchange joins the axis) × 2 schedules (the locality-aware
        // schedule joins on sharded grids) = 30, so 39 ledgers × 2 t =
        // 78 each. Grid 6x1 has a single-member reduce, so pipeline
        // drops off the axis: replicated 3 × 2 = 6 + sharded
        // 3 × 4 × 2 schedules = 24, so 30 ledgers × 2 t = 60.
        assert_eq!(plan.candidates.len(), 6 + 2 * 78 + 60);
        let best = plan.best().predicted.total_secs();
        for c in &plan.candidates {
            assert!(c.predicted.total_secs() >= best);
            assert_eq!(c.ranks(), 6);
            assert!(c.mem_feasible, "no --mem-limit ⇒ everything feasible");
            if c.pr == 1 {
                assert_eq!(c.storage, GridStorage::Replicated);
                assert_eq!(c.storage_tag(), "-");
            }
            assert!(c.mem_words() > 0);
        }
        // Both storage modes are genuinely enumerated on grids.
        assert!(plan
            .candidates
            .iter()
            .any(|c| c.pr > 1 && c.storage == GridStorage::Sharded));
        // The overlap axis is enumerated where it has a substrate —
        // exchange only on sharded grids, pipeline only on s > 1 — and
        // an overlapped candidate never predicts slower than its
        // blocking twin (the totals are identical; overlap only credits
        // the hidden fraction).
        assert!(plan.candidates.iter().any(|c| c.overlap == OverlapMode::Exchange));
        assert!(plan.candidates.iter().any(|c| c.overlap == OverlapMode::Pipeline));
        for c in &plan.candidates {
            match c.overlap {
                OverlapMode::Off => {}
                OverlapMode::Exchange => {
                    assert!(c.pr > 1 && c.storage == GridStorage::Sharded, "inert exchange");
                }
                OverlapMode::Pipeline => assert!(c.s > 1, "inert pipeline"),
            }
            if c.overlap != OverlapMode::Off {
                let off = plan
                    .candidates
                    .iter()
                    .find(|o| {
                        o.overlap == OverlapMode::Off
                            && o.schedule == c.schedule
                            && (o.pr, o.pc, o.storage, o.row_block, o.t, o.s)
                                == (c.pr, c.pc, c.storage, c.row_block, c.t, c.s)
                    })
                    .expect("blocking twin exists");
                assert!(c.predicted.total_secs() <= off.predicted.total_secs());
                assert_eq!(c.ledger.comm.words, off.ledger.comm.words);
                assert!(c.ledger.comm_posted.words > 0, "enumerated overlap must post");
            }
        }
        // The schedule axis is enumerated where the count replica can
        // tell the difference — the locality-aware schedule appears on
        // sharded grids only, preset to the candidate's shape, and its
        // uniform twin stays in the plan alongside it.
        assert!(plan
            .candidates
            .iter()
            .any(|c| c.schedule.kind == ScheduleKind::LocalityAware));
        for c in &plan.candidates {
            if c.schedule.kind == ScheduleKind::Uniform {
                assert_eq!(c.schedule, ScheduleSpec::default());
            } else {
                assert_eq!(c.schedule.kind, ScheduleKind::LocalityAware);
                assert!(c.pr > 1 && c.storage == GridStorage::Sharded, "inert schedule");
                assert_eq!(c.schedule.groups, c.pr);
                assert_eq!(c.schedule.group_block, c.row_block);
                plan.candidates
                    .iter()
                    .find(|u| {
                        u.schedule.kind == ScheduleKind::Uniform
                            && u.overlap == c.overlap
                            && (u.pr, u.pc, u.storage, u.row_block, u.t, u.s)
                                == (c.pr, c.pc, c.storage, c.row_block, c.t, c.s)
                    })
                    .expect("uniform twin exists");
            }
        }
        // Sharded grids at equal (pr, pc, row_block, s) never move fewer
        // words than replicated (the exchange is pure extra traffic)…
        for c in plan.candidates.iter().filter(|c| c.storage == GridStorage::Sharded) {
            let rep = plan
                .candidates
                .iter()
                .find(|r| {
                    r.storage == GridStorage::Replicated
                        && (r.pr, r.pc, r.row_block, r.t, r.s)
                            == (c.pr, c.pc, c.row_block, c.t, c.s)
                })
                .expect("replicated twin exists");
            assert!(c.ledger.comm.words >= rep.ledger.comm.words);
            // …but need strictly less per-rank memory on genuine grids
            // with meaningfully fewer rows per cell.
            if c.pr > 1 {
                assert!(
                    c.mem_words() < rep.mem_words(),
                    "pr={} pc={} rb={}: sharded {} !< replicated {}",
                    c.pr,
                    c.pc,
                    c.row_block,
                    c.mem_words(),
                    rep.mem_words()
                );
            }
        }
        // Ranked ascending.
        for w in plan.candidates.windows(2) {
            assert!(w[0].predicted.total_secs() <= w[1].predicted.total_secs());
        }
    }

    #[test]
    fn candidate_handoff_spec_and_hint() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let req = TuneRequest::new(8, 32);
        let machine = MachineProfile::cray_ex();
        let plan = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        for c in &plan.candidates {
            let spec = c.solver_spec(plan.h, 7, 0);
            assert_eq!(spec.s, c.s);
            assert_eq!(spec.h, 32);
            assert_eq!(spec.seed, 7);
            assert_eq!(spec.threads, c.t);
            assert_eq!(spec.grid, c.grid());
            assert_eq!(spec.grid_storage, c.storage);
            assert_eq!(spec.row_block, c.row_block);
            assert_eq!(spec.overlap, c.overlap);
            assert_eq!(spec.schedule, c.schedule);
            if c.pr == 1 {
                assert_eq!(spec.grid, None);
            }
            let hint = c.cli_hint(&plan.problem, plan.h);
            assert!(hint.starts_with("kcd train-svm --p 8"), "{hint}");
            assert!(hint.contains(&format!("--s {}", c.s)), "{hint}");
            if let Some((pr, pc)) = c.grid() {
                assert!(hint.contains(&format!("--grid {pr}x{pc}")), "{hint}");
                if c.storage == GridStorage::Sharded {
                    assert!(hint.contains("--grid-storage sharded"), "{hint}");
                }
                if c.row_block != crate::gram::DEFAULT_ROW_BLOCK {
                    assert!(hint.contains(&format!("--row-block {}", c.row_block)), "{hint}");
                }
            } else {
                assert!(!hint.contains("--grid"), "{hint}");
                assert!(!hint.contains("--row-block"), "{hint}");
            }
            if c.overlap != OverlapMode::Off {
                assert!(
                    hint.contains(&format!("--overlap {}", c.overlap.name())),
                    "{hint}"
                );
            } else {
                assert!(!hint.contains("--overlap"), "{hint}");
            }
            if c.schedule.kind != ScheduleKind::Uniform {
                assert!(
                    hint.contains(&format!("--schedule {}", c.schedule.kind.name())),
                    "{hint}"
                );
            } else {
                assert!(!hint.contains("--schedule"), "{hint}");
            }
        }
        let krr_hint = plan.best().cli_hint(&ProblemSpec::Krr { lambda: 1.0, b: 2 }, 32);
        assert!(krr_hint.starts_with("kcd train-krr"), "{krr_hint}");
    }

    #[test]
    fn candidate_layouts_describe_every_rank() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let mut req = TuneRequest::new(6, 16);
        req.s_list = vec![2];
        req.t_list = vec![1];
        let machine = MachineProfile::cray_ex();
        let plan = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        for c in &plan.candidates {
            for rank in 0..c.ranks() {
                let layout = c.layout_for_rank(rank);
                match c.grid() {
                    Some((pr, pc)) => assert_eq!(
                        layout,
                        Layout::Grid {
                            pr,
                            pc,
                            row: rank / pc,
                            col: rank % pc
                        }
                    ),
                    None => assert_eq!(
                        layout,
                        Layout::ColShard {
                            rank,
                            ranks: c.ranks()
                        }
                    ),
                }
            }
        }
        // The degenerate single-rank candidate is the serial layout.
        let mut req1 = TuneRequest::new(1, 16);
        req1.s_list = vec![1];
        req1.t_list = vec![1];
        let plan1 = tune(&ds, Kernel::paper_rbf(), &svm(), &req1, &machine);
        assert_eq!(plan1.best().layout_for_rank(0), Layout::Full);
    }

    /// The `--mem-limit` feasibility filter: a budget between the
    /// sharded and replicated footprints must rank every feasible
    /// (sharded/small) candidate ahead of every infeasible one, while
    /// keeping the infeasible ones visible; an unsatisfiable budget
    /// leaves the ranking pure-time (all equally infeasible).
    #[test]
    fn mem_limit_ranks_feasible_candidates_first() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let machine = MachineProfile::cray_ex();
        let mut req = TuneRequest::new(6, 16);
        req.s_list = vec![4];
        req.t_list = vec![1];
        let open = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        let mems: Vec<u64> = open.candidates.iter().map(|c| c.mem_words()).collect();
        let (lo, hi) = (*mems.iter().min().unwrap(), *mems.iter().max().unwrap());
        assert!(lo < hi, "need a memory spread to test the filter");
        let mid = (lo + hi) / 2;
        req.mem_limit_words = Some(mid);
        let filtered = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        assert_eq!(filtered.candidates.len(), open.candidates.len(), "never dropped");
        let first_infeasible = filtered
            .candidates
            .iter()
            .position(|c| !c.mem_feasible)
            .expect("mid-budget must exclude someone");
        assert!(
            filtered.candidates[..first_infeasible].iter().all(|c| c.mem_feasible)
                && filtered.candidates[first_infeasible..].iter().all(|c| !c.mem_feasible),
            "feasible candidates must come strictly first"
        );
        assert!(filtered.best().mem_feasible);
        assert!(filtered.best().mem_words() <= mid);
        // Unsatisfiable budget: nothing feasible, ranking intact.
        req.mem_limit_words = Some(0);
        let none = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        assert!(none.candidates.iter().all(|c| !c.mem_feasible));
        for (a, b) in none.candidates.iter().zip(&open.candidates) {
            assert_eq!(
                (a.pr, a.pc, a.storage, a.row_block, a.t, a.s),
                (b.pr, b.pc, b.storage, b.row_block, b.t, b.s),
                "all-infeasible ranking must match the unfiltered one"
            );
        }
    }

    #[test]
    fn theorem_anchor_uses_reduce_ranks_of_the_candidate() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 3);
        let mut req = TuneRequest::new(8, 32);
        req.s_list = vec![4];
        req.t_list = vec![1];
        let machine = MachineProfile::cray_ex();
        let plan = tune(&ds, Kernel::paper_rbf(), &svm(), &req, &machine);
        let find = |pr: usize, s: usize| -> &Candidate {
            plan.candidates
                .iter()
                .find(|c| c.pr == pr && c.s == s && c.t == 1)
                .unwrap()
        };
        // Same flops/words at every factorization; latency follows the
        // log of the reduce-collective participant count pc.
        let c1 = find(1, 4); // pc = 8 → log2 = 3
        let c4 = find(4, 4); // pc = 2 → log2 = 1
        assert_eq!(c1.theorem.flops, c4.theorem.flops);
        assert_eq!(c1.theorem.words, c4.theorem.words);
        assert!((c4.theorem.msgs - c1.theorem.msgs / 3.0).abs() < 1e-9);
    }
}
