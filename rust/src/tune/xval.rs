//! Measured cross-validation of tuner predictions.
//!
//! A ranking is only trustworthy if the counts behind it are: this
//! module replays a candidate on real ranks ([`run_distributed`], the
//! same instrumented execution the scaling harness measures) and
//! compares the tuner's analytic traffic — total and per
//! subcommunicator — against the measured [`CommStats`] word for word,
//! plus the flop accounting phase by phase. The expectation is
//! *bitwise* traffic equality (the analytic ledgers replicate the
//! collectives' accounting exactly); anything else is a model bug, not
//! noise.

use crate::comm::CommStats;
use crate::coordinator::{run_distributed, ProblemSpec};
use crate::costmodel::{MachineProfile, Phase};
use crate::data::Dataset;
use crate::kernelfn::Kernel;

use super::{Candidate, TuneRequest};

/// The face-off between a candidate's predicted counts and a measured
/// replay of the same configuration.
#[derive(Clone, Copy, Debug)]
pub struct CrossCheck {
    /// Predicted total critical-path traffic (the candidate's ledger).
    pub predicted: CommStats,
    /// Measured total critical-path traffic.
    pub measured: CommStats,
    /// Predicted column-subcommunicator (gram reduce) traffic — zero
    /// for 1D candidates, where `predicted` holds everything.
    pub predicted_col: CommStats,
    /// Measured column-subcommunicator traffic.
    pub measured_col: CommStats,
    /// Predicted row-subcommunicator (allgather) traffic.
    pub predicted_row: CommStats,
    /// Measured row-subcommunicator traffic.
    pub measured_row: CommStats,
    /// Predicted fragment-exchange traffic (sharded grid storage; zero
    /// for replicated and 1D candidates).
    pub predicted_exch: CommStats,
    /// Measured fragment-exchange traffic.
    pub measured_exch: CommStats,
    /// Predicted posted (nonblocking, overlappable) traffic — zero for
    /// [`crate::gram::OverlapMode::Off`] candidates.
    pub predicted_posted: CommStats,
    /// Measured posted traffic.
    pub measured_posted: CommStats,
    /// Worst relative flop disagreement across phases (flop accounting
    /// is f64 arithmetic, so "equal" means ≲1e-6 relative, not bitwise).
    pub flops_rel_err: f64,
}

impl CrossCheck {
    /// True when every traffic counter — total, reduce, allgather,
    /// exchange, posted — matches the measured run exactly. Posted
    /// `msgs` is the one excluded field: the analytic replica uses
    /// rounds as a send-count proxy for the tree collectives (exact
    /// only for rings), same as the blocking `msgs` convention.
    pub fn traffic_exact(&self) -> bool {
        self.predicted == self.measured
            && self.predicted_col == self.measured_col
            && self.predicted_row == self.measured_row
            && self.predicted_exch == self.measured_exch
            && self.predicted_posted.words == self.measured_posted.words
            && self.predicted_posted.rounds == self.measured_posted.rounds
            && self.predicted_posted.allreduces == self.measured_posted.allreduces
    }

    /// One-line human summary for the `tune` report.
    pub fn summary(&self) -> String {
        if self.traffic_exact() {
            format!(
                "traffic exact (words={}, rounds={}, msgs={}); flop rel err {:.1e}",
                self.measured.words, self.measured.rounds, self.measured.msgs, self.flops_rel_err
            )
        } else {
            format!(
                "TRAFFIC MISMATCH: predicted words={} rounds={} vs measured words={} rounds={}",
                self.predicted.words, self.predicted.rounds, self.measured.words,
                self.measured.rounds
            )
        }
    }
}

/// Replay `candidate` on real ranks and compare counts (see module
/// docs). Runs `candidate.ranks()` OS threads — practical for the same
/// rank counts the measured scaling engine handles (a few dozen), which
/// is why the `tune` CLI gates this behind `--measured-limit`.
pub fn cross_validate(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    candidate: &Candidate,
    req: &TuneRequest,
    machine: &MachineProfile,
) -> CrossCheck {
    // Cache off: the analytic replica models the uncached schedule (hit
    // patterns are data-dependent and cannot be projected analytically).
    let solver = candidate.solver_spec(req.h, req.seed, 0);
    let measured = run_distributed(
        ds,
        kernel,
        problem,
        &solver,
        candidate.ranks(),
        req.algo,
        machine,
    )
    .critical;
    let mut flops_rel_err = 0.0f64;
    for ph in Phase::ALL {
        let (a, b) = (candidate.ledger.flops(ph), measured.flops(ph));
        let rel = (a - b).abs() / b.abs().max(1.0);
        flops_rel_err = flops_rel_err.max(rel);
    }
    CrossCheck {
        predicted: candidate.ledger.comm,
        measured: measured.comm,
        predicted_col: candidate.ledger.comm_col,
        measured_col: measured.comm_col,
        predicted_row: candidate.ledger.comm_row,
        measured_row: measured.comm_row,
        predicted_exch: candidate.ledger.comm_exch,
        measured_exch: measured.comm_exch,
        predicted_posted: candidate.ledger.comm_posted,
        measured_posted: measured.comm_posted,
        flops_rel_err,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SvmVariant;
    use crate::tune::{tune, TuneRequest};

    /// The trust anchor: every candidate of a small plan — 1D and grid,
    /// classical and s-step, threaded and serial — cross-validates
    /// bitwise against measured execution.
    #[test]
    fn every_candidate_cross_validates_bitwise_at_small_p() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problem = ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        };
        let machine = MachineProfile::cray_ex();
        let mut req = TuneRequest::new(6, 16);
        req.s_list = vec![4];
        req.t_list = vec![1, 2];
        let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
        assert!(!plan.candidates.is_empty());
        for c in &plan.candidates {
            let check = cross_validate(&ds, Kernel::paper_rbf(), &problem, c, &req, &machine);
            assert!(
                check.traffic_exact(),
                "pr={} pc={} t={} s={} sched={}: {}",
                c.pr,
                c.pc,
                c.t,
                c.s,
                c.schedule.label(),
                check.summary()
            );
            assert!(
                check.flops_rel_err < 1e-6,
                "pr={} s={}: flop rel err {}",
                c.pr,
                c.s,
                check.flops_rel_err
            );
            assert!(check.summary().contains("traffic exact"));
        }
    }

    #[test]
    fn mismatches_are_reported_not_masked() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problem = ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        };
        let machine = MachineProfile::cray_ex();
        let mut req = TuneRequest::new(4, 16);
        req.s_list = vec![4];
        req.t_list = vec![1];
        let plan = tune(&ds, Kernel::paper_rbf(), &problem, &req, &machine);
        let mut broken = plan.best().clone();
        broken.ledger.comm.words += 1;
        let check =
            cross_validate(&ds, Kernel::paper_rbf(), &problem, &broken, &req, &machine);
        assert!(!check.traffic_exact());
        assert!(check.summary().contains("MISMATCH"), "{}", check.summary());
    }
}
