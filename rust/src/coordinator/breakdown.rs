//! Runtime-breakdown harness (Figures 4, 7, 8): per-phase time as `s`
//! varies at a fixed process count.

use crate::comm::AllreduceAlgo;
use crate::costmodel::{MachineProfile, Phase, Projection};
use crate::data::Dataset;
use crate::gram::OverlapMode;
use crate::kernelfn::Kernel;

use super::experiment::ProblemSpec;
use super::scaling::{analytic_ledger, Engine};
use super::experiment::{run_distributed, SolverSpec};

/// One bar of a breakdown figure: the per-phase projected seconds for a
/// given `s` (with `s = 1` being the classical method).
#[derive(Clone, Debug)]
pub struct BreakdownBar {
    /// s-step block size (`1` = the classical method).
    pub s: usize,
    /// Which engine produced the bar.
    pub engine: Engine,
    /// Per-phase projected seconds.
    pub projection: Projection,
}

impl BreakdownBar {
    /// Phase fractions (sums to 1).
    pub fn fractions(&self) -> Vec<(Phase, f64)> {
        let total = self.projection.total_secs().max(f64::MIN_POSITIVE);
        Phase::ALL
            .iter()
            .map(|&ph| (ph, self.projection.phase_secs(ph) / total))
            .collect()
    }
}

/// Breakdown sweep over `s ∈ {1} ∪ s_list` at fixed `p`, with `threads`
/// intra-rank product workers per rank (`1` = the flat-MPI bars) and
/// `overlap` the communication-overlap mode of every bar (the posted
/// fraction is credited against the hidden compute in the projection,
/// shrinking the exposed Allreduce share).
#[allow(clippy::too_many_arguments)]
pub fn breakdown(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    s_list: &[usize],
    h: usize,
    p: usize,
    threads: usize,
    algo: AllreduceAlgo,
    machine: &MachineProfile,
    measured_limit: usize,
    overlap: OverlapMode,
) -> Vec<BreakdownBar> {
    // Any P within the measured budget runs Measured — the collectives
    // (and, past the limit, the analytic traffic model) handle
    // non-power-of-two rank counts.
    let engine = if p <= measured_limit {
        Engine::Measured
    } else {
        Engine::Projected
    };
    let mut bars = Vec::with_capacity(s_list.len() + 1);
    for &s in std::iter::once(&1usize).chain(s_list.iter()) {
        if s > h {
            continue;
        }
        let projection = match engine {
            Engine::Measured => {
                let solver = SolverSpec {
                    s,
                    h,
                    seed: 0xB0,
                    cache_rows: 0,
                    threads,
                    grid: None,
                    overlap,
                    ..Default::default()
                };
                run_distributed(ds, kernel, problem, &solver, p, algo, machine).projection
            }
            Engine::Projected => machine.project_hybrid(
                &analytic_ledger(ds, kernel, problem, s, h, p, algo, overlap),
                threads,
            ),
        };
        bars.push(BreakdownBar {
            s,
            engine,
            projection,
        });
    }
    bars
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SvmVariant;

    #[test]
    fn allreduce_fraction_shrinks_then_memreset_grows() {
        // colon-like: latency-bound at moderate P. Raising s must shrink
        // the allreduce share; the s-step overhead phases must appear.
        let ds = crate::data::paper_dataset("colon-cancer")
            .unwrap()
            .generate_scaled(0.5);
        let bars = breakdown(
            &ds,
            Kernel::paper_rbf(),
            &ProblemSpec::Svm {
                c: 1.0,
                variant: SvmVariant::L1,
            },
            &[8, 64],
            128,
            32,
            1,
            AllreduceAlgo::Rabenseifner,
            &MachineProfile::cray_ex(),
            0,
            OverlapMode::Off,
        );
        assert_eq!(bars.len(), 3);
        let frac = |bar: &BreakdownBar, ph: Phase| {
            bar.fractions()
                .iter()
                .find(|(q, _)| *q == ph)
                .map(|(_, f)| *f)
                .unwrap()
        };
        let ar1 = frac(&bars[0], Phase::Allreduce);
        let ar64 = frac(&bars[2], Phase::Allreduce);
        assert!(
            ar64 < ar1,
            "allreduce share should fall with s: {ar1} → {ar64}"
        );
        assert_eq!(frac(&bars[0], Phase::MemReset), 0.0, "classical has no reset");
        assert!(frac(&bars[2], Phase::MemReset) > 0.0);
        assert!(frac(&bars[2], Phase::GradCorr) > 0.0);
    }

    #[test]
    fn bandwidth_bound_regime_shows_diminishing_returns() {
        // news20-like K-RR with b=4 at large P: past the optimum, total
        // time stops improving (Figure 7's 1.14× story). Scale must keep
        // m large enough that the s·b·m-word messages are genuinely
        // bandwidth-bound (m ≈ 5000 ⇒ 20k-word messages ≫ the ~1.2k-word
        // latency/bandwidth balance point of the machine profile).
        let ds = crate::data::paper_dataset("news20")
            .unwrap()
            .generate_scaled(0.25);
        let bars = breakdown(
            &ds,
            Kernel::paper_rbf(),
            &ProblemSpec::Krr { lambda: 1.0, b: 4 },
            &[4, 16, 64, 256],
            256,
            2048,
            1,
            AllreduceAlgo::Rabenseifner,
            &MachineProfile::cray_ex(),
            0,
            OverlapMode::Off,
        );
        let t: Vec<f64> = bars.iter().map(|b| b.projection.total_secs()).collect();
        let best = t.iter().cloned().fold(f64::MAX, f64::min);
        let speedup = t[0] / best;
        assert!(
            speedup < 2.5,
            "bandwidth-bound: win should be modest, got {speedup}"
        );
        // Marginal gain from the last doubling of s must be small or
        // negative.
        let last_gain = t[t.len() - 2] / t[t.len() - 1];
        assert!(last_gain < 1.3, "diminishing returns expected: {t:?}");
    }

    /// Pipelined bars never project slower than blocking ones — the
    /// posted gram reduce is credited against the hidden inner-loop
    /// compute — and the classical `s = 1` bar is identical (nothing is
    /// pipelined there).
    #[test]
    fn pipeline_overlap_never_projects_slower() {
        let ds = crate::data::paper_dataset("colon-cancer")
            .unwrap()
            .generate_scaled(0.5);
        let run = |overlap| {
            breakdown(
                &ds,
                Kernel::paper_rbf(),
                &ProblemSpec::Svm {
                    c: 1.0,
                    variant: SvmVariant::L1,
                },
                &[8, 64],
                128,
                32,
                1,
                AllreduceAlgo::Rabenseifner,
                &MachineProfile::cray_ex(),
                0,
                overlap,
            )
        };
        let off = run(OverlapMode::Off);
        let pipe = run(OverlapMode::Pipeline);
        assert_eq!(off.len(), pipe.len());
        for (o, p) in off.iter().zip(&pipe) {
            assert!(p.projection.total_secs() <= o.projection.total_secs(), "s={}", p.s);
        }
        assert_eq!(
            off[0].projection.total_secs(),
            pipe[0].projection.total_secs(),
            "s = 1 has no pipeline substrate"
        );
        // At least one s-step bar genuinely improves.
        assert!(pipe
            .iter()
            .zip(&off)
            .any(|(p, o)| p.projection.total_secs() < o.projection.total_secs()));
    }
}
