//! Report writers: markdown tables and CSV, shared by the CLI, the
//! examples and the paper-figure benches.

use crate::costmodel::{CacheStats, Phase};

use super::breakdown::BreakdownBar;
use super::scaling::{Engine, SweepRow};

/// A simple column-aligned markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers and no rows.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as aligned GitHub-flavored markdown.
    pub fn markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &width {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|c| esc(c))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

fn engine_tag(e: Engine) -> &'static str {
    match e {
        Engine::Measured => "measured",
        Engine::Projected => "projected",
    }
}

/// Strong-scaling rows → markdown (the Figures 3/5/6 table form, plus
/// the intra-rank thread count of each hybrid point, the process-grid
/// factorization — `-` for the 1D layout, `PRxPC` for 2D points — the
/// grid-cell storage mode, the communication-overlap mode, the
/// per-rank resident-memory model in MB (`Ledger::mem_per_rank` × 8
/// bytes/word, the column the sharded storage exists to shrink), the
/// kernel-row cache hit rate and the fragment-exchange words of the
/// best s-step point — the two counters the locality-aware schedule
/// trades against each other, so a schedule ablation reads off this
/// one table).
pub fn scaling_table(rows: &[SweepRow]) -> Table {
    let mut t = Table::new(vec![
        "P", "t", "grid", "storage", "overlap", "mem (MB)", "cache hit", "exch words",
        "engine", "tuned", "classical (s)", "s-step best (s)", "best s", "speedup",
    ]);
    for r in rows {
        t.row(vec![
            r.p.to_string(),
            r.t.to_string(),
            r.grid
                .map(|(pr, pc)| format!("{pr}x{pc}"))
                .unwrap_or_else(|| "-".to_string()),
            if r.grid.is_some() {
                r.storage.name().to_string()
            } else {
                "-".to_string()
            },
            r.overlap.name().to_string(),
            format!("{:.2}", r.mem_words as f64 * 8.0 / 1e6),
            format!("{:.1}%", r.cache_hit_rate * 100.0),
            r.exch_words.to_string(),
            engine_tag(r.engine).to_string(),
            if r.tuned { "auto" } else { "-" }.to_string(),
            format!("{:.4e}", r.classical.total_secs()),
            format!("{:.4e}", r.best_sstep.total_secs()),
            r.best_s.to_string(),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t
}

/// Breakdown bars → markdown (the Figures 4/7/8 table form).
pub fn breakdown_table(bars: &[BreakdownBar]) -> Table {
    let mut header = vec!["s".to_string(), "engine".to_string(), "total (s)".to_string()];
    header.extend(Phase::ALL.iter().map(|p| p.name().to_string()));
    let mut t = Table::new(header);
    for b in bars {
        let mut cells = vec![
            if b.s == 1 {
                "classical".to_string()
            } else {
                b.s.to_string()
            },
            engine_tag(b.engine).to_string(),
            format!("{:.4e}", b.projection.total_secs()),
        ];
        cells.extend(
            Phase::ALL
                .iter()
                .map(|&p| format!("{:.3e}", b.projection.phase_secs(p))),
        );
        t.row(cells);
    }
    t
}

/// Counters collected by one `kcd serve` / `kcd predict` run, rendered
/// through the same [`Table`] machinery as the training reports.
#[derive(Clone, Copy, Debug)]
pub struct ServeReport {
    /// Requests scored (stream length, counting repeats).
    pub requests: usize,
    /// Distinct query rows after request dedup.
    pub unique: usize,
    /// Engine batches issued.
    pub batches: usize,
    /// Requested batch size (0 = one batch for the whole stream).
    pub batch: usize,
    /// Flop-equivalents charged by the gram engine.
    pub kernel_flops: f64,
    /// Kernel-row cache counters from the prediction ledger.
    pub cache: CacheStats,
    /// Wall-clock seconds spent inside the prediction calls.
    pub wall_secs: f64,
}

impl ServeReport {
    /// Scored requests per wall-clock second (0 when degenerate).
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.requests as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Mean per-batch latency in seconds (0 when no batch ran).
    pub fn batch_latency_secs(&self) -> f64 {
        if self.batches > 0 {
            self.wall_secs / self.batches as f64
        } else {
            0.0
        }
    }
}

/// Serve counters → the one-row latency/throughput table printed after
/// the request loop drains.
pub fn serve_table(r: &ServeReport) -> Table {
    let mut t = Table::new(vec![
        "requests", "unique", "batch", "batches", "wall (s)", "req/s",
        "batch lat (s)", "Gflop/s", "cache hit", "words saved",
    ]);
    t.row(vec![
        r.requests.to_string(),
        r.unique.to_string(),
        if r.batch == 0 {
            "all".to_string()
        } else {
            r.batch.to_string()
        },
        r.batches.to_string(),
        format!("{:.4e}", r.wall_secs),
        format!("{:.1}", r.requests_per_sec()),
        format!("{:.4e}", r.batch_latency_secs()),
        format!(
            "{:.3}",
            if r.wall_secs > 0.0 {
                r.kernel_flops / r.wall_secs / 1e9
            } else {
                0.0
            }
        ),
        format!("{:.1}%", r.cache.hit_rate() * 100.0),
        r.cache.words_saved.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_table_has_one_row_and_sane_rates() {
        let r = ServeReport {
            requests: 10,
            unique: 7,
            batches: 5,
            batch: 2,
            kernel_flops: 4.0e9,
            cache: CacheStats::default(),
            wall_secs: 2.0,
        };
        assert!((r.requests_per_sec() - 5.0).abs() < 1e-12);
        assert!((r.batch_latency_secs() - 0.4).abs() < 1e-12);
        let md = serve_table(&r).markdown();
        assert_eq!(md.lines().count(), 3);
        assert!(md.contains("req/s"));
        let zero = ServeReport {
            requests: 0,
            unique: 0,
            batches: 0,
            batch: 0,
            kernel_flops: 0.0,
            cache: CacheStats::default(),
            wall_secs: 0.0,
        };
        assert_eq!(zero.requests_per_sec(), 0.0);
        assert_eq!(zero.batch_latency_secs(), 0.0);
        assert!(serve_table(&zero).markdown().contains("all"));
    }

    #[test]
    fn markdown_is_aligned_and_complete() {
        let mut t = Table::new(vec!["a", "long header", "x"]);
        t.row(vec!["1", "2", "3"]);
        t.row(vec!["wide cell", "5", "6"]);
        let md = t.markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].contains("long header"));
        assert!(lines[1].starts_with("|---"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "plain"]);
        let csv = t.csv();
        assert!(csv.contains("\"x,y\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1", "2"]);
    }
}
