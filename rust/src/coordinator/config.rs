//! Experiment configuration: a TOML-subset parser plus typed accessors.
//!
//! Supported syntax (the subset every config in `configs/` uses):
//!
//! ```toml
//! # comment
//! [section]
//! key = "string"
//! n = 42
//! x = 2.5
//! flag = true
//! list = [1, 2, 4]
//! names = ["a", "b"]
//! ```
//!
//! Keys are addressed as `section.key` (top-level keys have no prefix).
//! CLI `--key value` pairs override file values via [`Config::set`].

use std::collections::BTreeMap;

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string (or an unparseable bare CLI value).
    Str(String),
    /// Number (all numerics are f64; integer accessors validate).
    Num(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `[1, 2, 4]`-style numeric list.
    NumList(Vec<f64>),
    /// `["a", "b"]`-style string list.
    StrList(Vec<String>),
}

/// Flat `section.key → value` configuration map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// An empty configuration.
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.values.insert(full_key, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
        Self::parse(&text)
    }

    /// Set/override a key from a CLI string (type inferred like the file
    /// syntax, falling back to a bare string).
    pub fn set(&mut self, key: &str, raw: &str) {
        let v = parse_value(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.values.insert(key.to_string(), v);
    }

    /// Raw value at `key` (`section.key` addressing), if present.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// Lenient string accessor: `None` when absent *or* mistyped (CLI
    /// paths must use [`Self::try_str`] instead).
    pub fn str(&self, key: &str) -> Option<&str> {
        match self.values.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Lenient number accessor (see [`Self::str`]).
    pub fn f64(&self, key: &str) -> Option<f64> {
        match self.values.get(key) {
            Some(Value::Num(n)) => Some(*n),
            _ => None,
        }
    }

    /// Lenient non-negative-integer accessor (see [`Self::str`]).
    pub fn usize(&self, key: &str) -> Option<usize> {
        self.f64(key).and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// Lenient boolean accessor (see [`Self::str`]).
    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.values.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Strict string accessor: absent → `Ok(None)`; present with any
    /// other type → an error naming the key. The lenient [`Self::str`]
    /// silently returns `None` in both cases, which lets callers fall
    /// back to defaults on malformed input — CLI paths must use the
    /// strict accessors instead.
    pub fn try_str(&self, key: &str) -> Result<Option<&str>, String> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s)),
            Some(v) => Err(format!(
                "invalid value for '{key}': expected a string, got {v:?}"
            )),
        }
    }

    /// Strict number accessor (see [`Self::try_str`]).
    pub fn try_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Num(n)) => Ok(Some(*n)),
            Some(v) => Err(format!(
                "invalid value for '{key}': expected a number, got {v:?}"
            )),
        }
    }

    /// Strict non-negative-integer accessor (see [`Self::try_str`]):
    /// negative or fractional numbers are errors, not `None`.
    pub fn try_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.try_f64(key)? {
            None => Ok(None),
            Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 => {
                Ok(Some(n as usize))
            }
            Some(n) => Err(format!(
                "invalid value for '{key}': expected a non-negative integer, got {n}"
            )),
        }
    }

    /// Strict non-negative-integer-list accessor (see [`Self::try_str`]).
    pub fn try_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::NumList(ns)) => ns
                .iter()
                .map(|n| {
                    if *n >= 0.0 && n.fract() == 0.0 && *n <= usize::MAX as f64 {
                        Ok(*n as usize)
                    } else {
                        Err(format!(
                            "invalid value for '{key}': expected non-negative integers, got {n}"
                        ))
                    }
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
            Some(v) => Err(format!(
                "invalid value for '{key}': expected an integer list, got {v:?}"
            )),
        }
    }

    /// Strict boolean accessor (see [`Self::try_str`]).
    pub fn try_bool(&self, key: &str) -> Result<Option<bool>, String> {
        match self.values.get(key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(format!(
                "invalid value for '{key}': expected true/false, got {v:?}"
            )),
        }
    }

    /// Lenient non-negative-integer-list accessor (see [`Self::str`]).
    pub fn usize_list(&self, key: &str) -> Option<Vec<usize>> {
        match self.values.get(key) {
            Some(Value::NumList(ns)) => ns
                .iter()
                .map(|n| {
                    if *n >= 0.0 && n.fract() == 0.0 {
                        Some(*n as usize)
                    } else {
                        None
                    }
                })
                .collect(),
            _ => None,
        }
    }

    /// Keys in deterministic order (reports).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated list".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::NumList(Vec::new()));
        }
        let items: Vec<&str> = split_list(inner);
        if items.iter().all(|i| i.starts_with('"')) {
            let strs = items
                .iter()
                .map(|i| parse_string(i))
                .collect::<Result<Vec<_>, _>>()?;
            return Ok(Value::StrList(strs));
        }
        let nums = items
            .iter()
            .map(|i| i.trim().parse::<f64>().map_err(|e| format!("bad number '{i}': {e}")))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::NumList(nums));
    }
    if s.starts_with('"') {
        return parse_string(s).map(Value::Str);
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_list(inner: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(inner[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(inner[start..].trim());
    out
}

fn parse_string(s: &str) -> Result<String, String> {
    let body = s
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .ok_or_else(|| format!("unterminated string {s}"))?;
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            r#"
            # top comment
            name = "duke"          # inline comment
            scale = 0.5
            [solver]
            kind = "dcd-sstep"
            s = 32
            trace = true
            p_sweep = [1, 2, 4, 8]
            kernels = ["linear", "rbf"]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str("name"), Some("duke"));
        assert_eq!(cfg.f64("scale"), Some(0.5));
        assert_eq!(cfg.str("solver.kind"), Some("dcd-sstep"));
        assert_eq!(cfg.usize("solver.s"), Some(32));
        assert_eq!(cfg.bool("solver.trace"), Some(true));
        assert_eq!(cfg.usize_list("solver.p_sweep"), Some(vec![1, 2, 4, 8]));
        assert_eq!(
            cfg.get("solver.kernels"),
            Some(&Value::StrList(vec!["linear".into(), "rbf".into()]))
        );
    }

    #[test]
    fn cli_override_wins() {
        let mut cfg = Config::parse("s = 8\n").unwrap();
        cfg.set("s", "64");
        assert_eq!(cfg.usize("s"), Some(64));
        cfg.set("dataset", "news20"); // bare string fallback
        assert_eq!(cfg.str("dataset"), Some("news20"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("x = [1, \n").is_err());
        assert!(Config::parse("x = notanumber\n").is_err());
    }

    #[test]
    fn missing_keys_are_none() {
        let cfg = Config::parse("x = 1\n").unwrap();
        assert_eq!(cfg.str("x"), None); // wrong type
        assert_eq!(cfg.f64("y"), None); // absent
        assert_eq!(cfg.usize("x"), Some(1));
    }

    #[test]
    fn strict_accessors_distinguish_absent_from_malformed() {
        let cfg = Config::parse(
            "h = 2.5\nseed = -1\nname = \"x\"\nflag = true\nn = 8\n",
        )
        .unwrap();
        // Absent keys are None, not errors.
        assert_eq!(cfg.try_usize("missing"), Ok(None));
        assert_eq!(cfg.try_f64("missing"), Ok(None));
        assert_eq!(cfg.try_str("missing"), Ok(None));
        assert_eq!(cfg.try_bool("missing"), Ok(None));
        // Well-formed values come through.
        assert_eq!(cfg.try_usize("n"), Ok(Some(8)));
        assert_eq!(cfg.try_f64("h"), Ok(Some(2.5)));
        assert_eq!(cfg.try_str("name"), Ok(Some("x")));
        assert_eq!(cfg.try_bool("flag"), Ok(Some(true)));
        // Present-but-malformed is a hard error naming the key.
        let err = cfg.try_usize("h").unwrap_err();
        assert!(err.contains("'h'") && err.contains("2.5"), "{err}");
        let err = cfg.try_usize("seed").unwrap_err();
        assert!(err.contains("'seed'") && err.contains("-1"), "{err}");
        let err = cfg.try_f64("name").unwrap_err();
        assert!(err.contains("'name'"), "{err}");
        let err = cfg.try_str("n").unwrap_err();
        assert!(err.contains("'n'"), "{err}");
        let err = cfg.try_bool("n").unwrap_err();
        assert!(err.contains("'n'"), "{err}");
    }

    #[test]
    fn strict_list_accessor() {
        let cfg = Config::parse("good = [1, 2, 4]\nbad = [1, 2.5]\nneg = [-1]\nx = 3\n").unwrap();
        assert_eq!(cfg.try_usize_list("good"), Ok(Some(vec![1, 2, 4])));
        assert_eq!(cfg.try_usize_list("missing"), Ok(None));
        assert!(cfg.try_usize_list("bad").unwrap_err().contains("'bad'"));
        assert!(cfg.try_usize_list("neg").unwrap_err().contains("'neg'"));
        assert!(cfg.try_usize_list("x").unwrap_err().contains("'x'"));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let cfg = Config::parse("tag = \"a#b\"\n").unwrap();
        assert_eq!(cfg.str("tag"), Some("a#b"));
    }
}
