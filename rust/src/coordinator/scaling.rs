//! Strong-scaling harness (Figures 3, 5, 6 and Table 4).
//!
//! Two engines produce the same [`crate::costmodel::Ledger`] shape:
//!
//! * **Measured** — real ranks over [`crate::comm::ThreadComm`]; flop and
//!   traffic counts come from instrumented execution. Practical up to a
//!   few dozen ranks on one box.
//! * **Projected** — [`analytic_ledger`] replicates, count for count,
//!   what the measured path records (the solvers' flop accounting and the
//!   collectives' traffic accounting), using the dataset's column-nnz
//!   histogram for per-shard work. This extends the sweep to the paper's
//!   `P = 4096` regime. `cargo test` cross-validates the two engines at
//!   every overlapping `P` — the projection is trusted *because* it is
//!   pinned to measured counts.
//!
//! Both engines' ledgers go through the same Hockney projection, so every
//! scaling figure is a pure function of (counts, machine profile).

use crate::comm::AllreduceAlgo;
use crate::costmodel::{Ledger, MachineProfile, Phase, Projection};
use crate::data::Dataset;
use crate::kernelfn::Kernel;

use super::experiment::{run_distributed, ProblemSpec, SolverSpec};

/// Which engine produced a scaling point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Measured,
    Projected,
}

/// One (P, s) point of a strong-scaling sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub p: usize,
    pub s: usize,
    pub engine: Engine,
    pub projection: Projection,
}

impl ScalingPoint {
    pub fn secs(&self) -> f64 {
        self.projection.total_secs()
    }
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub p_list: Vec<usize>,
    /// s values tried for the s-step method (powers of two, per paper).
    pub s_list: Vec<usize>,
    pub h: usize,
    pub seed: u64,
    pub algo: AllreduceAlgo,
    /// Ranks up to this bound run measured; beyond it, projected.
    pub measured_limit: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            p_list: vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
            s_list: vec![2, 4, 8, 16, 32, 64, 128, 256],
            h: 256,
            seed: 0x5CA1E,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 8,
        }
    }
}

/// Result rows of one dataset × kernel sweep: per P, the classical time
/// and the best-s s-step time (the quantities the paper's scaling plots
/// show).
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub p: usize,
    pub engine: Engine,
    pub classical: Projection,
    pub best_sstep: Projection,
    pub best_s: usize,
    /// All (s → projection) points, for the breakdown-style detail plots.
    pub sstep_points: Vec<(usize, Projection)>,
}

impl SweepRow {
    pub fn speedup(&self) -> f64 {
        self.classical.total_secs() / self.best_sstep.total_secs()
    }
}

/// Run a strong-scaling sweep.
pub fn sweep(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    cfg: &SweepConfig,
    machine: &MachineProfile,
) -> Vec<SweepRow> {
    cfg.p_list
        .iter()
        .map(|&p| {
            let engine = if p <= cfg.measured_limit && p.is_power_of_two() {
                Engine::Measured
            } else {
                Engine::Projected
            };
            let point = |s: usize| -> Projection {
                match engine {
                    Engine::Measured => {
                        // Cache off: the projected engine replicates the
                        // uncached counts (hit patterns are data-dependent
                        // and cannot be projected analytically).
                        let solver = SolverSpec {
                            s,
                            h: cfg.h,
                            seed: cfg.seed,
                            cache_rows: 0,
                        };
                        run_distributed(ds, kernel, problem, &solver, p, cfg.algo, machine)
                            .projection
                    }
                    Engine::Projected => {
                        let ledger = analytic_ledger(ds, kernel, problem, s, cfg.h, p, cfg.algo);
                        machine.project(&ledger)
                    }
                }
            };
            let classical = point(1);
            let mut best_s = 1;
            let mut best = classical;
            let mut sstep_points = Vec::with_capacity(cfg.s_list.len());
            for &s in &cfg.s_list {
                if s <= 1 || s > cfg.h {
                    continue;
                }
                let proj = point(s);
                if proj.total_secs() < best.total_secs() {
                    best = proj;
                    best_s = s;
                }
                sstep_points.push((s, proj));
            }
            SweepRow {
                p,
                engine,
                classical,
                best_sstep: best,
                best_s,
                sstep_points,
            }
        })
        .collect()
}

/// Replicate the measured ledger analytically: identical flop accounting
/// to the solvers and identical traffic accounting to the collectives.
///
/// `p` must be a power of two (the projected sweep uses powers of two,
/// matching the paper's process counts).
pub fn analytic_ledger(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    s: usize,
    h: usize,
    p: usize,
    algo: AllreduceAlgo,
) -> Ledger {
    assert!(p.is_power_of_two(), "projected engine wants power-of-two P");
    let m = ds.m() as f64;
    let mu = kernel.mu();
    let max_nnz = if p == 1 {
        ds.a.nnz() as f64
    } else {
        ds.a.max_shard_nnz(p) as f64
    };
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let bf = b as f64;
    let outer = h.div_ceil(s);
    let s_f = s as f64;

    let mut l = Ledger::new();
    // --- Kernel compute (gram partial product + redundant nonlinear map,
    //     plus the y-scaling pass for SVM) --------------------------------
    let gram_calls = outer as f64;
    let k_rows = s_f * bf; // sampled rows per call
    l.kernel_calls = gram_calls;
    l.kernel_rows = gram_calls * k_rows;
    l.iters = h as f64;
    l.add_flops(
        Phase::KernelCompute,
        gram_calls * (2.0 * k_rows * max_nnz + mu * k_rows * m),
    );
    if matches!(problem, ProblemSpec::Svm { .. }) {
        // yscale_rows: 2 flops per entry of the k×m block.
        l.add_flops(Phase::KernelCompute, gram_calls * 2.0 * k_rows * m);
    }

    // --- Solve / gradient / correction / update / reset ------------------
    match *problem {
        ProblemSpec::Svm { .. } => {
            l.add_flops(Phase::Solve, h as f64 * (2.0 * m + 4.0));
            if s > 1 {
                l.add_flops(Phase::GradCorr, outer as f64 * s_f * (s_f - 1.0));
                l.add_flops(Phase::Update, h as f64);
                l.add_flops(Phase::MemReset, full_blocks(h, s) as f64 * s_f * m);
            } else {
                l.add_flops(Phase::Update, h as f64);
            }
        }
        ProblemSpec::Krr { .. } => {
            l.add_flops(
                Phase::Solve,
                h as f64 * (2.0 * bf * m + bf * bf + bf * bf * bf),
            );
            l.add_flops(Phase::Update, h as f64 * bf);
            if s > 1 {
                // Σ_j j·2b² per outer = s(s−1)·b².
                l.add_flops(
                    Phase::GradCorr,
                    outer as f64 * s_f * (s_f - 1.0) * bf * bf,
                );
                l.add_flops(Phase::MemReset, full_blocks(h, s) as f64 * s_f * bf * m);
            }
        }
    }

    // --- Communication (mirror of comm::collectives accounting) ----------
    if p > 1 {
        let log2p = p.trailing_zeros() as u64;
        let mut add_allreduce = |w: u64| {
            let (words, rounds) = match algo {
                AllreduceAlgo::Rabenseifner => {
                    if (w as usize) < p {
                        // Small-vector fallback inside rabenseifner
                        // degenerates to recursive doubling.
                        (w * log2p, log2p)
                    } else {
                        (rabenseifner_max_words(w as usize, p), 2 * log2p)
                    }
                }
                AllreduceAlgo::RecursiveDoubling => (w * log2p, log2p),
                // Binomial reduce + binomial broadcast: the root sends w
                // to each of its log₂P children.
                AllreduceAlgo::Linear => (w * log2p, 2 * log2p),
            };
            l.comm.words += words;
            l.comm.rounds += rounds;
            l.comm.msgs += rounds.max(1);
            l.comm.allreduces += 1;
        };
        // One row-norm allreduce at oracle construction…
        add_allreduce(ds.m() as u64);
        // …then one gram allreduce per outer iteration (w = s·b·m).
        for _ in 0..outer {
            add_allreduce((s * b * ds.m()) as u64);
        }
    }
    l
}

/// Exact max-over-ranks words sent by the rabenseifner allreduce for a
/// `w`-word vector over power-of-two `p` ranks, replicating the integer
/// chunk arithmetic of `comm::collectives` (for `w` not divisible by `p`
/// the naive `2·w·(1−1/p)` is off by rounding; this walks the same
/// bounds).
pub fn rabenseifner_max_words(w: usize, p: usize) -> u64 {
    assert!(p.is_power_of_two());
    let bounds: Vec<usize> = (0..=p).map(|i| i * w / p).collect();
    let mut max_words = 0u64;
    for r in 0..p {
        // Reduce-scatter (recursive halving): total sent telescopes to
        // w − own_chunk.
        let own = bounds[r + 1] - bounds[r];
        let rs = w - own;
        // Allgather (recursive doubling): sends the current span each
        // round, spans doubling from the own chunk.
        let mut lo = r;
        let mut hi = r + 1;
        let mut ag = 0usize;
        let mut mask = 1usize;
        while mask < p {
            ag += bounds[hi] - bounds[lo];
            if r & mask == 0 {
                hi += hi - lo;
            } else {
                lo -= hi - lo;
            }
            mask <<= 1;
        }
        max_words = max_words.max((rs + ag) as u64);
    }
    max_words
}

/// Number of outer iterations that process a full block of `s` (the
/// ragged tail allocates its own buffer and skips the reset).
fn full_blocks(h: usize, s: usize) -> usize {
    let outer = h.div_ceil(s);
    if h % s == 0 {
        outer
    } else {
        outer - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Communicator;
    use crate::solvers::SvmVariant;

    fn svm_problem() -> ProblemSpec {
        ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        }
    }

    /// The load-bearing test: the projected engine must agree exactly
    /// with measured execution wherever both run.
    #[test]
    fn analytic_ledger_matches_measured_counts() {
        let machine = MachineProfile::cray_ex();
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
        for problem in problems {
            for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::RecursiveDoubling] {
                for p in [2usize, 4, 8] {
                    for s in [1usize, 4, 8] {
                        let h = 16;
                        let solver = SolverSpec {
                            s,
                            h,
                            seed: 77,
                            cache_rows: 0,
                        };
                        let measured = run_distributed(
                            &ds, Kernel::paper_rbf(), &problem, &solver, p, algo, &machine,
                        )
                        .critical;
                        let analytic = analytic_ledger(
                            &ds,
                            Kernel::paper_rbf(),
                            &problem,
                            s,
                            h,
                            p,
                            algo,
                        );
                        for ph in Phase::ALL {
                            let a = analytic.flops(ph);
                            let b = measured.flops(ph);
                            assert!(
                                (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                                "{problem:?} {algo:?} p={p} s={s} phase {}: analytic {a} vs measured {b}",
                                ph.name()
                            );
                        }
                        assert_eq!(
                            analytic.comm.words, measured.comm.words,
                            "{problem:?} {algo:?} p={p} s={s} words"
                        );
                        assert_eq!(
                            analytic.comm.rounds, measured.comm.rounds,
                            "{problem:?} {algo:?} p={p} s={s} rounds"
                        );
                        assert_eq!(analytic.comm.allreduces, measured.comm.allreduces);
                        assert_eq!(analytic.kernel_calls, measured.kernel_calls);
                        assert_eq!(analytic.kernel_rows, measured.kernel_rows);
                        assert_eq!(analytic.iters, measured.iters);
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_produces_paper_shape_for_latency_bound_dataset() {
        // duke-like: tiny m, dense — the 9.8× regime. At large P the
        // s-step method must win by a lot; the win must grow with P.
        let ds = crate::data::paper_dataset("duke").unwrap().generate();
        let cfg = SweepConfig {
            p_list: vec![4, 64, 512],
            s_list: vec![8, 32, 128],
            h: 64,
            seed: 1,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 4,
        };
        let machine = MachineProfile::cray_ex();
        let rows = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &cfg, &machine);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].engine, Engine::Measured);
        assert_eq!(rows[2].engine, Engine::Projected);
        let sp_small = rows[0].speedup();
        let sp_large = rows[2].speedup();
        assert!(
            sp_large > sp_small,
            "speedup should grow with P: {sp_small} vs {sp_large}"
        );
        assert!(
            sp_large > 3.0 && sp_large < 64.0,
            "paper-regime speedup at P=512, got {sp_large}"
        );
    }

    #[test]
    fn krr_speedup_shrinks_with_block_size() {
        // Table 4's trend: larger b ⇒ more bandwidth-bound ⇒ smaller win.
        let ds = crate::data::paper_dataset("colon-cancer")
            .unwrap()
            .generate_scaled(0.5);
        let machine = MachineProfile::cray_ex();
        // P ≤ m/2 so even the b = 1 message (m words) stays above the
        // small-message collective fallback (which would flip the trend).
        let cfg = SweepConfig {
            p_list: vec![16],
            s_list: vec![4, 16, 64],
            h: 64,
            seed: 2,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 0, // pure projection, fast
        };
        let mut speedups = Vec::new();
        for b in [1usize, 4, 16] {
            let rows = sweep(
                &ds,
                Kernel::paper_rbf(),
                &ProblemSpec::Krr { lambda: 1.0, b },
                &cfg,
                &machine,
            );
            speedups.push(rows[0].speedup());
        }
        assert!(
            speedups[0] > speedups[1] && speedups[1] > speedups[2],
            "speedup should shrink with b: {speedups:?}"
        );
    }

    #[test]
    fn rabenseifner_word_formula_matches_traffic_exactly() {
        // Pin the chunk-walking word count to the real collective,
        // including w not divisible by p (integer-rounding cases).
        for p in [2usize, 4, 8, 16] {
            for w in [16usize, 64, 100, 1000, 1001] {
                if w < p {
                    continue;
                }
                let stats = crate::comm::run_ranks(p, |c| {
                    let mut buf = vec![1.0; w];
                    crate::comm::allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
                    c.stats()
                });
                let max_words = stats.iter().map(|s| s.words).max().unwrap();
                let expect = rabenseifner_max_words(w, p);
                assert_eq!(max_words, expect, "p={p} w={w}");
                // And the ideal 2w(1−1/p) is within rounding slack.
                let ideal = 2.0 * w as f64 * (1.0 - 1.0 / p as f64);
                assert!((expect as f64 - ideal).abs() <= 2.0 * p as f64);
            }
        }
    }
}
