//! Strong-scaling harness (Figures 3, 5, 6 and Table 4).
//!
//! Two engines produce the same [`crate::costmodel::Ledger`] shape:
//!
//! * **Measured** — real ranks over [`crate::comm::ThreadComm`]; flop and
//!   traffic counts come from instrumented execution. Practical up to a
//!   few dozen ranks on one box.
//! * **Projected** — [`analytic_ledger`] replicates, count for count,
//!   what the measured path records (the solvers' flop accounting and the
//!   collectives' traffic accounting), using the dataset's column-nnz
//!   histogram for per-shard work. This extends the sweep to the paper's
//!   `P = 4096` regime. `cargo test` cross-validates the two engines at
//!   every overlapping `P` — the projection is trusted *because* it is
//!   pinned to measured counts.
//!
//! Both engines' ledgers go through the same Hockney projection, so every
//! scaling figure is a pure function of (counts, machine profile).

use crate::comm::{AllreduceAlgo, CommStats};
use crate::costmodel::{Ledger, MachineProfile, Phase, Projection};
use crate::data::Dataset;
use crate::gram::{GridStorage, OverlapMode};
use crate::kernelfn::Kernel;
use crate::schedule::{packed_row_costs, ScheduleSpec};
use crate::sparse::Csr;

use super::experiment::{run_distributed, ProblemSpec, SolverSpec};

/// Which engine produced a scaling point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Real ranks over the threaded transport; instrumented counts.
    Measured,
    /// Analytic count replica (pinned to the measured engine in tests).
    Projected,
}

/// One (P, s) point of a strong-scaling sweep.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    /// Rank count.
    pub p: usize,
    /// s-step block size (`1` = classical).
    pub s: usize,
    /// Which engine produced the point.
    pub engine: Engine,
    /// Hockney projection of the point's critical-path ledger.
    pub projection: Projection,
}

impl ScalingPoint {
    /// Projected total seconds.
    pub fn secs(&self) -> f64 {
        self.projection.total_secs()
    }
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Rank counts to sweep.
    pub p_list: Vec<usize>,
    /// s values tried for the s-step method (powers of two, per paper).
    pub s_list: Vec<usize>,
    /// Intra-rank worker-thread counts: the sweep covers the hybrid
    /// grid `p_list × t_list` (P MPI-style ranks, each splitting its
    /// gram product across `t` threads). `vec![1]` reproduces the
    /// paper's flat-MPI sweep.
    pub t_list: Vec<usize>,
    /// Row-group count of the 2D process-grid layout: every sweep point
    /// `P` divisible by `pr` runs as `Grid{pr, P/pr}` (gram reduce over a
    /// `P/pr`-rank subcommunicator); points `pr` does not divide are
    /// skipped. `1` reproduces the 1D sweep exactly.
    pub pr: usize,
    /// Storage mode of the grid cells ([`GridStorage`]; only meaningful
    /// with `pr > 1`): `Sharded` stores `≈m/pr × ≈n/pc` per cell and
    /// pays the per-call fragment exchange, `Replicated` stores the
    /// full shard. Results are bitwise identical either way; the sweep
    /// report grows `storage` and memory columns.
    pub grid_storage: GridStorage,
    /// Block-cyclic row-block size of grid points (ignored with
    /// `pr = 1`). The auto-tuned rows override it with the tuner's
    /// choice.
    pub row_block: usize,
    /// Communication-overlap mode ([`OverlapMode`]) of every sweep
    /// point: `Exchange` overlaps the sharded grid's fragment exchange
    /// with the owned-rows partial product, `Pipeline` posts the next
    /// outer block's gram reduce under the current block's updates.
    /// Results are bitwise identical in every mode; the ledgers grow a
    /// posted-communication column the projection can credit. The
    /// analytic engine replicates the posted/hidden split exactly.
    pub overlap: OverlapMode,
    /// Coordinate schedule ([`ScheduleSpec`]) of every sweep point: the
    /// seeded sampler the solvers draw their coordinate stream through.
    /// The analytic engine replays the same schedule
    /// ([`gram_call_samples`]), so measured and projected rows stay
    /// count-identical for every kind. The default `Uniform` reproduces
    /// the legacy per-problem PCG stream bit for bit.
    pub schedule: ScheduleSpec,
    /// Inner iterations `H`.
    pub h: usize,
    /// Coordinate-stream seed shared by every point.
    pub seed: u64,
    /// Allreduce algorithm for the measured engine (mirrored by the
    /// analytic traffic replica).
    pub algo: AllreduceAlgo,
    /// Ranks up to this bound run measured; beyond it, projected.
    pub measured_limit: usize,
    /// Run the cost-model auto-tuner ([`crate::tune`]) per sweep point
    /// and append its predicted-best `(pr, pc, t, s)` configuration as
    /// an extra row alongside the user grid (marked in
    /// [`SweepRow::tuned`]). Candidates are drawn from this sweep's
    /// `s_list` / `t_list` and the factorizations of each `P`; the
    /// tuned row runs on the same engine rule (`measured_limit`) as the
    /// rest of the sweep.
    pub auto_tune: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            p_list: vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512],
            s_list: vec![2, 4, 8, 16, 32, 64, 128, 256],
            t_list: vec![1],
            pr: 1,
            grid_storage: GridStorage::Replicated,
            row_block: crate::gram::DEFAULT_ROW_BLOCK,
            overlap: OverlapMode::Off,
            schedule: ScheduleSpec::default(),
            h: 256,
            seed: 0x5CA1E,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 8,
            auto_tune: false,
        }
    }
}

/// Result rows of one dataset × kernel sweep: per (P, t), the classical
/// time and the best-s s-step time (the quantities the paper's scaling
/// plots show).
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// Rank count of this point.
    pub p: usize,
    /// Intra-rank worker threads of this hybrid point.
    pub t: usize,
    /// `Some((pr, pc))` when this point ran the 2D grid layout.
    pub grid: Option<(usize, usize)>,
    /// Grid-cell storage mode of this point (`Replicated` for 1D rows,
    /// where the field is meaningless).
    pub storage: GridStorage,
    /// Per-rank resident-memory model of this point in f64 words
    /// ([`Ledger::mem_per_rank`]): the max over this row's classical and
    /// s-step configurations (the s-step block enlarges the scratch).
    pub mem_words: u64,
    /// Communication-overlap mode this point ran (the sweep's
    /// [`SweepConfig::overlap`], or the tuner's pick on tuned rows).
    pub overlap: OverlapMode,
    /// Which engine produced the point.
    pub engine: Engine,
    /// Classical (`s = 1`) projection.
    pub classical: Projection,
    /// Best s-step projection over `s_list`.
    pub best_sstep: Projection,
    /// The `s` achieving [`Self::best_sstep`].
    pub best_s: usize,
    /// All (s → projection) points, for the breakdown-style detail plots.
    pub sstep_points: Vec<(usize, Projection)>,
    /// Kernel-row cache hit rate of the best-s configuration's critical
    /// ledger ([`crate::costmodel::CacheStats::hit_rate`]); `0` when the
    /// point ran cache-off (the sweep engines' default) or never
    /// consulted the cache.
    pub cache_hit_rate: f64,
    /// Fragment-exchange words of the best-s configuration's critical
    /// ledger (`comm_exch.words`; non-zero only for sharded grid
    /// points) — the traffic column the locality-aware schedule
    /// ablation compares.
    pub exch_words: u64,
    /// True when this row is the auto-tuner's predicted-best
    /// configuration ([`SweepConfig::auto_tune`]) rather than a point
    /// of the user's sweep grid.
    pub tuned: bool,
}

impl SweepRow {
    /// Classical-over-best-s-step projected-time ratio (the paper's
    /// headline metric).
    pub fn speedup(&self) -> f64 {
        self.classical.total_secs() / self.best_sstep.total_secs()
    }
}

/// Run a strong-scaling sweep over the hybrid grid `p_list × t_list`.
///
/// Every `P ≤ measured_limit` runs on the Measured engine — including
/// non-power-of-two rank counts, which the collectives handle via the
/// standard pre-fold (it used to silently downgrade those to the
/// Projected engine). Points beyond the limit use [`analytic_ledger`],
/// which replicates the collectives' traffic accounting for any `P`.
///
/// With `cfg.pr > 1`, every point `P` divisible by `pr` runs the 2D
/// `Grid{pr, P/pr}` layout instead of 1D (measured via
/// `solvers::GridGram`, projected via [`grid_analytic_ledger`]); points
/// `pr` does not divide are skipped, and the row's `grid` field records
/// the factorization for the report's grid column.
pub fn sweep(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    cfg: &SweepConfig,
    machine: &MachineProfile,
) -> Vec<SweepRow> {
    let t_list: &[usize] = if cfg.t_list.is_empty() {
        &[1]
    } else {
        &cfg.t_list
    };
    let pr = cfg.pr.max(1);
    let mut rows = Vec::with_capacity(cfg.p_list.len() * t_list.len());
    for &p in &cfg.p_list {
        // Grid sweeps skip every point pr does not divide — including
        // P = 1 — so a grid sweep never silently mixes layouts.
        let grid = if pr > 1 {
            if p % pr != 0 {
                continue;
            }
            Some((pr, p / pr))
        } else {
            None
        };
        let engine = if p <= cfg.measured_limit {
            Engine::Measured
        } else {
            Engine::Projected
        };
        // Counts are thread-invariant (the contract this PR pins), so
        // solve/model each (P, s) point ONCE and re-project it per t —
        // a measured hybrid sweep costs one distributed run per s, not
        // one per (s, t).
        let classical_ledger = point_ledger(ds, kernel, problem, cfg, machine, engine, grid, p, 1);
        let mut sstep_ledgers = Vec::with_capacity(cfg.s_list.len());
        for &s in &cfg.s_list {
            if s <= 1 || s > cfg.h {
                continue;
            }
            sstep_ledgers.push((
                s,
                point_ledger(ds, kernel, problem, cfg, machine, engine, grid, p, s),
            ));
        }
        let mem_words = sstep_ledgers
            .iter()
            .map(|(_, l)| l.mem_per_rank())
            .fold(classical_ledger.mem_per_rank(), u64::max);
        let storage = if grid.is_some() {
            cfg.grid_storage
        } else {
            GridStorage::Replicated
        };
        for &t in t_list {
            let classical = machine.project_hybrid(&classical_ledger, t);
            let mut best_s = 1;
            let mut best = classical;
            let mut best_ledger = &classical_ledger;
            let mut sstep_points = Vec::with_capacity(sstep_ledgers.len());
            for (s, ledger) in &sstep_ledgers {
                let proj = machine.project_hybrid(ledger, t);
                if proj.total_secs() < best.total_secs() {
                    best = proj;
                    best_s = *s;
                    best_ledger = ledger;
                }
                sstep_points.push((*s, proj));
            }
            rows.push(SweepRow {
                p,
                t,
                grid,
                storage,
                mem_words,
                overlap: cfg.overlap,
                engine,
                classical,
                best_sstep: best,
                best_s,
                sstep_points,
                cache_hit_rate: best_ledger.cache.hit_rate(),
                exch_words: best_ledger.comm_exch.words,
                tuned: false,
            });
        }
    }
    if cfg.auto_tune {
        for &p in &cfg.p_list {
            rows.push(tuned_row(ds, kernel, problem, cfg, machine, t_list, p));
        }
    }
    rows
}

/// One point's critical-path ledger under the sweep's engine rule:
/// measured (real ranks, cache off — the projected engine replicates
/// the uncached counts; hit patterns are data-dependent and cannot be
/// projected analytically) or the analytic count replica. Shared by the
/// sweep grid and the auto-tuned extra rows so the two cannot drift.
#[allow(clippy::too_many_arguments)]
fn point_ledger(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    cfg: &SweepConfig,
    machine: &MachineProfile,
    engine: Engine,
    grid: Option<(usize, usize)>,
    p: usize,
    s: usize,
) -> Ledger {
    match engine {
        Engine::Measured => {
            let solver = SolverSpec {
                s,
                h: cfg.h,
                seed: cfg.seed,
                cache_rows: 0,
                threads: 1,
                grid,
                grid_storage: cfg.grid_storage,
                row_block: cfg.row_block,
                overlap: cfg.overlap,
                schedule: cfg.schedule,
            };
            run_distributed(ds, kernel, problem, &solver, p, cfg.algo, machine).critical
        }
        Engine::Projected => match grid {
            Some((pr, pc)) => grid_analytic_ledger(
                ds,
                kernel,
                problem,
                s,
                cfg.h,
                pr,
                pc,
                cfg.row_block,
                cfg.grid_storage,
                &cfg.schedule,
                cfg.seed,
                cfg.algo,
                cfg.overlap,
            ),
            None => analytic_ledger(ds, kernel, problem, s, cfg.h, p, cfg.algo, cfg.overlap),
        },
    }
}

/// The auto-tuner's predicted-best configuration for sweep point `p`,
/// evaluated as a sweep row ([`SweepConfig::auto_tune`]): the tuner
/// picks `(pr, pc, t, s)` from this sweep's candidate lists, and the
/// row's projections are then produced by the same engine rule as the
/// user grid — so a measured tuned row really ran the tuned layout.
fn tuned_row(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    cfg: &SweepConfig,
    machine: &MachineProfile,
    t_list: &[usize],
    p: usize,
) -> SweepRow {
    let mut req = crate::tune::TuneRequest::new(p, cfg.h);
    req.s_list = cfg.s_list.clone();
    req.t_list = t_list.to_vec();
    req.algo = cfg.algo;
    req.seed = cfg.seed;
    let plan = crate::tune::tune(ds, kernel, problem, &req, machine);
    let best = plan.best();
    let grid = best.grid();
    let engine = if p <= cfg.measured_limit {
        Engine::Measured
    } else {
        Engine::Projected
    };
    // The tuned row runs the tuner's chosen storage/row_block/overlap,
    // not the sweep's — thread them through a config override.
    let tuned_cfg = SweepConfig {
        grid_storage: best.storage,
        row_block: best.row_block,
        overlap: best.overlap,
        schedule: best.schedule,
        ..cfg.clone()
    };
    let cfg = &tuned_cfg;
    let classical_ledger = point_ledger(ds, kernel, problem, cfg, machine, engine, grid, p, 1);
    let classical = machine.project_hybrid(&classical_ledger, best.t);
    let (best_sstep, sstep_points, mem_words, best_ledger) = if best.s > 1 {
        let ledger = point_ledger(ds, kernel, problem, cfg, machine, engine, grid, p, best.s);
        let proj = machine.project_hybrid(&ledger, best.t);
        let mem = ledger.mem_per_rank().max(classical_ledger.mem_per_rank());
        (proj, vec![(best.s, proj)], mem, ledger)
    } else {
        (
            classical,
            Vec::new(),
            classical_ledger.mem_per_rank(),
            classical_ledger.clone(),
        )
    };
    SweepRow {
        p,
        t: best.t,
        grid,
        storage: best.storage,
        mem_words,
        overlap: best.overlap,
        engine,
        classical,
        best_sstep,
        best_s: best.s,
        sstep_points,
        cache_hit_rate: best_ledger.cache.hit_rate(),
        exch_words: best_ledger.comm_exch.words,
        tuned: true,
    }
}

/// Replay the solvers' per-gram-call sample streams without running a
/// solver: one `Vec` of (duplicate-allowed) global row indices per gram
/// call, exactly as `dcd`/`dcd_sstep` (`s_now` coordinates per call on
/// `SVM_COORD_STREAM`) and `bdcd`/`bdcd_sstep` (`s_now` blocks of `b`
/// on `KRR_COORD_STREAM`) would pass to the oracle — drawn through the
/// same [`ScheduleSpec`] the run configures, so every schedule kind
/// replays bitwise ([`crate::schedule::call_samples`]). The sharded
/// grid storage's exchange traffic depends on *which* rows each call
/// samples (their owning row groups and per-shard nnz), so the analytic
/// replica must replay the exact stream — pinned against measured
/// execution in `grid_analytic_ledger_matches_measured_counts`.
/// `row_cost` feeds the locality-aware scoring (ignored by the other
/// kinds; pass the run's [`crate::schedule::packed_row_costs`]).
/// Models the uncached schedule, like every analytic replica.
pub fn gram_call_samples(
    problem: &ProblemSpec,
    schedule: &ScheduleSpec,
    s: usize,
    h: usize,
    m: usize,
    seed: u64,
    row_cost: &[u64],
) -> Vec<Vec<usize>> {
    crate::schedule::call_samples(
        schedule,
        m,
        seed,
        problem.coord_stream(),
        s,
        h,
        problem.block_size(),
        row_cost,
    )
}

/// Per-rank resident-memory model in f64 words — the number behind
/// [`Ledger::mem_per_rank`], the scaling table's memory column and the
/// auto-tuner's `--mem-limit` feasibility filter. Counts what a rank
/// must actually hold:
///
/// * **data** — the stored CSR entries of everything the engine keeps
///   resident, at 2 words each (column index + value): the full matrix
///   (serial) or the heaviest 1D column shard; a grid cell additionally
///   holds its gathered owned-row copy, and a sharded cell holds *only*
///   that copy (the term that finally shrinks with `pr`, via
///   [`grid_cell_nnz`]). Below the transpose-path density threshold
///   ([`crate::gram::TRANSPOSE_GRAM_MAX_DENSITY`]) the product also
///   caches a transpose of its target rows — same nnz again — exactly
///   as `CsrProduct::new` / `GridProduct` decide;
/// * **cache** — `cache_rows · m` (each cached kernel row is `m` f64);
/// * **scratch** — the engine's `k×m` miss block (`k = s·b`), the
///   blocked product's `k×width` dense gather, the grid's `k×|owned|`
///   staging block, and (sharded only) the worst-case `2·k·width`
///   assembled-fragment stream.
///
/// A static function of the configuration — the measured and analytic
/// engines both call it, so their memory columns are identical by
/// construction (and the sharded data term is pinned to the engine's
/// real residency via `GridGram::resident_nnz` in
/// `rust/tests/grid_layout_props.rs`).
pub fn mem_words_per_rank(
    ds: &Dataset,
    problem: &ProblemSpec,
    solver: &SolverSpec,
    p: usize,
) -> u64 {
    assert!(p >= 1, "need at least one rank");
    let m = ds.m();
    let n = ds.n();
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let k = solver.s.max(1) * b;
    // The sparse fast path caches a transpose of the product's target
    // rows (same stored entries again); the decision follows the full
    // shard's density everywhere, like the product stages themselves.
    let sparse = ds.a.density() < crate::gram::TRANSPOSE_GRAM_MAX_DENSITY;
    let (data_nnz, width, grid_scratch) = match solver.grid {
        None => {
            let shard = if p == 1 { ds.a.nnz() } else { ds.a.max_shard_nnz(p) };
            let transpose = if sparse { shard } else { 0 };
            (
                shard + transpose,
                if p == 1 { n } else { n.div_ceil(p) },
                0usize,
            )
        }
        Some((pr, pc)) => {
            let width = n.div_ceil(pc);
            let row_block = solver.row_block.max(1);
            let max_owned = (0..pr)
                .map(|g| crate::gram::block_cyclic_rows(m, pr, g, row_block).len())
                .max()
                .unwrap_or(0);
            let staging = k * max_owned;
            let max_cell = grid_cell_nnz(&ds.a, pr, pc, row_block)
                .iter()
                .flatten()
                .copied()
                .max()
                .unwrap_or(0);
            // The owned-row copy (and, sparse, its transpose) exists in
            // both storage modes; replicated cells keep the full shard
            // on top of it.
            let owned = max_cell + if sparse { max_cell } else { 0 };
            match solver.grid_storage {
                GridStorage::Replicated => {
                    (ds.a.max_shard_nnz(pc) + owned, width, staging)
                }
                GridStorage::Sharded => {
                    // Worst-case assembled-fragment residency: k dense
                    // rows of the shard width, 2 words per entry.
                    (owned, width, staging + 2 * k * width)
                }
            }
        }
    };
    let data = 2 * data_nnz as u64;
    let cache = (solver.cache_rows * m) as u64;
    let scratch = (k * m + k * width + grid_scratch) as u64;
    data + cache + scratch
}

/// How each [`Phase`] is replicated by the analytic ledgers — the
/// structural-exhaustiveness anchor behind detlint's `phase-coverage`
/// rule (see `docs/LINTS.md`). The match has no wildcard arm on
/// purpose: adding a `Phase` variant fails compilation here until its
/// analytic treatment is decided and documented, and deleting a
/// variant's real replica from [`analytic_ledger`] /
/// [`grid_analytic_ledger`] still leaves this note naming what must
/// exist.
pub fn analytic_phase_replica(ph: Phase) -> &'static str {
    match ph {
        Phase::KernelCompute => "flops: 2*k*nnz partial product + mu*k*m epilogue per gram call",
        Phase::Allreduce => "traffic: comm/comm_col word+round replicas (allreduce_max_counts)",
        Phase::GradCorr => "flops: s*(s-1)-term gradient correction per outer block",
        Phase::Solve => "flops: per-iteration subproblem solves plus the iter-overhead floor",
        Phase::MemReset => "flops: s*b*m buffer zeroing per full outer block",
        Phase::Update => "flops: per-iteration alpha updates",
        Phase::CacheHit => "zero by construction: the analytic replicas model the cache-off engine",
        Phase::FragmentExchange => "traffic: comm_exch ring replicas (allgatherv_counts_per_rank)",
    }
}

/// Replicate the measured ledger analytically: identical flop accounting
/// to the solvers and identical traffic accounting to the collectives —
/// for any `p`, including non-powers-of-two (the collectives' pre-fold
/// is replicated exactly by [`allreduce_max_counts`]).
///
/// `overlap` replicates the nonblocking engine's posted/hidden split on
/// top of the (mode-invariant) totals: with [`OverlapMode::Pipeline`]
/// and `s > 1` the pipelined drivers post every outer block's gram
/// allreduce (`comm_posted`, the construction norm allreduce stays
/// blocking) and run all but the last block's Solve / GradCorr / Update
/// under it (hidden flops). [`OverlapMode::Exchange`] has no 1D
/// substrate and is inert here, exactly like the measured engine.
#[allow(clippy::too_many_arguments)]
pub fn analytic_ledger(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    s: usize,
    h: usize,
    p: usize,
    algo: AllreduceAlgo,
    overlap: OverlapMode,
) -> Ledger {
    assert!(p >= 1, "need at least one rank");
    let m = ds.m() as f64;
    let mu = kernel.mu();
    let max_nnz = if p == 1 {
        ds.a.nnz() as f64
    } else {
        ds.a.max_shard_nnz(p) as f64
    };
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let outer = h.div_ceil(s);

    let mut l = Ledger::new();
    // Kernel product + epilogue (layout-specific nnz), then the shared
    // layout-independent accounting.
    let k_rows = (s * b) as f64;
    l.add_flops(
        Phase::KernelCompute,
        outer as f64 * (2.0 * k_rows * max_nnz + mu * k_rows * m),
    );
    add_layout_independent_flops(&mut l, problem, s, h, m);

    // --- Communication (mirror of comm::collectives accounting) ----------
    if p > 1 {
        // The measured critical path is the elementwise max over ranks of
        // each rank's *accumulated* counters, so compose per-rank first
        // and take the max last (summing per-allreduce maxima would
        // overcount whenever different ranks maximize the `m`-word norm
        // allreduce vs the `s·b·m`-word gram allreduces — possible at
        // non-pof2 P with chunk-rounding-unaligned widths).
        // One row-norm allreduce at oracle construction (w = m), then one
        // gram allreduce per outer iteration (w = s·b·m).
        let norm = allreduce_counts_per_rank(ds.m(), p, algo);
        let gram = allreduce_counts_per_rank(s * b * ds.m(), p, algo);
        let outer = outer as u64;
        let mut max_words = 0u64;
        let mut max_rounds = 0u64;
        for (n, g) in norm.iter().zip(&gram) {
            max_words = max_words.max(n.0 + outer * g.0);
            max_rounds = max_rounds.max(n.1 + outer * g.1);
        }
        l.comm.words += max_words;
        l.comm.rounds += max_rounds;
        let max1 = |counts: &[(u64, u64)]| counts.iter().map(|c| c.1).max().unwrap_or(0).max(1);
        l.comm.msgs += max1(&norm) + outer * max1(&gram);
        l.comm.allreduces += 1 + outer;
        // Posted replica: the pipelined drivers (dispatched only for
        // s > 1) post every outer block's gram allreduce; per rank the
        // posted counters are `outer` copies of that rank's blocking
        // counts, maxed last like every other column. Rounds stand in
        // for sends (exact for the ring allreduce).
        if overlap == OverlapMode::Pipeline && s > 1 {
            let max = |f: fn(&(u64, u64)) -> u64| gram.iter().map(f).max().unwrap_or(0);
            l.comm_posted = CommStats {
                msgs: outer * max(|g| g.1),
                words: outer * max(|g| g.0),
                rounds: outer * max(|g| g.1),
                allreduces: outer,
            };
            add_pipeline_hidden_flops(&mut l, problem, s, h, m);
        }
    }
    l.mem_words = mem_words_per_rank(
        ds,
        problem,
        &SolverSpec {
            s,
            h,
            ..Default::default()
        },
        p,
    );
    l
}

/// Layout-independent flop accounting shared by the 1D and grid count
/// replicas: kernel-call/row bookkeeping, the SVM y-scaling pass, and
/// the Solve / GradCorr / Update / MemReset phases all run on replicated
/// state, so both engines must charge them with identical arithmetic —
/// one implementation keeps the `grid_analytic_with_pr1_degenerates_to_1d`
/// invariant from drifting when a solver formula changes.
fn add_layout_independent_flops(l: &mut Ledger, problem: &ProblemSpec, s: usize, h: usize, m: f64) {
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let bf = b as f64;
    let outer = h.div_ceil(s);
    let s_f = s as f64;
    let gram_calls = outer as f64;
    let k_rows = s_f * bf; // sampled rows per call
    l.kernel_calls = gram_calls;
    l.kernel_rows = gram_calls * k_rows;
    l.iters = h as f64;
    if matches!(problem, ProblemSpec::Svm { .. }) {
        // yscale_rows: 2 flops per entry of the k×m block.
        l.add_flops(Phase::KernelCompute, gram_calls * 2.0 * k_rows * m);
    }
    match *problem {
        ProblemSpec::Svm { .. } => {
            l.add_flops(Phase::Solve, h as f64 * (2.0 * m + 4.0));
            if s > 1 {
                l.add_flops(Phase::GradCorr, outer as f64 * s_f * (s_f - 1.0));
                l.add_flops(Phase::Update, h as f64);
                l.add_flops(Phase::MemReset, full_blocks(h, s) as f64 * s_f * m);
            } else {
                l.add_flops(Phase::Update, h as f64);
            }
        }
        ProblemSpec::Krr { .. } => {
            l.add_flops(
                Phase::Solve,
                h as f64 * (2.0 * bf * m + bf * bf + bf * bf * bf),
            );
            l.add_flops(Phase::Update, h as f64 * bf);
            if s > 1 {
                // Σ_j j·2b² per outer = s(s−1)·b².
                l.add_flops(
                    Phase::GradCorr,
                    outer as f64 * s_f * (s_f - 1.0) * bf * bf,
                );
                l.add_flops(Phase::MemReset, full_blocks(h, s) as f64 * s_f * bf * m);
            }
        }
    }
}

/// Hidden-flop replica of the pipelined s-step drivers
/// (`dcd_sstep_pipelined` / `bdcd_sstep_pipelined`): every outer block
/// except the last runs its Solve / GradCorr / Update under the next
/// block's posted gram reduce, and overlapped blocks are always
/// full-size `s` (only the final block can be partial, and it has no
/// successor to hide behind).
fn add_pipeline_hidden_flops(l: &mut Ledger, problem: &ProblemSpec, s: usize, h: usize, m: f64) {
    let outer = h.div_ceil(s);
    if outer < 2 {
        return;
    }
    let hb = (outer - 1) as f64;
    let s_f = s as f64;
    match *problem {
        ProblemSpec::Svm { .. } => {
            l.add_hidden_flops(Phase::Solve, hb * s_f * (2.0 * m + 4.0));
            l.add_hidden_flops(Phase::GradCorr, hb * s_f * (s_f - 1.0));
            l.add_hidden_flops(Phase::Update, hb * s_f);
        }
        ProblemSpec::Krr { b, .. } => {
            let bf = b as f64;
            l.add_hidden_flops(
                Phase::Solve,
                hb * s_f * (2.0 * bf * m + bf * bf + bf * bf * bf),
            );
            l.add_hidden_flops(Phase::GradCorr, hb * s_f * (s_f - 1.0) * bf * bf);
            l.add_hidden_flops(Phase::Update, hb * s_f * bf);
        }
    }
}

/// Replicate the measured 2D-grid ledger analytically, the grid analog
/// of [`analytic_ledger`]: per-cell partial-product flops from the grid
/// cells' nnz, the column-subcommunicator reduce traffic from
/// [`allreduce_counts_per_rank`] over `pc` ranks with the `1/pr`-sized
/// payload, and the row-subcommunicator ring-allgather traffic from
/// [`allgatherv_counts_per_rank`] — composed per rank (i, j) and maxed
/// last, exactly like the measured critical path. `comm` holds the
/// per-rank totals; `comm_col` / `comm_row` / `comm_exch` the
/// per-subcommunicator split. With `pr = 1` this degenerates to
/// [`analytic_ledger`] (pinned in tests).
///
/// With [`GridStorage::Sharded`], the fragment exchange is replicated
/// exactly too: the one-time setup ring (counts `2·|owned_g|`, the
/// per-row `(norm, nnz)` pairs) plus one per-call ring whose per-group
/// counts are `2·Σ nnz` of the call's deduplicated sampled rows within
/// each feature shard — which requires replaying the exact sample
/// stream ([`gram_call_samples`] with `schedule` and `seed`; every
/// schedule kind replays bitwise, locality-aware scoring included).
/// Replicated storage ignores `schedule` and `seed`.
///
/// `overlap` replicates the nonblocking engine's posted/hidden split on
/// top of the (mode-invariant) totals. [`OverlapMode::Exchange`]
/// (sharded storage only): the per-call fragment rings are posted (the
/// construction setup ring stays blocking) and the owned-rows partial
/// product runs under them — hidden `KernelCompute` flops of
/// `2·(Σ owned sampled positions)·cell_nnz` per rank.
/// [`OverlapMode::Pipeline`] (`s > 1` only): every outer block's column
/// reduce is posted; the row allgather is the exposed tail of
/// `reduce_finish` and stays out of `comm_posted`; all but the last
/// block's Solve / GradCorr / Update flops are hidden.
#[allow(clippy::too_many_arguments)]
pub fn grid_analytic_ledger(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    s: usize,
    h: usize,
    pr: usize,
    pc: usize,
    row_block: usize,
    storage: GridStorage,
    schedule: &ScheduleSpec,
    seed: u64,
    algo: AllreduceAlgo,
    overlap: OverlapMode,
) -> Ledger {
    assert!(pr >= 1 && pc >= 1, "grid dimensions must be positive");
    // Mirror the measured path's clamp (`run_distributed` passes
    // `row_block.max(1)` to the oracle) so a degenerate 0 cannot make
    // the two engines diverge — or divide by zero in the replica.
    let row_block = row_block.max(1);
    let m = ds.m() as f64;
    let mu = kernel.mu();
    let b = match *problem {
        ProblemSpec::Svm { .. } => 1usize,
        ProblemSpec::Krr { b, .. } => b,
    };
    let bf = b as f64;
    let outer = h.div_ceil(s);
    let s_f = s as f64;

    let mut l = Ledger::new();
    // --- Kernel compute: the partial product touches only this cell's
    //     rows×features nnz; the epilogue (and the layout-independent
    //     accounting below) stay full-width and redundant on every rank.
    //     Critical path = the heaviest grid cell. -----------------------
    let cell_nnz = grid_cell_nnz(&ds.a, pr, pc, row_block);
    let max_cell = cell_nnz.iter().flatten().copied().max().unwrap_or(0) as f64;
    let k_rows = s_f * bf;
    l.add_flops(
        Phase::KernelCompute,
        outer as f64 * (2.0 * k_rows * max_cell + mu * k_rows * m),
    );
    add_layout_independent_flops(&mut l, problem, s, h, m);

    // --- Communication: per-rank (i, j) composition, maxed last (the
    //     measured critical path is the max over ranks of accumulated
    //     counters). Per gram call, rank (i, j) pays the column reduce of
    //     its group's s·b·|owned_i| words at column rank j, plus the row
    //     allgather ring at row rank i; the construction-time norm
    //     allreduce (m words) runs on the column subcomm only. ----------
    let owned_len: Vec<usize> = (0..pr)
        .map(|g| crate::gram::block_cyclic_rows(ds.m(), pr, g, row_block).len())
        .collect();
    let outer_u = outer as u64;
    let norm = allreduce_counts_per_rank(ds.m(), pc, algo);
    let ag_counts: Vec<usize> = owned_len.iter().map(|&w| s * b * w).collect();
    let ring = allgatherv_counts_per_rank(&ag_counts);
    // Fragment-exchange replica (sharded storage): per row-subcomm rank
    // `i` and feature shard `j`, the setup ring plus one ring per gram
    // call with per-group counts 2·Σ nnz of that call's deduplicated
    // sampled rows — the exact counts the measured exchange's
    // `allgatherv` moves, which requires replaying the sample stream.
    // The overlap overlay below needs the exchange totals split from the
    // setup ring (only per-call rings are posted) and the per-group count
    // of *sampled positions* owned — duplicates included, because the
    // uncached engine computes every sampled row and `GridProduct`
    // charges `2·k·cell_nnz` regardless of which rows the call names.
    let mut exch_setup: Vec<(u64, u64)> = vec![(0, 0); pr];
    let mut owned_hits = vec![0u64; pr];
    let exch: Vec<Vec<(u64, u64)>> = match storage {
        GridStorage::Replicated => vec![vec![(0, 0); pc]; pr],
        GridStorage::Sharded => {
            // Per-row per-shard stored-entry counts (the nnz table the
            // measured setup ring gathers).
            let n = ds.a.ncols();
            let shard_width = n.div_ceil(pc);
            let row_shard_nnz: Vec<Vec<usize>> = (0..ds.m())
                .map(|t| {
                    let (cols, _) = ds.a.row_parts(t);
                    (0..pc)
                        .map(|j| {
                            let c0 = (j * shard_width).min(n);
                            let c1 = ((j + 1) * shard_width).min(n);
                            cols.partition_point(|&c| c < c1) - cols.partition_point(|&c| c < c0)
                        })
                        .collect()
                })
                .collect();
            let setup_counts: Vec<usize> = owned_len.iter().map(|&w| 2 * w).collect();
            let setup_ring = allgatherv_counts_per_rank(&setup_counts);
            exch_setup.clone_from(&setup_ring);
            let mut exch: Vec<Vec<(u64, u64)>> = (0..pr)
                .map(|i| vec![setup_ring[i]; pc])
                .collect();
            let row_cost = packed_row_costs(&ds.a);
            for call in gram_call_samples(problem, schedule, s, h, ds.m(), seed, &row_cost) {
                for &t in &call {
                    owned_hits[(t / row_block) % pr] += 1;
                }
                let mut uniq = call;
                uniq.sort_unstable();
                uniq.dedup();
                let mut group_rows: Vec<Vec<usize>> = vec![Vec::new(); pr];
                for &t in &uniq {
                    group_rows[(t / row_block) % pr].push(t);
                }
                for j in 0..pc {
                    let counts: Vec<usize> = group_rows
                        .iter()
                        .map(|g| g.iter().map(|&t| 2 * row_shard_nnz[t][j]).sum())
                        .collect();
                    let call_ring = allgatherv_counts_per_rank(&counts);
                    for i in 0..pr {
                        exch[i][j].0 += call_ring[i].0;
                        exch[i][j].1 += call_ring[i].1;
                    }
                }
            }
            exch
        }
    };
    let mut max_total = (0u64, 0u64, 0u64);
    let mut max_col = (0u64, 0u64, 0u64);
    let mut max_row = (0u64, 0u64, 0u64);
    let mut max_exch = (0u64, 0u64, 0u64);
    for i in 0..pr {
        let gram = allreduce_counts_per_rank(s * b * owned_len[i], pc, algo);
        for j in 0..pc {
            let col_words = norm[j].0 + outer_u * gram[j].0;
            let col_rounds = norm[j].1 + outer_u * gram[j].1;
            // Rounds stand in for sends in the allreduce replica (exact
            // for the ring, a proxy for the tree collectives — and
            // exactly zero for a single-member subcommunicator, matching
            // the measured no-op).
            let col_msgs = col_rounds;
            let row_words = outer_u * ring[i].0;
            let row_rounds = outer_u * ring[i].1;
            let row_msgs = row_rounds;
            // Exchange rings: one send per round, so msgs = rounds.
            let (ex_words, ex_rounds) = exch[i][j];
            let ex_msgs = ex_rounds;
            max_col = (
                max_col.0.max(col_words),
                max_col.1.max(col_rounds),
                max_col.2.max(col_msgs),
            );
            max_row = (
                max_row.0.max(row_words),
                max_row.1.max(row_rounds),
                max_row.2.max(row_msgs),
            );
            max_exch = (
                max_exch.0.max(ex_words),
                max_exch.1.max(ex_rounds),
                max_exch.2.max(ex_msgs),
            );
            max_total = (
                max_total.0.max(col_words + row_words + ex_words),
                max_total.1.max(col_rounds + row_rounds + ex_rounds),
                max_total.2.max(col_msgs + row_msgs + ex_msgs),
            );
        }
    }
    if pc > 1 || pr > 1 {
        l.comm.words = max_total.0;
        l.comm.rounds = max_total.1;
        l.comm.msgs = max_total.2;
        l.comm.allreduces = 1 + outer_u;
        l.comm_col = CommStats {
            msgs: max_col.2,
            words: max_col.0,
            rounds: max_col.1,
            allreduces: 1 + outer_u,
        };
        l.comm_row = CommStats {
            msgs: max_row.2,
            words: max_row.0,
            rounds: max_row.1,
            allreduces: 0,
        };
        l.comm_exch = CommStats {
            msgs: max_exch.2,
            words: max_exch.0,
            rounds: max_exch.1,
            allreduces: 0,
        };
    }
    // --- Overlap overlay: the posted/hidden split of the nonblocking
    //     engine, replicated per rank (i, j) and maxed last. The totals
    //     above are mode-invariant — overlap only moves counters into
    //     `comm_posted` / hidden flops. -----------------------------------
    match overlap {
        OverlapMode::Off => {}
        OverlapMode::Exchange => {
            // `product_into` posts each call's fragment ring and computes
            // the owned-rows partial under it; the setup ring runs
            // blocking at construction. Ring sends: msgs = rounds.
            if storage == GridStorage::Sharded && (pc > 1 || pr > 1) {
                let mut posted = (0u64, 0u64);
                let mut hidden = 0f64;
                for i in 0..pr {
                    for j in 0..pc {
                        posted.0 = posted.0.max(exch[i][j].0 - exch_setup[i].0);
                        posted.1 = posted.1.max(exch[i][j].1 - exch_setup[i].1);
                        hidden = hidden.max(2.0 * owned_hits[i] as f64 * cell_nnz[i][j] as f64);
                    }
                }
                l.comm_posted = CommStats {
                    msgs: posted.1,
                    words: posted.0,
                    rounds: posted.1,
                    allreduces: 0,
                };
                l.add_hidden_flops(Phase::KernelCompute, hidden);
            }
        }
        OverlapMode::Pipeline => {
            // The pipelined drivers (dispatched only for s > 1) post
            // every outer block's column reduce; the row allgather is
            // the exposed tail of `reduce_finish`. Rounds stand in for
            // sends (exact for the ring allreduce).
            if s > 1 && (pc > 1 || pr > 1) {
                let mut posted = (0u64, 0u64);
                for i in 0..pr {
                    for g in &allreduce_counts_per_rank(s * b * owned_len[i], pc, algo) {
                        posted.0 = posted.0.max(outer_u * g.0);
                        posted.1 = posted.1.max(outer_u * g.1);
                    }
                }
                l.comm_posted = CommStats {
                    msgs: posted.1,
                    words: posted.0,
                    rounds: posted.1,
                    allreduces: outer_u,
                };
                add_pipeline_hidden_flops(&mut l, problem, s, h, m);
            }
        }
    }
    l.mem_words = mem_words_per_rank(
        ds,
        problem,
        &SolverSpec {
            s,
            h,
            grid: Some((pr, pc)),
            grid_storage: storage,
            row_block,
            ..Default::default()
        },
        pr * pc,
    );
    l
}

/// Per-rank nnz of every `pr × pc` grid cell: `out[i][j]` is the stored
/// entries of the block-cyclic row group `i` restricted to column shard
/// `j` — the flop base of that cell's partial product.
pub fn grid_cell_nnz(a: &Csr, pr: usize, pc: usize, row_block: usize) -> Vec<Vec<usize>> {
    let n = a.ncols();
    let width = n.div_ceil(pc);
    let mut out = vec![vec![0usize; pc]; pr];
    for t in 0..a.nrows() {
        let group = (t / row_block) % pr;
        let (cols, _) = a.row_parts(t);
        for (j, cell) in out[group].iter_mut().enumerate() {
            let c0 = (j * width).min(n);
            let c1 = ((j + 1) * width).min(n);
            let lo = cols.partition_point(|&c| c < c0);
            let hi = cols.partition_point(|&c| c < c1);
            *cell += hi - lo;
        }
    }
    out
}

/// Per-rank `(words, rounds)` of one ring [`crate::comm::allgatherv`]
/// with the given per-rank contribution counts — exactly the counters the
/// collective records, replicated message-free: rank `g` forwards blocks
/// `g, g−1, …` over `P−1` rounds, sending every block except its
/// successor's own.
pub fn allgatherv_counts_per_rank(counts: &[usize]) -> Vec<(u64, u64)> {
    let p = counts.len();
    if p <= 1 {
        return vec![(0, 0); p.max(1)];
    }
    // Rank g forwards blocks g, g−1, …, i.e. every block except its
    // successor's own — the per-rank sum telescopes to total − next
    // (O(p) overall; the sharded fragment-exchange replica calls this
    // once per gram call).
    let total: u64 = counts.iter().map(|&c| c as u64).sum();
    (0..p)
        .map(|g| (total - counts[(g + 1) % p] as u64, (p - 1) as u64))
        .collect()
}

/// Critical-path `(words, rounds)` of one `allreduce_sum` of a `w`-word
/// vector over `p` ranks: the elementwise max over ranks of
/// [`allreduce_counts_per_rank`].
pub fn allreduce_max_counts(w: usize, p: usize, algo: AllreduceAlgo) -> (u64, u64) {
    let counts = allreduce_counts_per_rank(w, p, algo);
    let max_words = counts.iter().map(|c| c.0).max().unwrap_or(0);
    let max_rounds = counts.iter().map(|c| c.1).max().unwrap_or(0);
    (max_words, max_rounds)
}

/// Per-rank `(words, rounds)` of one `allreduce_sum` of a `w`-word vector
/// over `p` ranks — exactly the counters `comm::collectives` records,
/// replicated message-free. Covers non-power-of-two `p` via the same
/// pre-fold the collectives use (the first `2·rem` ranks fold pairwise
/// onto `pof2` survivors, the core algorithm runs on the survivors,
/// survivors send results back).
pub fn allreduce_counts_per_rank(w: usize, p: usize, algo: AllreduceAlgo) -> Vec<(u64, u64)> {
    assert!(p >= 1);
    if p == 1 || w == 0 {
        return vec![(0, 0); p];
    }
    let ww = w as u64;
    let mut counts = Vec::with_capacity(p);
    match algo {
        AllreduceAlgo::Linear => {
            // Binomial reduce onto rank 0 + binomial broadcast; simulate
            // each rank's sends/recvs exactly.
            for rank in 0..p {
                let mut words = 0u64;
                let mut rounds = 0u64;
                // reduce_to_root: receive from children until the first
                // set bit, then send up once (rank 0 never sends).
                let mut mask = 1usize;
                while mask < p {
                    if rank & mask != 0 {
                        words += ww;
                        rounds += 1;
                        break;
                    } else if rank | mask < p {
                        rounds += 1; // recv from child
                    }
                    mask <<= 1;
                }
                // broadcast from root 0: one recv from the parent, one
                // send per child below the lowest set bit.
                if rank != 0 {
                    rounds += 1;
                }
                let lowbit = if rank == 0 {
                    p.next_power_of_two()
                } else {
                    rank & rank.wrapping_neg()
                };
                let mut mask = lowbit >> 1;
                while mask > 0 {
                    let child = rank | mask;
                    if child != rank && child < p {
                        words += ww;
                        rounds += 1;
                    }
                    mask >>= 1;
                }
                counts.push((words, rounds));
            }
        }
        AllreduceAlgo::RecursiveDoubling | AllreduceAlgo::Rabenseifner => {
            let pof2 = p.next_power_of_two() / if p.is_power_of_two() { 1 } else { 2 };
            let rem = p - pof2;
            let log2 = pof2.trailing_zeros() as u64;
            // Chunk bounds for the rabenseifner big-vector core, shared
            // across ranks.
            let bounds: Vec<usize> = (0..=pof2).map(|i| i * w / pof2).collect();
            // Core counts per survivor-group rank g.
            let core = |g: usize| -> (u64, u64) {
                match algo {
                    AllreduceAlgo::RecursiveDoubling => (ww * log2, log2),
                    AllreduceAlgo::Rabenseifner => {
                        if w < pof2 {
                            // Small-vector fallback: recursive doubling
                            // among the survivors.
                            (ww * log2, log2)
                        } else {
                            // Reduce-scatter (recursive halving): sends
                            // telescope to w − own chunk.
                            let own = bounds[g + 1] - bounds[g];
                            let mut words = (w - own) as u64;
                            // Allgather (recursive doubling): sends the
                            // current span each round, doubling from the
                            // own chunk.
                            let (mut lo, mut hi) = (g, g + 1);
                            let mut mask = 1usize;
                            while mask < pof2 {
                                words += (bounds[hi] - bounds[lo]) as u64;
                                if g & mask == 0 {
                                    hi += hi - lo;
                                } else {
                                    lo -= hi - lo;
                                }
                                mask <<= 1;
                            }
                            (words, 2 * log2)
                        }
                    }
                    AllreduceAlgo::Linear => unreachable!(),
                }
            };
            for rank in 0..p {
                if rank < 2 * rem && rank % 2 == 0 {
                    // Folded-out even rank: one send up, one result recv.
                    counts.push((ww, 2));
                    continue;
                }
                // Survivor-group rank: odds among the first 2·rem sit at
                // positions 0..rem, everyone else follows in order.
                let g = if rank < 2 * rem {
                    rank / 2
                } else {
                    rem + (rank - 2 * rem)
                };
                let (mut words, mut rounds) = core(g);
                if rank < 2 * rem {
                    // Odd fold survivor: fold recv + result send-back.
                    words += ww;
                    rounds += 2;
                }
                counts.push((words, rounds));
            }
        }
    }
    counts
}

/// Exact max-over-ranks words sent by the rabenseifner allreduce for a
/// `w`-word vector over power-of-two `p` ranks, replicating the integer
/// chunk arithmetic of `comm::collectives` (for `w` not divisible by `p`
/// the naive `2·w·(1−1/p)` is off by rounding; this walks the same
/// bounds).
pub fn rabenseifner_max_words(w: usize, p: usize) -> u64 {
    assert!(p.is_power_of_two());
    let bounds: Vec<usize> = (0..=p).map(|i| i * w / p).collect();
    let mut max_words = 0u64;
    for r in 0..p {
        // Reduce-scatter (recursive halving): total sent telescopes to
        // w − own_chunk.
        let own = bounds[r + 1] - bounds[r];
        let rs = w - own;
        // Allgather (recursive doubling): sends the current span each
        // round, spans doubling from the own chunk.
        let mut lo = r;
        let mut hi = r + 1;
        let mut ag = 0usize;
        let mut mask = 1usize;
        while mask < p {
            ag += bounds[hi] - bounds[lo];
            if r & mask == 0 {
                hi += hi - lo;
            } else {
                lo -= hi - lo;
            }
            mask <<= 1;
        }
        max_words = max_words.max((rs + ag) as u64);
    }
    max_words
}

/// Number of outer iterations that process a full block of `s` (the
/// ragged tail allocates its own buffer and skips the reset).
fn full_blocks(h: usize, s: usize) -> usize {
    let outer = h.div_ceil(s);
    if h % s == 0 {
        outer
    } else {
        outer - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Communicator;
    use crate::solvers::SvmVariant;

    fn svm_problem() -> ProblemSpec {
        ProblemSpec::Svm {
            c: 1.0,
            variant: SvmVariant::L1,
        }
    }

    /// Every phase names its analytic treatment, and the notes are
    /// distinct — a stale copy-paste (two phases claiming the same
    /// replica) would silently weaken the exhaustiveness anchor.
    #[test]
    fn analytic_phase_replica_covers_every_phase() {
        let mut seen = std::collections::BTreeSet::new();
        for ph in Phase::ALL {
            let note = analytic_phase_replica(ph);
            assert!(!note.is_empty(), "{} has an empty replica note", ph.name());
            assert!(seen.insert(note), "{} duplicates another note", ph.name());
        }
    }

    /// The load-bearing test: the projected engine must agree exactly
    /// with measured execution wherever both run — including
    /// non-power-of-two rank counts (the collectives' pre-fold) and the
    /// linear collective.
    #[test]
    fn analytic_ledger_matches_measured_counts() {
        let machine = MachineProfile::cray_ex();
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
        for problem in problems {
            for algo in [
                AllreduceAlgo::Rabenseifner,
                AllreduceAlgo::RecursiveDoubling,
                AllreduceAlgo::Linear,
            ] {
                for p in [2usize, 3, 4, 5, 6, 8, 12] {
                    for s in [1usize, 4, 8] {
                        let h = 16;
                        let solver = SolverSpec {
                            s,
                            h,
                            seed: 77,
                            cache_rows: 0,
                            threads: 1,
                            grid: None,
                            ..Default::default()
                        };
                        let measured = run_distributed(
                            &ds, Kernel::paper_rbf(), &problem, &solver, p, algo, &machine,
                        )
                        .critical;
                        let analytic = analytic_ledger(
                            &ds,
                            Kernel::paper_rbf(),
                            &problem,
                            s,
                            h,
                            p,
                            algo,
                            OverlapMode::Off,
                        );
                        for ph in Phase::ALL {
                            let a = analytic.flops(ph);
                            let b = measured.flops(ph);
                            assert!(
                                (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                                "{problem:?} {algo:?} p={p} s={s} phase {}: analytic {a} vs measured {b}",
                                ph.name()
                            );
                        }
                        assert_eq!(
                            analytic.comm.words, measured.comm.words,
                            "{problem:?} {algo:?} p={p} s={s} words"
                        );
                        assert_eq!(
                            analytic.comm.rounds, measured.comm.rounds,
                            "{problem:?} {algo:?} p={p} s={s} rounds"
                        );
                        assert_eq!(analytic.comm.allreduces, measured.comm.allreduces);
                        assert_eq!(analytic.kernel_calls, measured.kernel_calls);
                        assert_eq!(analytic.kernel_rows, measured.kernel_rows);
                        assert_eq!(analytic.iters, measured.iters);
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_produces_paper_shape_for_latency_bound_dataset() {
        // duke-like: tiny m, dense — the 9.8× regime. At large P the
        // s-step method must win by a lot; the win must grow with P.
        let ds = crate::data::paper_dataset("duke").unwrap().generate();
        let cfg = SweepConfig {
            p_list: vec![4, 64, 512],
            s_list: vec![8, 32, 128],
            t_list: vec![1],
            pr: 1,
            h: 64,
            seed: 1,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 4,
            auto_tune: false,
            ..Default::default()
        };
        let machine = MachineProfile::cray_ex();
        let rows = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &cfg, &machine);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].engine, Engine::Measured);
        assert_eq!(rows[2].engine, Engine::Projected);
        let sp_small = rows[0].speedup();
        let sp_large = rows[2].speedup();
        assert!(
            sp_large > sp_small,
            "speedup should grow with P: {sp_small} vs {sp_large}"
        );
        assert!(
            sp_large > 3.0 && sp_large < 64.0,
            "paper-regime speedup at P=512, got {sp_large}"
        );
    }

    #[test]
    fn krr_speedup_shrinks_with_block_size() {
        // Table 4's trend: larger b ⇒ more bandwidth-bound ⇒ smaller win.
        let ds = crate::data::paper_dataset("colon-cancer")
            .unwrap()
            .generate_scaled(0.5);
        let machine = MachineProfile::cray_ex();
        // P ≤ m/2 so even the b = 1 message (m words) stays above the
        // small-message collective fallback (which would flip the trend).
        let cfg = SweepConfig {
            p_list: vec![16],
            s_list: vec![4, 16, 64],
            t_list: vec![1],
            pr: 1,
            h: 64,
            seed: 2,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 0, // pure projection, fast
            auto_tune: false,
            ..Default::default()
        };
        let mut speedups = Vec::new();
        for b in [1usize, 4, 16] {
            let rows = sweep(
                &ds,
                Kernel::paper_rbf(),
                &ProblemSpec::Krr { lambda: 1.0, b },
                &cfg,
                &machine,
            );
            speedups.push(rows[0].speedup());
        }
        assert!(
            speedups[0] > speedups[1] && speedups[1] > speedups[2],
            "speedup should shrink with b: {speedups:?}"
        );
    }

    /// Regression for the non-pof2 downgrade bug: `P ≤ measured_limit`
    /// must run on the Measured engine even for non-power-of-two rank
    /// counts (the collectives handle them), and the Projected engine
    /// must cross-validate against it at the same non-pof2 points.
    #[test]
    fn non_pof2_ranks_run_measured_and_match_projection() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let machine = MachineProfile::cray_ex();
        let cfg = SweepConfig {
            p_list: vec![3, 5, 6],
            s_list: vec![4, 8],
            t_list: vec![1],
            pr: 1,
            h: 16,
            seed: 7,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 8,
            auto_tune: false,
            ..Default::default()
        };
        let measured = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &cfg, &machine);
        assert_eq!(measured.len(), 3);
        for r in &measured {
            assert_eq!(r.engine, Engine::Measured, "P={} must run measured", r.p);
        }
        let projected_cfg = SweepConfig {
            measured_limit: 0,
            ..cfg
        };
        let projected = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &projected_cfg, &machine);
        for (m, pr) in measured.iter().zip(&projected) {
            assert_eq!(pr.engine, Engine::Projected);
            assert_eq!(m.p, pr.p);
            let (a, b) = (m.classical.total_secs(), pr.classical.total_secs());
            assert!(
                (a - b).abs() <= 1e-9 * a.max(b),
                "P={}: measured {a} vs projected {b}",
                m.p
            );
            assert_eq!(m.best_s, pr.best_s, "P={}", m.p);
        }
    }

    /// The grid analytic replica must agree with measured grid execution
    /// wherever both run — total traffic AND the per-subcommunicator
    /// split, *including the sharded storage's fragment exchange* — for
    /// pof2 and non-pof2 subgroup sizes, both storage modes and both
    /// problems. This is the acceptance criterion's "analytic counts
    /// match measured CommStats exactly" for the exchange stage.
    #[test]
    fn grid_analytic_ledger_matches_measured_counts() {
        let machine = MachineProfile::cray_ex();
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
        for problem in problems {
            for storage in [GridStorage::Replicated, GridStorage::Sharded] {
                for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::RecursiveDoubling] {
                    for (pr, pc) in [
                        (2usize, 2usize),
                        (2, 3),
                        (3, 2),
                        (4, 2),
                        (2, 4),
                        (3, 3),
                        (4, 1), // degenerate column subcomm: reduce is a no-op
                        (1, 4), // degenerate row subcomm: allgather is a no-op
                    ] {
                        for s in [1usize, 4] {
                            let h = 16;
                            let solver = SolverSpec {
                                s,
                                h,
                                seed: 77,
                                cache_rows: 0,
                                threads: 1,
                                grid: Some((pr, pc)),
                                grid_storage: storage,
                                ..Default::default()
                            };
                            let measured = run_distributed(
                                &ds,
                                Kernel::paper_rbf(),
                                &problem,
                                &solver,
                                pr * pc,
                                algo,
                                &machine,
                            )
                            .critical;
                            let analytic = grid_analytic_ledger(
                                &ds,
                                Kernel::paper_rbf(),
                                &problem,
                                s,
                                h,
                                pr,
                                pc,
                                crate::gram::DEFAULT_ROW_BLOCK,
                                storage,
                                &ScheduleSpec::default(),
                                77,
                                algo,
                                OverlapMode::Off,
                            );
                            let tag = format!(
                                "{problem:?} {algo:?} {pr}x{pc} {} s={s}",
                                storage.name()
                            );
                            for ph in Phase::ALL {
                                let a = analytic.flops(ph);
                                let b = measured.flops(ph);
                                assert!(
                                    (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                                    "{tag} phase {}: {a} vs {b}",
                                    ph.name()
                                );
                            }
                            for (which, a, m) in [
                                ("total", analytic.comm, measured.comm),
                                ("col", analytic.comm_col, measured.comm_col),
                                ("row", analytic.comm_row, measured.comm_row),
                                ("exch", analytic.comm_exch, measured.comm_exch),
                            ] {
                                assert_eq!(a.words, m.words, "{tag} {which} words");
                                assert_eq!(a.rounds, m.rounds, "{tag} {which} rounds");
                            }
                            // Ring collectives send exactly once per
                            // round, so their msgs replica is exact (the
                            // tree collectives' msgs use rounds as a
                            // proxy and are excluded, as before).
                            assert_eq!(
                                analytic.comm_exch.msgs, measured.comm_exch.msgs,
                                "{tag} exch msgs"
                            );
                            assert_eq!(
                                analytic.comm_col.allreduces,
                                measured.comm_col.allreduces
                            );
                            assert_eq!(analytic.kernel_calls, measured.kernel_calls);
                            assert_eq!(analytic.kernel_rows, measured.kernel_rows);
                            // Both engines report the same memory model.
                            assert_eq!(analytic.mem_per_rank(), measured.mem_per_rank(), "{tag}");
                            if storage == GridStorage::Replicated {
                                assert_eq!(analytic.comm_exch, CommStats::default(), "{tag}");
                            } else if pr > 1 {
                                assert!(analytic.comm_exch.words > 0, "{tag}");
                            }
                        }
                    }
                }
            }
        }
    }

    /// The overlap overlay of both analytic replicas must agree with
    /// measured overlapped execution word-for-word: the mode-invariant
    /// totals stay equal to the blocking counters, and the posted split
    /// and hidden flops match the nonblocking engine exactly. This is
    /// the acceptance criterion's "analytic replicas cross-validate
    /// against measured CommStats" for the overlapped modes.
    #[test]
    fn analytic_overlap_replicas_match_measured_counts() {
        let machine = MachineProfile::cray_ex();
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let problems = [svm_problem(), ProblemSpec::Krr { lambda: 1.0, b: 3 }];
        let h = 16;
        // 1D pipeline: every outer block's gram allreduce is posted; the
        // construction norm allreduce stays blocking.
        for problem in &problems {
            for algo in [AllreduceAlgo::Rabenseifner, AllreduceAlgo::Linear] {
                for p in [2usize, 3, 4] {
                    for s in [4usize, 8] {
                        let solver = SolverSpec {
                            s,
                            h,
                            seed: 77,
                            cache_rows: 0,
                            threads: 1,
                            grid: None,
                            overlap: OverlapMode::Pipeline,
                            ..Default::default()
                        };
                        let measured = run_distributed(
                            &ds, Kernel::paper_rbf(), problem, &solver, p, algo, &machine,
                        )
                        .critical;
                        let analytic = analytic_ledger(
                            &ds,
                            Kernel::paper_rbf(),
                            problem,
                            s,
                            h,
                            p,
                            algo,
                            OverlapMode::Pipeline,
                        );
                        let tag = format!("{problem:?} {algo:?} p={p} s={s} pipeline");
                        assert_eq!(analytic.comm.words, measured.comm.words, "{tag} words");
                        assert_eq!(analytic.comm.rounds, measured.comm.rounds, "{tag} rounds");
                        assert_eq!(
                            analytic.comm_posted.words, measured.comm_posted.words,
                            "{tag} posted words"
                        );
                        assert_eq!(
                            analytic.comm_posted.rounds, measured.comm_posted.rounds,
                            "{tag} posted rounds"
                        );
                        assert_eq!(
                            analytic.comm_posted.allreduces, measured.comm_posted.allreduces,
                            "{tag} posted allreduces"
                        );
                        assert!(measured.comm_posted.words > 0, "{tag}");
                        for ph in Phase::ALL {
                            let a = analytic.hidden_flops(ph);
                            let b = measured.hidden_flops(ph);
                            assert!(
                                (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                                "{tag} hidden {}: {a} vs {b}",
                                ph.name()
                            );
                        }
                    }
                }
            }
        }
        // Grid exchange: the per-call fragment rings are posted (setup
        // ring excluded) and the owned-rows partial product is hidden.
        // Rings send exactly once per round, so the whole posted replica
        // — msgs included — is exact.
        for problem in &problems {
            for (pr, pc) in [(2usize, 2usize), (2, 3), (4, 1), (3, 2)] {
                for s in [1usize, 4] {
                    let solver = SolverSpec {
                        s,
                        h,
                        seed: 77,
                        cache_rows: 0,
                        threads: 1,
                        grid: Some((pr, pc)),
                        grid_storage: GridStorage::Sharded,
                        overlap: OverlapMode::Exchange,
                        ..Default::default()
                    };
                    let measured = run_distributed(
                        &ds,
                        Kernel::paper_rbf(),
                        problem,
                        &solver,
                        pr * pc,
                        AllreduceAlgo::Rabenseifner,
                        &machine,
                    )
                    .critical;
                    let analytic = grid_analytic_ledger(
                        &ds,
                        Kernel::paper_rbf(),
                        problem,
                        s,
                        h,
                        pr,
                        pc,
                        crate::gram::DEFAULT_ROW_BLOCK,
                        GridStorage::Sharded,
                        &ScheduleSpec::default(),
                        77,
                        AllreduceAlgo::Rabenseifner,
                        OverlapMode::Exchange,
                    );
                    let tag = format!("{problem:?} {pr}x{pc} s={s} exchange");
                    assert_eq!(analytic.comm.words, measured.comm.words, "{tag} words");
                    assert_eq!(analytic.comm.rounds, measured.comm.rounds, "{tag} rounds");
                    assert_eq!(
                        analytic.comm_exch.words, measured.comm_exch.words,
                        "{tag} exch words"
                    );
                    assert_eq!(analytic.comm_posted, measured.comm_posted, "{tag} posted");
                    let a = analytic.hidden_flops(Phase::KernelCompute);
                    let b = measured.hidden_flops(Phase::KernelCompute);
                    assert!(
                        (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                        "{tag} hidden kernel: {a} vs {b}"
                    );
                    assert!(b > 0.0, "{tag} expected hidden owned partial");
                    if pr > 1 {
                        assert!(measured.comm_posted.words > 0, "{tag}");
                    }
                }
            }
        }
        // Grid pipeline: only the column reduce is posted — the row
        // allgather is the exposed tail of `reduce_finish`.
        for storage in [GridStorage::Replicated, GridStorage::Sharded] {
            for (pr, pc) in [(2usize, 2usize), (2, 3), (1, 4)] {
                let s = 4;
                let solver = SolverSpec {
                    s,
                    h,
                    seed: 77,
                    cache_rows: 0,
                    threads: 1,
                    grid: Some((pr, pc)),
                    grid_storage: storage,
                    overlap: OverlapMode::Pipeline,
                    ..Default::default()
                };
                let measured = run_distributed(
                    &ds,
                    Kernel::paper_rbf(),
                    &svm_problem(),
                    &solver,
                    pr * pc,
                    AllreduceAlgo::Rabenseifner,
                    &machine,
                )
                .critical;
                let analytic = grid_analytic_ledger(
                    &ds,
                    Kernel::paper_rbf(),
                    &svm_problem(),
                    s,
                    h,
                    pr,
                    pc,
                    crate::gram::DEFAULT_ROW_BLOCK,
                    storage,
                    &ScheduleSpec::default(),
                    77,
                    AllreduceAlgo::Rabenseifner,
                    OverlapMode::Pipeline,
                );
                let tag = format!("{pr}x{pc} {} pipeline", storage.name());
                assert_eq!(analytic.comm.words, measured.comm.words, "{tag} words");
                assert_eq!(analytic.comm.rounds, measured.comm.rounds, "{tag} rounds");
                assert_eq!(
                    analytic.comm_posted.words, measured.comm_posted.words,
                    "{tag} posted words"
                );
                assert_eq!(
                    analytic.comm_posted.rounds, measured.comm_posted.rounds,
                    "{tag} posted rounds"
                );
                assert_eq!(
                    analytic.comm_posted.allreduces, measured.comm_posted.allreduces,
                    "{tag} posted allreduces"
                );
                if pc > 1 {
                    assert!(measured.comm_posted.words > 0, "{tag}");
                }
                for ph in Phase::ALL {
                    let a = analytic.hidden_flops(ph);
                    let b = measured.hidden_flops(ph);
                    assert!(
                        (a - b).abs() <= 1e-6 * b.abs().max(1.0),
                        "{tag} hidden {}: {a} vs {b}",
                        ph.name()
                    );
                }
            }
        }
    }

    /// With one row group the grid replica must degenerate to the 1D
    /// replica exactly (same flops, same total traffic).
    #[test]
    fn grid_analytic_with_pr1_degenerates_to_1d() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        for p in [2usize, 3, 4, 8] {
            for s in [1usize, 4] {
                let one_d = analytic_ledger(
                    &ds,
                    Kernel::paper_rbf(),
                    &svm_problem(),
                    s,
                    16,
                    p,
                    AllreduceAlgo::Rabenseifner,
                    OverlapMode::Off,
                );
                let grid = grid_analytic_ledger(
                    &ds,
                    Kernel::paper_rbf(),
                    &svm_problem(),
                    s,
                    16,
                    1,
                    p,
                    1,
                    GridStorage::Replicated,
                    &ScheduleSpec::default(),
                    0,
                    AllreduceAlgo::Rabenseifner,
                    OverlapMode::Off,
                );
                for ph in Phase::ALL {
                    assert_eq!(one_d.flops(ph), grid.flops(ph), "p={p} s={s} {}", ph.name());
                }
                assert_eq!(one_d.comm.words, grid.comm.words, "p={p} s={s}");
                assert_eq!(one_d.comm.rounds, grid.comm.rounds, "p={p} s={s}");
            }
        }
    }

    /// The acceptance criterion's traffic story: at fixed P, the grid's
    /// reduce traffic scales with the subcommunicator size pc (payload
    /// s·b·m/pr over pc ranks), far below the 1D allreduce of the full
    /// block over all P ranks.
    #[test]
    fn grid_reduce_traffic_scales_with_pc_not_p() {
        let ds = crate::data::gen_dense_classification(64, 16, 0.05, 3);
        let s = 4;
        let h = 16;
        let one_d = analytic_ledger(
            &ds,
            Kernel::paper_rbf(),
            &svm_problem(),
            s,
            h,
            8,
            AllreduceAlgo::Rabenseifner,
            OverlapMode::Off,
        );
        let grid = grid_analytic_ledger(
            &ds,
            Kernel::paper_rbf(),
            &svm_problem(),
            s,
            h,
            4,
            2,
            1,
            GridStorage::Replicated,
            &ScheduleSpec::default(),
            0,
            AllreduceAlgo::Rabenseifner,
            OverlapMode::Off,
        );
        // Reduce payload shrinks 4× (m/pr) and the tree shrinks from 8 to
        // 2 ranks: the grid's reduce words must be well under half of 1D.
        assert!(
            2 * grid.comm_col.words < one_d.comm.words,
            "grid reduce words {} !<< 1D allreduce words {}",
            grid.comm_col.words,
            one_d.comm.words
        );
        // And the total grid traffic (reduce + allgather) still beats 1D.
        assert!(
            grid.comm.words < one_d.comm.words,
            "grid total {} !< 1D {}",
            grid.comm.words,
            one_d.comm.words
        );
    }

    /// allgatherv count replica vs real ring traffic, rank by rank.
    #[test]
    fn allgatherv_counts_match_real_traffic_per_rank() {
        use crate::comm::CommStats;
        for counts in [vec![3usize, 0, 1, 2], vec![4usize, 4], vec![5usize], vec![2usize, 7, 1]] {
            let p = counts.len();
            let stats = crate::comm::run_ranks(p, |c| {
                let mine = vec![1.0; counts[c.rank()]];
                let mut stats = CommStats::default();
                // Run over a SubComm spanning everyone so the accounting
                // path matches the grid's row allgather exactly.
                let members: Vec<usize> = (0..p).collect();
                let mut sub = crate::comm::SubComm::new(c, &members, &mut stats);
                let _ = crate::comm::allgatherv(&mut sub, &mine, &counts);
                stats
            });
            let replica = allgatherv_counts_per_rank(&counts);
            for (rank, (s, &(words, rounds))) in stats.iter().zip(&replica).enumerate() {
                assert_eq!(s.words, words, "counts {counts:?} rank {rank} words");
                assert_eq!(s.rounds, rounds, "counts {counts:?} rank {rank} rounds");
            }
        }
    }

    /// Hybrid grid: one row per (P, t); more threads must cut the
    /// projected kernel phase in both engines, identically.
    #[test]
    fn hybrid_sweep_covers_grid_and_threads_cut_kernel_time() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let machine = MachineProfile::cray_ex();
        let cfg = SweepConfig {
            p_list: vec![2, 16],
            s_list: vec![4],
            t_list: vec![1, 4],
            pr: 1,
            h: 16,
            seed: 7,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 4, // P=2 measured, P=16 projected
            auto_tune: false,
            ..Default::default()
        };
        let rows = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &cfg, &machine);
        assert_eq!(rows.len(), 4);
        let find = |p: usize, t: usize| -> &SweepRow {
            rows.iter()
                .find(|r| r.p == p && r.t == t)
                .expect("grid point present")
        };
        for &(p, engine) in &[(2usize, Engine::Measured), (16usize, Engine::Projected)] {
            let r1 = find(p, 1);
            let r4 = find(p, 4);
            assert_eq!(r1.engine, engine);
            assert_eq!(r4.engine, engine);
            let k1 = r1.classical.phase_secs(Phase::KernelCompute);
            let k4 = r4.classical.phase_secs(Phase::KernelCompute);
            assert!(
                (k4 - k1 / 4.0).abs() <= 1e-9 * k1,
                "P={p}: kernel phase {k4} vs {k1}/4"
            );
            // Communication is thread-invariant.
            assert_eq!(
                r1.classical.phase_secs(Phase::Allreduce),
                r4.classical.phase_secs(Phase::Allreduce)
            );
            assert!(r4.classical.total_secs() < r1.classical.total_secs());
        }
    }

    /// The auto-tune hook: `auto_tune` appends one tuned row per sweep
    /// point, drawn from the sweep's own candidate lists, on the same
    /// engine rule as the grid — and the tuned row can never be worse
    /// than the user grid's rows at the same P under the same model
    /// (the tuner searched a superset of those configurations).
    #[test]
    fn auto_tune_appends_best_of_superset_rows() {
        let ds = crate::data::gen_dense_classification(24, 16, 0.05, 12);
        let machine = MachineProfile::cray_ex();
        let cfg = SweepConfig {
            p_list: vec![4, 16],
            s_list: vec![4, 8],
            t_list: vec![1, 4],
            pr: 1,
            h: 16,
            seed: 7,
            algo: AllreduceAlgo::Rabenseifner,
            measured_limit: 4, // P=4 measured, P=16 projected
            auto_tune: true,
            ..Default::default()
        };
        let rows = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &cfg, &machine);
        // 2 P × 2 t sweep rows + 2 tuned rows.
        assert_eq!(rows.len(), 6);
        let tuned: Vec<&SweepRow> = rows.iter().filter(|r| r.tuned).collect();
        assert_eq!(tuned.len(), 2);
        assert_eq!(tuned[0].p, 4);
        assert_eq!(tuned[0].engine, Engine::Measured);
        assert_eq!(tuned[1].p, 16);
        assert_eq!(tuned[1].engine, Engine::Projected);
        for tr in &tuned {
            if let Some((pr, pc)) = tr.grid {
                assert_eq!(pr * pc, tr.p, "tuned grid must factor P");
            }
            let best_grid_row = rows
                .iter()
                .filter(|r| !r.tuned && r.p == tr.p)
                .map(|r| r.best_sstep.total_secs().min(r.classical.total_secs()))
                .fold(f64::MAX, f64::min);
            let tuned_secs = tr.best_sstep.total_secs().min(tr.classical.total_secs());
            assert!(
                tuned_secs <= best_grid_row * (1.0 + 1e-9),
                "P={}: tuned {tuned_secs} worse than grid best {best_grid_row}",
                tr.p
            );
        }
        // Without the hook, no tuned rows appear.
        let plain_cfg = SweepConfig {
            auto_tune: false,
            ..cfg
        };
        let plain = sweep(&ds, Kernel::paper_rbf(), &svm_problem(), &plain_cfg, &machine);
        assert!(plain.iter().all(|r| !r.tuned));
    }

    /// The message-free count replica must agree with real traffic —
    /// rank by rank, not just on the max — for every algorithm and rank
    /// count, pof2 or not, big or tiny vectors.
    #[test]
    fn allreduce_counts_match_real_traffic_per_rank() {
        for algo in [
            AllreduceAlgo::Rabenseifner,
            AllreduceAlgo::RecursiveDoubling,
            AllreduceAlgo::Linear,
        ] {
            for p in [2usize, 3, 4, 5, 7, 8, 12, 13] {
                for w in [1usize, 3, 17, 64, 100] {
                    let stats = crate::comm::run_ranks(p, |c| {
                        let mut buf = vec![1.0; w];
                        crate::comm::allreduce_sum(c, &mut buf, algo);
                        c.stats()
                    });
                    let counts = allreduce_counts_per_rank(w, p, algo);
                    for (rank, (s, &(words, rounds))) in
                        stats.iter().zip(&counts).enumerate()
                    {
                        assert_eq!(s.words, words, "{algo:?} p={p} w={w} rank {rank} words");
                        assert_eq!(s.rounds, rounds, "{algo:?} p={p} w={w} rank {rank} rounds");
                    }
                    let max_words = stats.iter().map(|s| s.words).max().unwrap();
                    let max_rounds = stats.iter().map(|s| s.rounds).max().unwrap();
                    let (words, rounds) = allreduce_max_counts(w, p, algo);
                    assert_eq!(max_words, words, "{algo:?} p={p} w={w} words");
                    assert_eq!(max_rounds, rounds, "{algo:?} p={p} w={w} rounds");
                }
            }
        }
    }

    /// Composition regression: the run-long critical path is the max of
    /// per-rank *sums*, not the sum of per-allreduce maxima. At P = 13
    /// (pof2 = 8) with m = 21 (not divisible by 8), the rank maximizing
    /// the m-word norm allreduce differs from the rank maximizing the
    /// s·m-word gram allreduces (chunk rounding), so summing maxima
    /// overcounts by one word — the analytic ledger must still match
    /// measured traffic exactly.
    #[test]
    fn analytic_ledger_matches_measured_at_rounding_unaligned_widths() {
        let machine = MachineProfile::cray_ex();
        let ds = crate::data::gen_dense_classification(21, 16, 0.05, 14);
        for s in [1usize, 2] {
            let h = 8;
            let solver = SolverSpec {
                s,
                h,
                seed: 21,
                cache_rows: 0,
                threads: 1,
                grid: None,
                ..Default::default()
            };
            let measured = run_distributed(
                &ds,
                Kernel::paper_rbf(),
                &svm_problem(),
                &solver,
                13,
                AllreduceAlgo::Rabenseifner,
                &machine,
            )
            .critical;
            let analytic = analytic_ledger(
                &ds,
                Kernel::paper_rbf(),
                &svm_problem(),
                s,
                h,
                13,
                AllreduceAlgo::Rabenseifner,
                OverlapMode::Off,
            );
            assert_eq!(analytic.comm.words, measured.comm.words, "s={s} words");
            assert_eq!(analytic.comm.rounds, measured.comm.rounds, "s={s} rounds");
        }
    }

    #[test]
    fn rabenseifner_word_formula_matches_traffic_exactly() {
        // Pin the chunk-walking word count to the real collective,
        // including w not divisible by p (integer-rounding cases).
        for p in [2usize, 4, 8, 16] {
            for w in [16usize, 64, 100, 1000, 1001] {
                if w < p {
                    continue;
                }
                let stats = crate::comm::run_ranks(p, |c| {
                    let mut buf = vec![1.0; w];
                    crate::comm::allreduce_sum(c, &mut buf, AllreduceAlgo::Rabenseifner);
                    c.stats()
                });
                let max_words = stats.iter().map(|s| s.words).max().unwrap();
                let expect = rabenseifner_max_words(w, p);
                assert_eq!(max_words, expect, "p={p} w={w}");
                // And the ideal 2w(1−1/p) is within rounding slack.
                let ideal = 2.0 * w as f64 * (1.0 - 1.0 / p as f64);
                assert!((expect as f64 - ideal).abs() <= 2.0 * p as f64);
            }
        }
    }
}
