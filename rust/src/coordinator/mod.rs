//! L3 coordinator: the distributed experiment engine behind the CLI, the
//! examples, and the paper-figure benches.
//!
//! * [`config`] — experiment configuration (TOML-subset files + CLI
//!   overrides).
//! * [`experiment`] — single-run launcher: shard the dataset 1D-column,
//!   spin up `P` ranks ([`crate::comm::run_ranks`]), run a solver over a
//!   [`crate::solvers::DistGram`], collect per-rank ledgers, project onto
//!   a machine profile.
//! * [`scaling`] — the strong-scaling harness (Figures 3, 5, 6): sweeps
//!   `P` and `s` with two engines — `measured` (real ranks, real message
//!   traffic) and `projected` (count model for `P` beyond what one box
//!   can thread), cross-validated against each other in tests.
//! * [`breakdown`] — the runtime-breakdown harness (Figures 4, 7, 8).
//! * [`report`] — markdown / CSV table writers shared by benches.

#![forbid(unsafe_code)]

pub mod breakdown;
pub mod config;
pub mod experiment;
pub mod figures;
pub mod report;
pub mod scaling;

pub use config::Config;
pub use experiment::{run_distributed, run_serial, ProblemSpec, RunResult, SolverSpec};
