//! Single-experiment launcher: run one solver configuration, serially or
//! across `P` ranks, and collect the cost ledgers + machine projection.

use crate::comm::{run_ranks, AllreduceAlgo, Communicator, SelfComm};
use crate::costmodel::{Ledger, MachineProfile, Projection};
use crate::data::Dataset;
use crate::gram::{GridStorage, OverlapMode};
use crate::kernelfn::Kernel;
use crate::schedule::{build_schedule, packed_row_costs, Schedule, ScheduleSpec};
use crate::solvers::{
    bdcd_sstep_with_schedule, bdcd_with_schedule, dcd_sstep_with_schedule, dcd_with_schedule,
    DistGram, GramOracle, GridGram, KrrParams, LocalGram, SvmParams, SvmVariant,
    KRR_COORD_STREAM, SVM_COORD_STREAM,
};

use super::scaling::mem_words_per_rank;

/// Which optimization problem to solve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProblemSpec {
    /// K-SVM with hinge (`L1`) or squared-hinge (`L2`) loss.
    Svm {
        /// Box constraint `C`.
        c: f64,
        /// Hinge (`L1`) or squared-hinge (`L2`) loss.
        variant: SvmVariant,
    },
    /// K-RR with ridge penalty `λ` and block size `b`.
    Krr {
        /// Ridge penalty `λ`.
        lambda: f64,
        /// Coordinate-block size `b`.
        b: usize,
    },
}

impl ProblemSpec {
    /// PCG stream id of this problem's coordinate-selection sequence
    /// ([`SVM_COORD_STREAM`] / [`KRR_COORD_STREAM`]): the stream every
    /// [`crate::schedule::Schedule`] for this problem must draw from so
    /// analytic replicas replay the solvers bitwise.
    pub fn coord_stream(&self) -> u64 {
        match self {
            ProblemSpec::Svm { .. } => SVM_COORD_STREAM,
            ProblemSpec::Krr { .. } => KRR_COORD_STREAM,
        }
    }

    /// Coordinate-block size per schedule draw: `1` for DCD, the K-RR
    /// block size `b` for BDCD.
    pub fn block_size(&self) -> usize {
        match self {
            ProblemSpec::Svm { .. } => 1,
            ProblemSpec::Krr { b, .. } => *b,
        }
    }

    /// Report tag (`k-svm-l1`, `k-svm-l2`, `k-rr`).
    pub fn name(&self) -> &'static str {
        match self {
            ProblemSpec::Svm {
                variant: SvmVariant::L1,
                ..
            } => "k-svm-l1",
            ProblemSpec::Svm {
                variant: SvmVariant::L2,
                ..
            } => "k-svm-l2",
            ProblemSpec::Krr { .. } => "k-rr",
        }
    }
}

/// Classical (`s = 1`) or s-step solver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolverSpec {
    /// `1` = the classical method; `> 1` = the s-step variant.
    pub s: usize,
    /// Total inner iterations `H`.
    pub h: usize,
    /// Coordinate-stream seed (equal seeds ⇒ comparable runs).
    pub seed: u64,
    /// Kernel-row LRU cache capacity for the gram engine; `0` disables
    /// it (and reproduces the legacy cost accounting exactly). Must be
    /// identical on every rank — the launcher threads the same value to
    /// all of them. Results are bit-identical with the cache on or off.
    pub cache_rows: usize,
    /// Intra-rank worker threads for the gram product stage (`>= 1`;
    /// `1` = serial). Results are bitwise identical for every value —
    /// only wall time and the hybrid Hockney projection change (the
    /// kernel phase divides by `min(threads, cores_per_rank)`).
    pub threads: usize,
    /// `Some((pr, pc))` runs the 2D `pr × pc` grid layout
    /// ([`crate::solvers::GridGram`]) — `pr · pc` must equal the launch's
    /// rank count. The gram reduce then runs over a `pc`-rank
    /// subcommunicator with a `1/pr`-sized payload instead of all `P`
    /// ranks; results are bitwise identical to the 1D layout over `pc`
    /// ranks (see [`crate::gram`]). `None` is the paper's 1D layout.
    pub grid: Option<(usize, usize)>,
    /// Storage mode of the grid cells ([`GridStorage`]; ignored for the
    /// 1D layout): `Replicated` keeps the full `m × ≈n/pc` feature
    /// shard on every cell, `Sharded` keeps only the cell's block-cyclic
    /// row group and assembles sampled rows through the per-call
    /// fragment exchange. Must be identical on every rank (the exchange
    /// is a collective); results are bitwise identical either way.
    pub grid_storage: GridStorage,
    /// Block-cyclic row-block size of the grid layout (`>= 1`; ignored
    /// for 1D). A pure wall-time/traffic knob — results are bitwise
    /// identical for every value. Tunable via `--row-block` and the
    /// auto-tuner's candidate grid.
    pub row_block: usize,
    /// Communication-overlap mode ([`OverlapMode`]): `Off` runs every
    /// collective blocking; `Exchange` overlaps the sharded grid's
    /// fragment exchange with the partial product over locally owned
    /// rows; `Pipeline` posts outer block `k+1`'s gram reduce under
    /// block `k`'s α updates (s-step drivers only). A pure wall-time
    /// knob — inert where inapplicable, bitwise-identical results and
    /// identical wire traffic in every mode. Must be identical on every
    /// rank. Tunable via `--overlap` and the auto-tuner.
    pub overlap: OverlapMode,
    /// Coordinate schedule ([`ScheduleSpec`]): which seeded sampler the
    /// solver draws its coordinate stream through. Must be identical on
    /// every rank (the stream is replicated, exactly like the paper's
    /// shared-seed sampling). For a fixed spec, results are bitwise
    /// invariant to `threads`, `cache_rows`, `row_block`, `grid_storage`
    /// and `overlap`; the default [`crate::schedule::ScheduleKind::Uniform`]
    /// replays the legacy per-problem PCG stream bit for bit. Tunable via
    /// `--schedule` and the auto-tuner's candidate grid.
    pub schedule: ScheduleSpec,
}

impl Default for SolverSpec {
    fn default() -> Self {
        SolverSpec {
            s: 1,
            h: 256,
            seed: 0x5EED,
            cache_rows: 0,
            threads: 1,
            grid: None,
            grid_storage: GridStorage::Replicated,
            row_block: crate::gram::DEFAULT_ROW_BLOCK,
            overlap: OverlapMode::Off,
            schedule: ScheduleSpec::default(),
        }
    }
}

impl SolverSpec {
    /// The spec that runs an auto-tuner candidate (`crate::tune`):
    /// read-only plan → spec handoff — `s`, `threads` and the grid
    /// factorization come from the candidate, while `h`, `seed` and the
    /// cache stay the caller's run parameters. Launch it with
    /// `run_distributed(.., candidate.ranks(), ..)`.
    pub fn from_candidate(
        candidate: &crate::tune::Candidate,
        h: usize,
        seed: u64,
        cache_rows: usize,
    ) -> SolverSpec {
        SolverSpec {
            s: candidate.s,
            h,
            seed,
            cache_rows,
            threads: candidate.t,
            grid: candidate.grid(),
            grid_storage: candidate.storage,
            row_block: candidate.row_block,
            overlap: candidate.overlap,
            schedule: candidate.schedule,
        }
    }
}

/// Result of one run.
pub struct RunResult {
    /// Final dual solution (identical on every rank; rank 0's copy).
    pub alpha: Vec<f64>,
    /// Critical-path ledger (max over ranks).
    pub critical: Ledger,
    /// Per-rank ledgers (rank-indexed).
    pub per_rank: Vec<Ledger>,
    /// Hockney projection of the critical path.
    pub projection: Projection,
    /// Local wall-clock of the whole run (all ranks, this box).
    pub wall_secs: f64,
}

fn run_solver<O: crate::solvers::GramOracle>(
    oracle: &mut O,
    y: &[f64],
    problem: &ProblemSpec,
    solver: &SolverSpec,
    sched: &mut dyn Schedule,
    ledger: &mut Ledger,
) -> Vec<f64> {
    match *problem {
        ProblemSpec::Svm { c, variant } => {
            let p = SvmParams {
                c,
                variant,
                h: solver.h,
                seed: solver.seed,
            };
            if solver.s <= 1 {
                dcd_with_schedule(oracle, y, &p, sched, ledger, None)
            } else {
                dcd_sstep_with_schedule(oracle, y, &p, solver.s, sched, ledger, None)
            }
        }
        ProblemSpec::Krr { lambda, b } => {
            let p = KrrParams {
                lambda,
                b,
                h: solver.h,
                seed: solver.seed,
            };
            if solver.s <= 1 {
                bdcd_with_schedule(oracle, y, &p, sched, ledger, None)
            } else {
                bdcd_sstep_with_schedule(oracle, y, &p, solver.s, sched, ledger, None)
            }
        }
    }
}

/// Build the replicated coordinate schedule a run draws through: the
/// spec's sampler on the problem's coordinate stream, with packed-
/// fragment row costs from the *full* dataset (every rank computes the
/// identical costs from the replicated row structure, so the stream is
/// rank-invariant — and layout-invariant, since the costs never depend
/// on how the run shards columns).
fn build_run_schedule(
    ds: &Dataset,
    problem: &ProblemSpec,
    solver: &SolverSpec,
) -> Box<dyn Schedule> {
    let row_cost = packed_row_costs(&ds.a);
    build_schedule(
        &solver.schedule,
        ds.a.nrows(),
        solver.seed,
        problem.coord_stream(),
        &row_cost,
    )
}

/// Run on a single rank with a [`LocalGram`] oracle.
pub fn run_serial(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    solver: &SolverSpec,
    machine: &MachineProfile,
) -> RunResult {
    let t0 = std::time::Instant::now();
    let mut ledger = Ledger::new();
    let mut oracle =
        LocalGram::with_opts(ds.a.clone(), kernel, solver.cache_rows, solver.threads.max(1));
    let mut sched = build_run_schedule(ds, problem, solver);
    let alpha = run_solver(&mut oracle, &ds.y, problem, solver, sched.as_mut(), &mut ledger);
    ledger.mem_words = mem_words_per_rank(ds, problem, solver, 1);
    let mut comm = SelfComm::new();
    let _ = &mut comm;
    let wall = t0.elapsed().as_secs_f64();
    let critical = Ledger::critical_path(std::slice::from_ref(&ledger));
    let projection = machine.project_hybrid(&critical, solver.threads);
    RunResult {
        alpha,
        critical,
        per_rank: vec![ledger],
        projection,
        wall_secs: wall,
    }
}

/// Run across `p` ranks (threads) with [`DistGram`] oracles over
/// 1D-column shards — the paper's parallelization, with real message
/// traffic feeding the cost projection — or, when `solver.grid` is set,
/// with [`GridGram`] oracles over a 2D `pr × pc` process grid (the
/// column-subcomm reduce + row-subcomm allgather refinement).
pub fn run_distributed(
    ds: &Dataset,
    kernel: Kernel,
    problem: &ProblemSpec,
    solver: &SolverSpec,
    p: usize,
    algo: AllreduceAlgo,
    machine: &MachineProfile,
) -> RunResult {
    assert!(p >= 1);
    if let Some((pr, pc)) = solver.grid {
        assert_eq!(
            pr * pc,
            p,
            "grid {pr}x{pc} does not factor the launch's {p} ranks"
        );
    }
    if p == 1 {
        return run_serial(ds, kernel, problem, solver, machine);
    }
    let t0 = std::time::Instant::now();
    // Grid cells hold one of pc feature shards; 1D ranks one of p.
    let shards = match solver.grid {
        Some((_, pc)) => ds.shard_cols(pc),
        None => ds.shard_cols(p),
    };
    let outs: Vec<(Vec<f64>, Ledger)> = run_ranks(p, |comm| {
        let mut ledger = Ledger::new();
        // Every rank draws the identical replicated coordinate stream
        // (shared seed), exactly like the paper's MPI implementation.
        let mut sched = build_run_schedule(ds, problem, solver);
        let alpha = match solver.grid {
            Some((pr, pc)) => {
                let shard = shards[comm.rank() % pc].clone();
                let mut oracle = GridGram::with_opts(
                    shard,
                    kernel,
                    comm,
                    algo,
                    pr,
                    pc,
                    solver.row_block.max(1),
                    solver.grid_storage,
                    solver.cache_rows,
                    solver.threads.max(1),
                );
                oracle.set_overlap(solver.overlap);
                let alpha =
                    run_solver(&mut oracle, &ds.y, problem, solver, sched.as_mut(), &mut ledger);
                ledger.comm = oracle.comm_stats();
                ledger.comm_col = oracle.col_stats();
                ledger.comm_row = oracle.row_stats();
                ledger.comm_exch = oracle.exch_stats();
                alpha
            }
            None => {
                let shard = shards[comm.rank()].clone();
                let mut oracle = DistGram::with_opts(
                    shard,
                    kernel,
                    comm,
                    algo,
                    solver.cache_rows,
                    solver.threads.max(1),
                );
                oracle.set_overlap(solver.overlap);
                let alpha =
                    run_solver(&mut oracle, &ds.y, problem, solver, sched.as_mut(), &mut ledger);
                ledger.comm = oracle.comm_stats();
                alpha
            }
        };
        (alpha, ledger)
    });
    let wall = t0.elapsed().as_secs_f64();

    // Every rank must hold the same replicated solution.
    let alpha = outs[0].0.clone();
    for (a, _) in &outs[1..] {
        debug_assert_eq!(a.len(), alpha.len());
    }
    let per_rank: Vec<Ledger> = outs.into_iter().map(|(_, l)| l).collect();
    let mut critical = Ledger::critical_path(&per_rank);
    // Same model the analytic engines use — measured and projected rows
    // report identical memory (it is a static function of the config).
    critical.mem_words = mem_words_per_rank(ds, problem, solver, p);
    let projection = machine.project_hybrid(&critical, solver.threads);
    RunResult {
        alpha,
        critical,
        per_rank,
        projection,
        wall_secs: wall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Phase;
    use crate::data::paper_dataset;
    use crate::testkit;

    fn small_svm() -> (Dataset, ProblemSpec, SolverSpec) {
        let ds = crate::data::gen_dense_classification(32, 12, 0.05, 55);
        (
            ds,
            ProblemSpec::Svm {
                c: 1.0,
                variant: SvmVariant::L1,
            },
            SolverSpec {
                s: 8,
                h: 64,
                seed: 9,
                cache_rows: 0,
                threads: 1,
                grid: None,
                ..Default::default()
            },
        )
    }

    #[test]
    fn distributed_solution_matches_serial() {
        let (ds, problem, solver) = small_svm();
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        let serial = run_serial(&ds, kernel, &problem, &solver, &machine);
        for p in [2, 4, 7] {
            let dist = run_distributed(
                &ds,
                kernel,
                &problem,
                &solver,
                p,
                AllreduceAlgo::Rabenseifner,
                &machine,
            );
            testkit::assert_close(&dist.alpha, &serial.alpha, 1e-9, &format!("p={p}"));
        }
    }

    #[test]
    fn distributed_krr_matches_serial_and_classical() {
        let ds = crate::data::gen_dense_regression(24, 8, 0.1, 66);
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        let problem = ProblemSpec::Krr { lambda: 1.0, b: 3 };
        let classical = SolverSpec {
            s: 1,
            h: 40,
            seed: 4,
            ..Default::default()
        };
        let sstep = SolverSpec {
            s: 8,
            h: 40,
            seed: 4,
            ..Default::default()
        };
        let a_serial = run_serial(&ds, kernel, &problem, &classical, &machine).alpha;
        let a_dist = run_distributed(
            &ds,
            kernel,
            &problem,
            &sstep,
            3,
            AllreduceAlgo::RecursiveDoubling,
            &machine,
        )
        .alpha;
        testkit::assert_close(&a_dist, &a_serial, 1e-9, "dist s-step vs serial classical");
    }

    #[test]
    fn cached_runs_are_bit_identical_and_save_communication() {
        // The cache acceptance criterion end to end: same solver, same
        // seed, cache on vs off — α must match *bitwise*, and the cached
        // distributed run must measurably send fewer words.
        let (ds, problem, solver) = small_svm();
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        let cached_solver = SolverSpec {
            cache_rows: 16,
            ..solver
        };
        for p in [1usize, 4] {
            let plain = run_distributed(
                &ds,
                kernel,
                &problem,
                &solver,
                p,
                AllreduceAlgo::Rabenseifner,
                &machine,
            );
            let cached = run_distributed(
                &ds,
                kernel,
                &problem,
                &cached_solver,
                p,
                AllreduceAlgo::Rabenseifner,
                &machine,
            );
            assert_eq!(plain.alpha, cached.alpha, "p={p} bitwise equality");
            assert!(cached.critical.cache.hits > 0, "p={p} expected hits");
            if p > 1 {
                assert!(
                    cached.critical.comm.words < plain.critical.comm.words,
                    "p={p}: cached words {} !< uncached {}",
                    cached.critical.comm.words,
                    plain.critical.comm.words
                );
                assert!(cached.critical.cache.words_saved > 0);
            }
        }
    }

    /// Hybrid acceptance, end to end: threaded runs return bit-identical
    /// α (threads is a pure wall-time knob), the measured counts are
    /// unchanged, and the hybrid projection divides exactly the kernel
    /// phase by the thread count.
    #[test]
    fn threaded_runs_are_bitwise_identical_and_project_faster() {
        let (ds, problem, solver) = small_svm();
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        for p in [1usize, 3, 4] {
            let serial = run_distributed(
                &ds,
                kernel,
                &problem,
                &solver,
                p,
                AllreduceAlgo::Rabenseifner,
                &machine,
            );
            for threads in [2usize, 4] {
                let hybrid_solver = SolverSpec { threads, ..solver };
                let hybrid = run_distributed(
                    &ds,
                    kernel,
                    &problem,
                    &hybrid_solver,
                    p,
                    AllreduceAlgo::Rabenseifner,
                    &machine,
                );
                assert_eq!(serial.alpha, hybrid.alpha, "p={p} t={threads} bitwise");
                assert_eq!(
                    serial.critical.comm.words, hybrid.critical.comm.words,
                    "threads must not change traffic"
                );
                let k1 = serial.projection.phase_secs(Phase::KernelCompute);
                let kt = hybrid.projection.phase_secs(Phase::KernelCompute);
                assert!(
                    (kt - k1 / threads as f64).abs() <= 1e-12 * k1,
                    "p={p} t={threads}: kernel phase {kt} vs {k1}/{threads}"
                );
                assert!(hybrid.projection.total_secs() < serial.projection.total_secs());
            }
        }
    }

    #[test]
    fn sstep_reduces_projected_allreduce_latency() {
        // The paper's core claim, end to end: same H, same P, same data —
        // s-step must cut allreduce rounds by ~s and reduce projected time
        // in the latency-bound regime.
        let (ds, problem, _) = small_svm();
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        let classical = run_distributed(
            &ds,
            kernel,
            &problem,
            &SolverSpec {
                s: 1,
                h: 64,
                seed: 9,
                ..Default::default()
            },
            4,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        let sstep = run_distributed(
            &ds,
            kernel,
            &problem,
            &SolverSpec {
                s: 16,
                h: 64,
                seed: 9,
                ..Default::default()
            },
            4,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        let r1 = classical.critical.comm.rounds;
        let r2 = sstep.critical.comm.rounds;
        assert!(
            r2 * 8 <= r1,
            "s-step rounds {r2} should be ≪ classical {r1}"
        );
        let t1 = classical.projection.phase_secs(Phase::Allreduce);
        let t2 = sstep.projection.phase_secs(Phase::Allreduce);
        assert!(t2 < t1, "projected allreduce {t2} !< {t1}");
    }

    /// Overlap acceptance, end to end: every mode returns bit-identical
    /// α with identical wire traffic, the overlapping modes actually
    /// post communication (`comm_posted` non-zero where applicable), and
    /// the projection credits the overlap (never slower than blocking).
    #[test]
    fn overlapped_runs_are_bitwise_identical_and_project_no_slower() {
        let (ds, problem, solver) = small_svm();
        let machine = MachineProfile::cray_ex();
        let kernel = Kernel::paper_rbf();
        // Sharded 2×2 grid with a cache: exercises both the fragment
        // exchange and the s-step reduce pipeline.
        let base = SolverSpec {
            grid: Some((2, 2)),
            grid_storage: crate::gram::GridStorage::Sharded,
            cache_rows: 12,
            ..solver
        };
        let run = |overlap: OverlapMode| {
            run_distributed(
                &ds,
                kernel,
                &problem,
                &SolverSpec { overlap, ..base },
                4,
                AllreduceAlgo::Rabenseifner,
                &machine,
            )
        };
        let off = run(OverlapMode::Off);
        assert_eq!(off.critical.comm_posted.words, 0, "blocking posts nothing");
        for mode in [OverlapMode::Exchange, OverlapMode::Pipeline] {
            let over = run(mode);
            assert_eq!(off.alpha, over.alpha, "{mode:?} bitwise α");
            assert_eq!(
                off.critical.comm.words, over.critical.comm.words,
                "{mode:?} must not change traffic"
            );
            assert!(
                over.critical.comm_posted.words > 0,
                "{mode:?} must post communication"
            );
            assert!(
                over.projection.total_secs() <= off.projection.total_secs(),
                "{mode:?} projection must not be slower than blocking"
            );
        }
    }

    #[test]
    fn per_rank_ledgers_reflect_load_imbalance() {
        let ds = paper_dataset("news20").unwrap().generate_scaled(0.01);
        let machine = MachineProfile::cray_ex();
        let res = run_distributed(
            &ds,
            Kernel::paper_rbf(),
            &ProblemSpec::Svm {
                c: 1.0,
                variant: SvmVariant::L1,
            },
            &SolverSpec {
                s: 4,
                h: 8,
                seed: 3,
                ..Default::default()
            },
            4,
            AllreduceAlgo::Rabenseifner,
            &machine,
        );
        let flops: Vec<f64> = res
            .per_rank
            .iter()
            .map(|l| l.flops(Phase::KernelCompute))
            .collect();
        let max = flops.iter().fold(0.0f64, |a, &b| a.max(b));
        let min = flops.iter().fold(f64::MAX, |a, &b| a.min(b));
        assert!(
            max / min > 1.2,
            "power-law shards should be imbalanced: {flops:?}"
        );
        // Critical path takes the max.
        assert_eq!(res.critical.flops(Phase::KernelCompute), max);
    }
}
