//! Shared figure-regeneration helpers: convergence series and
//! iterations-to-tolerance, used by the CLI, the examples and the
//! `benches/fig*` harnesses.

use crate::costmodel::Ledger;
use crate::data::Dataset;
use crate::kernelfn::Kernel;
use crate::solvers::objective::SvmObjective;
use crate::solvers::{
    bdcd, bdcd_sstep, dcd, dcd_sstep, krr_exact, KrrParams, LocalGram, SvmParams, SvmVariant,
};

/// Duality-gap series for (s-step) DCD on K-SVM: `(iteration, gap)` every
/// `every` iterations. `s = 1` runs the classical method.
#[allow(clippy::too_many_arguments)]
pub fn svm_gap_series(
    ds: &Dataset,
    kernel: Kernel,
    variant: SvmVariant,
    c: f64,
    h: usize,
    s: usize,
    seed: u64,
    every: usize,
) -> Vec<(usize, f64)> {
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let obj = SvmObjective::new(&mut oracle, &ds.y, c, variant);
    let mut pts = Vec::new();
    let mut cb = |k: usize, a: &[f64]| {
        if k % every == 0 || k == h {
            pts.push((k, obj.duality_gap(a)));
        }
    };
    let params = SvmParams {
        c,
        variant,
        h,
        seed,
    };
    let mut o = LocalGram::new(ds.a.clone(), kernel);
    if s <= 1 {
        dcd(&mut o, &ds.y, &params, &mut Ledger::new(), Some(&mut cb));
    } else {
        dcd_sstep(&mut o, &ds.y, &params, s, &mut Ledger::new(), Some(&mut cb));
    }
    pts
}

/// Relative-solution-error series for (s-step) BDCD on K-RR, against the
/// closed-form `α*`.
#[allow(clippy::too_many_arguments)]
pub fn krr_relerr_series(
    ds: &Dataset,
    kernel: Kernel,
    lambda: f64,
    b: usize,
    h: usize,
    s: usize,
    seed: u64,
    every: usize,
) -> Vec<(usize, f64)> {
    let mut oracle = LocalGram::new(ds.a.clone(), kernel);
    let astar = krr_exact(&mut oracle, &ds.y, lambda);
    krr_relerr_series_vs(ds, kernel, lambda, b, h, s, seed, every, &astar)
}

/// Same, against a precomputed `α*` (lets callers amortize the exact
/// solve across several series).
#[allow(clippy::too_many_arguments)]
pub fn krr_relerr_series_vs(
    ds: &Dataset,
    kernel: Kernel,
    lambda: f64,
    b: usize,
    h: usize,
    s: usize,
    seed: u64,
    every: usize,
    astar: &[f64],
) -> Vec<(usize, f64)> {
    let mut pts = Vec::new();
    let mut cb = |k: usize, a: &[f64]| {
        if k % every == 0 || k == h {
            pts.push((k, crate::dense::rel_err(a, astar)));
        }
    };
    let params = KrrParams {
        lambda,
        b,
        h,
        seed,
    };
    let mut o = LocalGram::new(ds.a.clone(), kernel);
    if s <= 1 {
        bdcd(&mut o, &ds.y, &params, &mut Ledger::new(), Some(&mut cb));
    } else {
        bdcd_sstep(&mut o, &ds.y, &params, s, &mut Ledger::new(), Some(&mut cb));
    }
    pts
}

/// First iteration at which a series crosses below `tol` (None if never).
pub fn iters_to_tol(series: &[(usize, f64)], tol: f64) -> Option<usize> {
    series.iter().find(|(_, v)| *v <= tol).map(|(k, _)| *k)
}

/// Max absolute deviation between two series sampled at the same
/// iterations — the "s-step overlays classical" check of Figures 1–2.
pub fn max_series_deviation(a: &[(usize, f64)], b: &[(usize, f64)]) -> f64 {
    assert_eq!(a.len(), b.len(), "series sampled differently");
    a.iter()
        .zip(b)
        .map(|((ka, va), (kb, vb))| {
            assert_eq!(ka, kb);
            (va - vb).abs()
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gap_series_overlays_for_sstep() {
        let ds = crate::data::gen_dense_classification(30, 8, 0.05, 7);
        let a = svm_gap_series(&ds, Kernel::paper_rbf(), SvmVariant::L1, 1.0, 96, 1, 3, 16);
        let b = svm_gap_series(&ds, Kernel::paper_rbf(), SvmVariant::L1, 1.0, 96, 16, 3, 16);
        assert!(a.len() >= 6);
        assert!(max_series_deviation(&a, &b) < 1e-8);
        // Gap decreases overall.
        assert!(a.last().unwrap().1 < a.first().unwrap().1);
    }

    #[test]
    fn relerr_series_overlays_and_converges() {
        let ds = crate::data::gen_dense_regression(40, 6, 0.1, 8);
        let a = krr_relerr_series(&ds, Kernel::paper_rbf(), 1.0, 8, 400, 1, 5, 50);
        let b = krr_relerr_series(&ds, Kernel::paper_rbf(), 1.0, 8, 400, 16, 5, 50);
        assert!(max_series_deviation(&a, &b) < 1e-8);
        assert!(a.last().unwrap().1 < 1e-4, "relerr {:?}", a.last());
        assert_eq!(iters_to_tol(&a, 1.0), Some(50));
    }
}
