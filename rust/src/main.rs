//! `kcd` — the L3 coordinator binary.
//!
//! See `kcd help` (or [`kcd::cli::USAGE`]) for the command reference.

#![forbid(unsafe_code)]

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match kcd::cli::run(argv) {
        Ok(out) => print!("{out}"),
        Err(err) => {
            eprintln!("error: {err:#}");
            std::process::exit(1);
        }
    }
}
