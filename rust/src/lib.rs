//! # kcd — Scalable Dual Coordinate Descent for Kernel Methods
//!
//! A Rust + JAX + Pallas reproduction of *"Scalable Dual Coordinate Descent
//! for Kernel Methods"* (Shao & Devarakonda, 2024): s-step (communication-
//! avoiding) variants of Dual Coordinate Descent for kernel SVM and Block
//! Dual Coordinate Descent for kernel ridge regression.
//!
//! The crate is organized bottom-up:
//!
//! * [`rng`] — reproducible PCG random streams shared across ranks.
//! * [`dense`] / [`sparse`] — the BLAS/SparseBLAS substrate (the paper used
//!   Intel MKL; we build the needed subset from scratch).
//! * [`kernelfn`] — linear / polynomial / RBF kernel maps over gram blocks.
//! * [`gram`] — the staged, cached gram engine: layout → linear product →
//!   reduction → epilogue, with a deterministic kernel-row LRU cache in
//!   front. Every gram oracle is a thin configuration of this engine.
//!   Layouts: full matrix, the paper's 1D column shard, and the 2D
//!   `pr × pc` process grid whose reduce runs over a `pc`-rank
//!   subcommunicator (see `docs/ARCHITECTURE.md`).
//! * [`parallel`] — intra-rank threading: a deterministic scoped-thread
//!   pool and the `ParallelProduct` adapter that splits sampled rows of
//!   any product stage across worker threads (bitwise-invariant in the
//!   thread count; composes with `DistGram`/`GridGram` for hybrid P×t
//!   scaling).
//! * [`comm`] — a simulated-MPI communicator (threads + channels) with
//!   allreduce algorithms, `MPI_Comm_split`-style subcommunicators, and
//!   traffic instrumentation.
//! * [`costmodel`] — Hockney γF+βW+φL machine model used to project
//!   measured per-rank counts onto a Cray-EX-like machine profile.
//! * [`data`] — LIBSVM-format I/O plus synthetic dataset generators that
//!   mirror the paper's benchmark datasets (Tables 2–3).
//! * [`solvers`] — Algorithms 1–4 of the paper (DCD, s-step DCD, BDCD,
//!   s-step BDCD) in serial and distributed form, the closed-form K-RR
//!   solver, and the convergence metrics (duality gap, relative error).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   gram-block artifacts (`artifacts/*.hlo.txt`).
//! * [`schedule`] — pluggable, seeded coordinate schedules (uniform /
//!   shuffled epochs / locality-aware) the solvers draw through; the
//!   locality-aware schedule packs blocks to maximize cache re-hits and
//!   minimize fragment-exchange words, bitwise-deterministically.
//! * [`model`] — trained-model API: prediction, evaluation, JSON and
//!   binary `.kcd` persistence.
//! * [`serve`] — model serving: the versioned `.kcd` format
//!   (support-vector-compacted K-SVM saves, extraction from sharded
//!   grid cells) and batched prediction routed through the gram engine
//!   (`ProductStage` + `ParallelProduct` + the kernel-row cache), with
//!   predictions bitwise identical to the naive reference and invariant
//!   to threads, cache, and batch split.
//! * [`coordinator`] — experiment configs, the launcher, phase timers, and
//!   the strong-scaling / runtime-breakdown harnesses behind the CLI and
//!   the paper-figure benches.
//! * [`tune`] — the cost-model auto-tuner: enumerates `(pr, pc, t, s)`
//!   for a machine profile, scores candidates with the analytic count
//!   replicas, ranks them by predicted latency/bandwidth/compute, and
//!   cross-validates predictions against measured traffic.
//! * [`bench_harness`] — a small criterion-like measurement harness.
//! * [`testkit`] — a property-testing mini-framework used by the test
//!   suites (proptest is unavailable in the offline build).

#![warn(missing_docs)]

pub mod bench_harness;
pub mod cli;
pub mod comm;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod dense;
pub mod gram;
pub mod kernelfn;
pub mod model;
pub mod parallel;
pub mod rng;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod solvers;
pub mod sparse;
pub mod testkit;
pub mod tune;
pub mod util;
