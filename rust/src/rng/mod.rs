//! Reproducible pseudo-random number generation.
//!
//! The distributed solvers require every rank to draw the *same* coordinate
//! sequence without communicating (the paper samples coordinates uniformly
//! at random on all ranks; in the C+MPI implementation this is done with a
//! shared seed). We implement PCG-XSH-RR 64/32 (O'Neill 2014) from scratch:
//! it is small, fast, statistically solid for this use, and — critically —
//! fully deterministic across platforms, which the equivalence tests
//! (s-step ≡ classical) rely on.

#![forbid(unsafe_code)]

/// PCG-XSH-RR 64/32 pseudo-random generator.
///
/// Deterministic, seedable, and cheap to fork into independent streams
/// (each stream selects a distinct odd increment).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id.
    ///
    /// Generators with the same seed but different streams produce
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Fork an independent child stream; deterministic in `(self, tag)`.
    pub fn fork(&mut self, tag: u64) -> Pcg {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg::new(seed, tag.wrapping_add(1))
    }

    /// Next 32 uniformly random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire-style rejection
    /// (unbiased).
    pub fn gen_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_below(0)");
        let bound = bound as u64;
        // Rejection threshold for unbiased sampling.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.gen_below(hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic — throughput is irrelevant for data generation).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::EPSILON {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Sample `k` distinct indices from `[0, m)` uniformly without
    /// replacement (Floyd's algorithm; O(k) expected, order then shuffled).
    pub fn sample_without_replacement(&mut self, m: usize, k: usize) -> Vec<usize> {
        assert!(k <= m, "cannot sample {k} from {m} without replacement");
        // Full-range fast path: Floyd degenerates to selecting every
        // index, so the set-dedup pass only burns RNG draws producing an
        // order the final shuffle immediately redoes — one Fisher–Yates
        // pass over the identity is the same uniform permutation at half
        // the draws. Guarded to m > 1 because a 1-element sample must
        // still consume exactly one draw (`gen_below(1)`), the identity
        // the Uniform schedule's `b = 1` stream replay depends on.
        if k == m && m > 1 {
            let mut chosen: Vec<usize> = (0..m).collect();
            self.shuffle(&mut chosen);
            return chosen;
        }
        // Floyd's algorithm produces a set; we collect then Fisher–Yates
        // shuffle so block order is also uniform (matters for BDCD blocks).
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        let mut set = std::collections::HashSet::with_capacity(k * 2);
        for j in (m - k)..m {
            let t = self.gen_below(j + 1);
            if set.insert(t) {
                chosen.push(t);
            } else {
                set.insert(j);
                chosen.push(j);
            }
        }
        self.shuffle(&mut chosen);
        chosen
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42, 7);
        let mut b = Pcg::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5, "streams should be independent, {same} collisions");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_close_to_half() {
        let mut r = Pcg::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_below_bounds_and_coverage() {
        let mut r = Pcg::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn sample_without_replacement_is_distinct_and_in_range() {
        let mut r = Pcg::seeded(11);
        for _ in 0..100 {
            let m = r.gen_range(1, 200);
            let k = r.gen_range(0, m) + 1;
            let s = r.sample_without_replacement(m, k.min(m));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len(), "duplicates in sample");
            assert!(s.iter().all(|&i| i < m));
        }
    }

    #[test]
    fn sample_full_range_is_permutation() {
        let mut r = Pcg::seeded(13);
        let mut s = r.sample_without_replacement(50, 50);
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    /// The `k == m` fast path is exactly one Fisher–Yates pass over the
    /// identity: same output and same post-call RNG state as calling
    /// `shuffle` directly — no Floyd draws are burnt first.
    #[test]
    fn sample_full_range_is_single_fisher_yates_pass() {
        for m in [2usize, 3, 7, 50] {
            let mut a = Pcg::new(13, 5);
            let mut b = Pcg::new(13, 5);
            let got = a.sample_without_replacement(m, m);
            let mut expect: Vec<usize> = (0..m).collect();
            b.shuffle(&mut expect);
            assert_eq!(got, expect, "m={m}");
            assert_eq!(a.next_u64(), b.next_u64(), "m={m} post-call state");
        }
    }

    /// A 1-element sample still consumes exactly one `gen_below(m)` draw
    /// (the fast path is guarded to `m > 1`): the identity the Uniform
    /// schedule's `b = 1` replay of the DCD coordinate stream relies on.
    #[test]
    fn sample_single_consumes_exactly_one_draw() {
        for m in [1usize, 2, 9] {
            let mut a = Pcg::new(29, 3);
            let mut b = Pcg::new(29, 3);
            let got = a.sample_without_replacement(m, 1);
            assert_eq!(got, vec![b.gen_below(m)], "m={m}");
            assert_eq!(a.next_u64(), b.next_u64(), "m={m} post-call state");
        }
    }

    /// Partial-range (`k < m`) streams are bitwise-unchanged by the
    /// full-range fast path: pinned against a verbatim copy of the
    /// pre-fast-path implementation, output and post-call state both.
    #[test]
    fn sample_partial_range_stream_is_unchanged() {
        fn legacy(rng: &mut Pcg, m: usize, k: usize) -> Vec<usize> {
            let mut chosen: Vec<usize> = Vec::with_capacity(k);
            let mut set = std::collections::HashSet::with_capacity(k * 2);
            for j in (m - k)..m {
                let t = rng.gen_below(j + 1);
                if set.insert(t) {
                    chosen.push(t);
                } else {
                    set.insert(j);
                    chosen.push(j);
                }
            }
            rng.shuffle(&mut chosen);
            chosen
        }
        for (m, k) in [(10usize, 3usize), (10, 9), (50, 25), (3, 2)] {
            let mut a = Pcg::new(41, 9);
            let mut b = Pcg::new(41, 9);
            assert_eq!(
                a.sample_without_replacement(m, k),
                legacy(&mut b, m, k),
                "m={m} k={k}"
            );
            assert_eq!(a.next_u64(), b.next_u64(), "m={m} k={k} post-call state");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg::seeded(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Pcg::seeded(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg::seeded(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }
}
