//! Layout stage: where this engine's slice of the data matrix lives.

/// Default block size of the grid layout's block-cyclic row
/// distribution: blocks of this many consecutive samples are dealt to
/// the `pr` row groups round-robin. Cyclic dealing spreads nnz-heavy
/// rows across groups (load balance); blocking keeps some row locality
/// in the product. Like `threads`, the block size is a pure wall-time
/// knob — element bits never depend on which group owns a row (see the
/// determinism contract in [`crate::gram`]).
pub const DEFAULT_ROW_BLOCK: usize = 4;

/// How a grid cell stores its slice of the data matrix
/// ([`Layout::Grid`] only; the 1D layouts always replicate nothing).
///
/// * [`GridStorage::Replicated`] — the cell keeps the *full* feature
///   shard (`m × ≈n/pc`): the sampled rows of every gram call are read
///   locally, and `pr` splits only compute. Per-rank memory does not
///   shrink with `pr`.
/// * [`GridStorage::Sharded`] — the cell keeps **only its block-cyclic
///   row group of the shard** (`≈m/pr × ≈n/pc`), the true 2D data
///   partition. A pre-product *fragment exchange* over the row
///   subcommunicator assembles the sampled rows each gram call (see
///   `GridReduce::exchange`), after which the product — and therefore
///   every solver bit — is identical to the replicated path.
///
/// Storage is a pure memory/traffic knob: like `threads`, `row_block`
/// and `pr`, it never changes a bit of arithmetic (the exchanged
/// fragments are verbatim copies of the stored rows). It must be
/// identical on every rank — the exchange is a collective.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GridStorage {
    /// Full `m × ≈n/pc` feature shard on every cell (the PR 3 layout).
    #[default]
    Replicated,
    /// Only the cell's `≈m/pr × ≈n/pc` row group; sampled rows are
    /// assembled by the per-call fragment exchange.
    Sharded,
}

impl GridStorage {
    /// Canonical CLI/report name (`replicated`, `sharded`).
    pub fn name(&self) -> &'static str {
        match self {
            GridStorage::Replicated => "replicated",
            GridStorage::Sharded => "sharded",
        }
    }

    /// Parse a [`Self::name`]-style string (plus the `rep`/`shard`
    /// shorthands); `None` for unknown names.
    pub fn parse(s: &str) -> Option<GridStorage> {
        match s {
            "replicated" | "rep" => Some(GridStorage::Replicated),
            "sharded" | "shard" => Some(GridStorage::Sharded),
            _ => None,
        }
    }
}

/// Communication/compute overlap mode of a distributed gram engine.
///
/// * [`OverlapMode::Off`] — every stage is a blocking barrier (the
///   pre-overlap engine): the measured critical path is comm + compute.
/// * [`OverlapMode::Exchange`] — the sharded grid's fragment exchange is
///   *posted* nonblocking and the product is split into an owned-rows
///   pass (the sampled rows this cell's row group stores, computable
///   under the in-flight exchange) and a remote-rows pass after `wait`.
///   Inert unless the layout actually has an exchange (sharded grid
///   with `pr > 1`).
/// * [`OverlapMode::Pipeline`] — the s-step solvers post gram call
///   k+1's reduce collective before running block k's local α/residual
///   updates, so the reduce rides under the inner loop. Inert for
///   serial oracles and for `s = 1` solvers (there is no inner loop to
///   hide under).
///
/// Like `threads`, `row_block` and `GridStorage`, overlap is a pure
/// wall-time knob: a posted collective replays the blocking algorithm's
/// exact per-rank schedule ([`crate::comm::CollectiveHandle`]), and the
/// split product passes compute each output row with identical
/// arithmetic — so every solver bit and every `CommStats` counter is
/// unchanged. It must be identical on every rank (post order is part of
/// the collective schedule).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverlapMode {
    /// Blocking stages everywhere (the baseline critical path).
    #[default]
    Off,
    /// Overlap the sharded fragment exchange with the owned-rows product
    /// pass.
    Exchange,
    /// Post gram call k+1's reduce under block k's s-step inner updates.
    Pipeline,
}

impl OverlapMode {
    /// Canonical CLI/report name (`off`, `exchange`, `pipeline`).
    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Off => "off",
            OverlapMode::Exchange => "exchange",
            OverlapMode::Pipeline => "pipeline",
        }
    }

    /// Parse a [`Self::name`]-style string (plus the `exch`/`pipe`
    /// shorthands); `None` for unknown names.
    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "off" => Some(OverlapMode::Off),
            "exchange" | "exch" => Some(OverlapMode::Exchange),
            "pipeline" | "pipe" => Some(OverlapMode::Pipeline),
            _ => None,
        }
    }

    /// All modes, in report order — the tuner's enumeration axis.
    pub fn all() -> [OverlapMode; 3] {
        [OverlapMode::Off, OverlapMode::Exchange, OverlapMode::Pipeline]
    }
}

/// Data layout behind a gram engine. Purely descriptive — the product
/// stage already operates on whatever slice it was built from — but
/// carried explicitly so reports, assertions and the 2D grid pipeline
/// have one source of truth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// The full `m×n` matrix on one rank (serial reference, Nyström,
    /// PJRT).
    Full,
    /// This rank's 1D-column shard: `m × ≈n/P` features of every sample
    /// (the paper's partitioning). The linear gram is additive over
    /// shards, which is what makes the allreduce reduction correct.
    ColShard {
        /// This rank's id in `[0, ranks)`.
        rank: usize,
        /// Total ranks `P`.
        ranks: usize,
    },
    /// One cell of a `pr × pc` process grid (`P = pr·pc` ranks): the
    /// standard communication-avoiding refinement of the 1D layout.
    ///
    /// Cell `(row, col)` holds feature shard `col` (of `pc` 1D-column
    /// shards) and computes partial gram entries only for the sample
    /// columns its row group owns under a block-cyclic distribution
    /// ([`block_cyclic_rows`]). The sum over feature shards runs over the
    /// *column subcommunicator* (the `pc` cells of grid row `row`), and
    /// the owned slices are then reassembled by an allgather over the
    /// *row subcommunicator* (the `pr` cells of grid column `col`) — so
    /// the reduce collective has `pc ≪ P` participants with a
    /// `1/pr`-sized payload, instead of all `P` ranks moving the full
    /// block.
    Grid {
        /// Row-group count `pr` (the allgather subcommunicator size).
        pr: usize,
        /// Feature-shard count `pc` (the reduce subcommunicator size).
        pc: usize,
        /// This cell's row-group index in `[0, pr)`.
        row: usize,
        /// This cell's feature-shard index in `[0, pc)`.
        col: usize,
    },
}

impl Layout {
    /// The grid cell of global rank `rank` in a `pr × pc` process grid:
    /// row-major, so rank `r` is cell `(r / pc, r % pc)`. One source of
    /// truth for the rank → cell map, shared by the grid oracle and the
    /// auto-tuner's read-only plan handoff (`crate::tune`).
    pub fn grid_for_rank(pr: usize, pc: usize, rank: usize) -> Layout {
        assert!(pr >= 1 && pc >= 1, "grid dimensions must be positive");
        assert!(rank < pr * pc, "rank {rank} outside the {pr}x{pc} grid");
        Layout::Grid {
            pr,
            pc,
            row: rank / pc,
            col: rank % pc,
        }
    }

    /// True if the product stage emits *partial* blocks that require a
    /// cross-rank reduction.
    pub fn is_sharded(&self) -> bool {
        matches!(self, Layout::ColShard { .. } | Layout::Grid { .. })
    }

    /// Short report tag (`full`, `col-shard`, `grid`).
    pub fn name(&self) -> &'static str {
        match self {
            Layout::Full => "full",
            Layout::ColShard { .. } => "col-shard",
            Layout::Grid { .. } => "grid",
        }
    }
}

/// Global sample indices owned by row group `group` of `groups` under a
/// block-cyclic distribution of `m` rows with blocks of `block`
/// consecutive rows: row `t` belongs to group `(t / block) mod groups`.
/// Ascending (the grid reduce relies on the order to reassemble slices
/// bitwise-deterministically).
pub fn block_cyclic_rows(m: usize, groups: usize, group: usize, block: usize) -> Vec<usize> {
    assert!(groups >= 1, "need at least one row group");
    assert!(group < groups, "group index out of range");
    assert!(block >= 1, "block size must be at least 1");
    (0..m).filter(|&t| (t / block) % groups == group).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_storage_parse_roundtrip_and_default() {
        for s in [GridStorage::Replicated, GridStorage::Sharded] {
            assert_eq!(GridStorage::parse(s.name()), Some(s));
        }
        assert_eq!(GridStorage::parse("shard"), Some(GridStorage::Sharded));
        assert_eq!(GridStorage::parse("rep"), Some(GridStorage::Replicated));
        assert_eq!(GridStorage::parse("nope"), None);
        assert_eq!(GridStorage::default(), GridStorage::Replicated);
    }

    #[test]
    fn overlap_mode_parse_roundtrip_and_default() {
        for o in OverlapMode::all() {
            assert_eq!(OverlapMode::parse(o.name()), Some(o));
        }
        assert_eq!(OverlapMode::parse("exch"), Some(OverlapMode::Exchange));
        assert_eq!(OverlapMode::parse("pipe"), Some(OverlapMode::Pipeline));
        assert_eq!(OverlapMode::parse("nope"), None);
        assert_eq!(OverlapMode::default(), OverlapMode::Off);
    }

    #[test]
    fn shard_predicate() {
        assert!(!Layout::Full.is_sharded());
        assert!(Layout::ColShard { rank: 0, ranks: 4 }.is_sharded());
        assert!(Layout::Grid {
            pr: 2,
            pc: 2,
            row: 0,
            col: 1
        }
        .is_sharded());
        assert_eq!(Layout::Full.name(), "full");
        assert_eq!(
            Layout::Grid {
                pr: 2,
                pc: 3,
                row: 1,
                col: 2
            }
            .name(),
            "grid"
        );
    }

    #[test]
    fn grid_for_rank_is_row_major_and_total() {
        for (pr, pc) in [(1usize, 1usize), (2, 3), (3, 2), (4, 1), (1, 4)] {
            let mut seen = vec![false; pr * pc];
            for rank in 0..pr * pc {
                match Layout::grid_for_rank(pr, pc, rank) {
                    Layout::Grid {
                        pr: gpr,
                        pc: gpc,
                        row,
                        col,
                    } => {
                        assert_eq!((gpr, gpc), (pr, pc));
                        assert!(!seen[row * pc + col], "cell ({row},{col}) mapped twice");
                        seen[row * pc + col] = true;
                        assert_eq!(rank, row * pc + col, "row-major inverse");
                    }
                    other => panic!("expected a grid cell, got {other:?}"),
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn grid_for_rank_rejects_out_of_range_ranks() {
        let _ = Layout::grid_for_rank(2, 2, 4);
    }

    #[test]
    fn block_cyclic_partitions_all_rows_exactly_once() {
        for m in [0usize, 1, 7, 24, 25] {
            for groups in [1usize, 2, 3, 5] {
                for block in [1usize, 2, 4] {
                    let mut seen = vec![false; m];
                    for g in 0..groups {
                        for &t in &block_cyclic_rows(m, groups, g, block) {
                            assert!(!seen[t], "row {t} owned twice");
                            seen[t] = true;
                        }
                    }
                    assert!(seen.iter().all(|&s| s), "m={m} groups={groups} block={block}");
                }
            }
        }
    }

    #[test]
    fn block_cyclic_deals_blocks_round_robin() {
        // m=8, 2 groups, block 2: blocks 0,2 → group 0; blocks 1,3 → 1.
        assert_eq!(block_cyclic_rows(8, 2, 0, 2), vec![0, 1, 4, 5]);
        assert_eq!(block_cyclic_rows(8, 2, 1, 2), vec![2, 3, 6, 7]);
        // Pure cyclic with block 1.
        assert_eq!(block_cyclic_rows(5, 3, 0, 1), vec![0, 3]);
        assert_eq!(block_cyclic_rows(5, 3, 2, 1), vec![2]);
        // More groups than blocks: trailing groups own nothing.
        assert_eq!(block_cyclic_rows(4, 4, 3, 2), Vec::<usize>::new());
    }
}
