//! Layout stage: where this engine's slice of the data matrix lives.

/// Data layout behind a gram engine. Purely descriptive — the product
/// stage already operates on whatever slice it was built from — but
/// carried explicitly so reports, assertions and future 2D layouts have
/// one source of truth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// The full `m×n` matrix on one rank (serial reference, Nyström,
    /// PJRT).
    Full,
    /// This rank's 1D-column shard: `m × ≈n/P` features of every sample
    /// (the paper's partitioning). The linear gram is additive over
    /// shards, which is what makes the allreduce reduction correct.
    ColShard {
        /// This rank's id in `[0, ranks)`.
        rank: usize,
        /// Total ranks `P`.
        ranks: usize,
    },
}

impl Layout {
    /// True if the product stage emits *partial* blocks that require a
    /// cross-rank reduction.
    pub fn is_sharded(&self) -> bool {
        matches!(self, Layout::ColShard { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Layout::Full => "full",
            Layout::ColShard { .. } => "col-shard",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_predicate() {
        assert!(!Layout::Full.is_sharded());
        assert!(Layout::ColShard { rank: 0, ranks: 4 }.is_sharded());
        assert_eq!(Layout::Full.name(), "full");
    }
}
