//! The staged gram engine: cache → product → reduce → epilogue.

use std::collections::HashMap;

use crate::comm::CommStats;
use crate::costmodel::{Ledger, Phase};
use crate::dense::Mat;
use crate::kernelfn::Kernel;

use super::cache::RowCache;
use super::epilogue::Epilogue;
use super::layout::Layout;
use super::product::{BlockKind, ProductStage};
use super::reduce::ReduceStage;

/// Where a sampled position is served from in a cached call.
enum Src {
    /// Present in the cache before this call.
    Hit,
    /// Computed this call; the payload is the index into the miss block.
    Miss(usize),
}

/// One gram pipeline: a product backend, a reduction, an optional
/// nonlinear epilogue, and an optional kernel-row LRU cache in front.
/// Every oracle in the crate is a thin configuration of this struct.
pub struct GramEngine<P: ProductStage, R: ReduceStage> {
    layout: Layout,
    product: P,
    reduce: R,
    epilogue: Option<Epilogue>,
    /// `K(a_i, a_i)` for all `i` (precomputed by the configuration).
    diag: Vec<f64>,
    m: usize,
    cache: Option<RowCache>,
    /// Miss-block buffer, reused across calls.
    scratch: Mat,
    miss_rows: Vec<usize>,
    miss_pos: HashMap<usize, usize>,
    srcs: Vec<Src>,
}

impl<P: ProductStage, R: ReduceStage> GramEngine<P, R> {
    /// Assemble a pipeline. `epilogue` must be `Some` exactly when the
    /// product emits linear inner products; `cache_rows == 0` disables
    /// the row cache (the accounting then matches the pre-engine oracles
    /// count for count).
    pub fn new(
        layout: Layout,
        product: P,
        reduce: R,
        epilogue: Option<Epilogue>,
        diag: Vec<f64>,
        cache_rows: usize,
    ) -> GramEngine<P, R> {
        let m = product.m();
        assert_eq!(diag.len(), m, "diag length");
        assert_eq!(
            matches!(product.kind(), BlockKind::Linear),
            epilogue.is_some(),
            "Linear products need an epilogue; Kernel products must not have one"
        );
        GramEngine {
            layout,
            product,
            reduce,
            epilogue,
            diag,
            m,
            cache: (cache_rows > 0).then(|| RowCache::new(cache_rows)),
            scratch: Mat::zeros(0, 0),
            miss_rows: Vec::new(),
            miss_pos: HashMap::new(),
            srcs: Vec::new(),
        }
    }

    /// Kernel-matrix dimension `m`.
    pub fn m(&self) -> usize {
        self.m
    }

    /// The layout this pipeline was configured for.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The configured kernel (None for finished-kernel products, whose
    /// map lives inside the product).
    pub fn kernel(&self) -> Option<Kernel> {
        self.epilogue.as_ref().map(|e| e.kernel())
    }

    /// `K(a_i, a_i)` for all `i`.
    pub fn diag(&self) -> Vec<f64> {
        self.diag.clone()
    }

    /// Row-cache capacity (0 = cache off).
    pub fn cache_capacity(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.capacity())
    }

    /// Traffic accumulated by the reduction stage.
    pub fn comm_stats(&self) -> CommStats {
        self.reduce.stats()
    }

    /// The product stage.
    pub fn product(&self) -> &P {
        &self.product
    }

    /// The reduction stage.
    pub fn reduce_stage(&self) -> &R {
        &self.reduce
    }

    /// Mutable access to the reduction stage (construction-time
    /// collectives).
    pub fn reduce_stage_mut(&mut self) -> &mut R {
        &mut self.reduce
    }

    /// Fill `q[r][·]` with kernel row `sample[r]`, recording costs.
    pub fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.m);
        if self.cache.is_none() {
            self.compute_block(sample, q, ledger);
            return;
        }

        // 1. Classify positions. Deterministic: pure function of the
        //    sample stream and prior cache state (see module docs).
        self.miss_rows.clear();
        self.miss_pos.clear();
        self.srcs.clear();
        let cache = self.cache.as_mut().expect("checked above");
        for &sr in sample {
            if let Some(&i) = self.miss_pos.get(&sr) {
                // Duplicate of a row already missed in this call.
                self.srcs.push(Src::Miss(i));
            } else if cache.contains_and_touch(sr) {
                self.srcs.push(Src::Hit);
            } else {
                let i = self.miss_rows.len();
                self.miss_pos.insert(sr, i);
                self.miss_rows.push(sr);
                self.srcs.push(Src::Miss(i));
            }
        }
        let served = (sample.len() - self.miss_rows.len()) as u64;
        ledger.cache.hits += served;
        ledger.cache.misses += self.miss_rows.len() as u64;
        if self.reduce.is_active() {
            // Each served row skips the reduction of one m-word kernel
            // row (the 1D allreduce payload; the grid layout splits the
            // same row across its reduce + allgather collectives).
            ledger.cache.words_saved += served * self.m as u64;
        }

        // 2. Serve hits out of the cache (before any insert can evict
        //    them).
        if served > 0 {
            ledger.time(Phase::CacheHit, || {
                for (pos, src) in self.srcs.iter().enumerate() {
                    if matches!(src, Src::Hit) {
                        let row = cache.peek(sample[pos]).expect("hit row present");
                        q.row_mut(pos).copy_from_slice(row);
                    }
                }
            });
        }

        // 3. Compute the deduplicated miss block through the pipeline.
        if self.miss_rows.is_empty() {
            if self.reduce.is_active() {
                ledger.cache.allreduces_saved += 1;
            }
            return;
        }
        let miss = std::mem::take(&mut self.miss_rows);
        let mut scratch = std::mem::replace(&mut self.scratch, Mat::zeros(0, 0));
        if scratch.nrows() != miss.len() || scratch.ncols() != self.m {
            scratch = Mat::zeros(miss.len(), self.m);
        }
        self.compute_block(&miss, &mut scratch, ledger);

        // 4. Fill missed positions (duplicates included) from the block.
        for (pos, src) in self.srcs.iter().enumerate() {
            if let Src::Miss(i) = src {
                q.row_mut(pos).copy_from_slice(scratch.row(*i));
            }
        }

        // 5. Remember the finished rows.
        let cache = self.cache.as_mut().expect("checked above");
        for (i, &r) in miss.iter().enumerate() {
            cache.insert(r, scratch.row(i));
        }
        self.scratch = scratch;
        self.miss_rows = miss;
    }

    /// The uncached pipeline: product → reduce → epilogue, with the same
    /// phase and flop accounting the pre-engine oracles recorded.
    fn compute_block(&mut self, rows: &[usize], out: &mut Mat, ledger: &mut Ledger) {
        debug_assert_eq!(out.nrows(), rows.len());
        debug_assert_eq!(out.ncols(), self.m);
        if self.reduce.has_exchange() {
            // Sharded grid storage: assemble the sampled rows' fragments
            // from the row subcommunicator before the product reads them.
            ledger.time(Phase::FragmentExchange, || self.reduce.exchange(rows));
        }
        let cost = ledger.time(Phase::KernelCompute, || self.product.compute(rows, out));
        ledger.add_flops(Phase::KernelCompute, cost.flops);
        if self.reduce.is_active() {
            // The per-iteration collective the s-step methods amortize.
            ledger.time(Phase::Allreduce, || self.reduce.reduce(out.data_mut()));
        }
        if let Some(ep) = &self.epilogue {
            // Redundant nonlinear map (identical on every rank).
            ledger.time(Phase::KernelCompute, || ep.apply(rows, out));
            ledger.add_flops(Phase::KernelCompute, ep.flops(rows.len()));
        }
        ledger.add_kernel_call(cost.rows_charged);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_classification;
    use crate::gram::{CsrProduct, NoReduce};
    use crate::rng::Pcg;

    fn local_engine(cache_rows: usize, kernel: Kernel) -> GramEngine<CsrProduct, NoReduce> {
        let ds = gen_dense_classification(24, 6, 0.0, 11);
        let product = CsrProduct::new(ds.a.clone());
        let ep = Epilogue::new(kernel, ds.a.row_norms_sq());
        let diag = ep.diag();
        GramEngine::new(Layout::Full, product, NoReduce, Some(ep), diag, cache_rows)
    }

    #[test]
    fn cached_engine_is_bitwise_equal_to_uncached() {
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut plain = local_engine(0, kernel);
            let mut cached = local_engine(8, kernel);
            let mut rng = Pcg::seeded(5);
            for _ in 0..20 {
                let k = rng.gen_range(1, 6);
                let sample: Vec<usize> = (0..k).map(|_| rng.gen_below(24)).collect();
                let mut q1 = Mat::zeros(k, 24);
                let mut q2 = Mat::zeros(k, 24);
                plain.gram(&sample, &mut q1, &mut Ledger::new());
                cached.gram(&sample, &mut q2, &mut Ledger::new());
                assert_eq!(q1.data(), q2.data(), "{kernel:?} sample {sample:?}");
            }
        }
    }

    #[test]
    fn cache_counters_track_hits_and_dedup() {
        let mut e = local_engine(16, Kernel::paper_rbf());
        let mut ledger = Ledger::new();
        // Cold call with an intra-call duplicate: 2 unique misses, 1 dup.
        let mut q = Mat::zeros(3, 24);
        e.gram(&[3, 7, 3], &mut q, &mut ledger);
        assert_eq!(ledger.cache.misses, 2);
        assert_eq!(ledger.cache.hits, 1);
        // Warm call: all hits, no kernel work.
        let flops_before = ledger.flops(Phase::KernelCompute);
        let mut q2 = Mat::zeros(2, 24);
        e.gram(&[7, 3], &mut q2, &mut ledger);
        assert_eq!(ledger.cache.hits, 3);
        assert_eq!(ledger.cache.misses, 2);
        assert_eq!(ledger.flops(Phase::KernelCompute), flops_before);
        // Local engine: nothing to save on the wire.
        assert_eq!(ledger.cache.words_saved, 0);
        assert_eq!(ledger.cache.allreduces_saved, 0);
        // Rows match a fresh uncached computation bitwise.
        let mut plain = local_engine(0, Kernel::paper_rbf());
        let mut q_ref = Mat::zeros(2, 24);
        plain.gram(&[7, 3], &mut q_ref, &mut Ledger::new());
        assert_eq!(q2.data(), q_ref.data());
    }

    #[test]
    fn uncached_engine_accounting_matches_legacy_formulas() {
        let ds = gen_dense_classification(20, 6, 0.0, 1);
        let kernel = Kernel::paper_rbf();
        let product = CsrProduct::new(ds.a.clone());
        let nnz = ds.a.nnz() as f64;
        let ep = Epilogue::new(kernel, ds.a.row_norms_sq());
        let diag = ep.diag();
        let mut e = GramEngine::new(Layout::Full, product, NoReduce, Some(ep), diag, 0);
        let mut ledger = Ledger::new();
        let mut q = Mat::zeros(3, 20);
        e.gram(&[4, 17, 4], &mut q, &mut ledger);
        let expect = 2.0 * 3.0 * nnz + kernel.mu() * 3.0 * 20.0;
        assert_eq!(ledger.flops(Phase::KernelCompute), expect);
        assert_eq!(ledger.kernel_calls, 1.0);
        assert_eq!(ledger.kernel_rows, 3.0);
    }

    #[test]
    fn eviction_pressure_stays_correct() {
        // Cache far smaller than the working set: every call mixes hits,
        // misses and evictions; results must still match uncached.
        let kernel = Kernel::paper_poly();
        let mut plain = local_engine(0, kernel);
        let mut cached = local_engine(2, kernel);
        let mut rng = Pcg::seeded(17);
        for _ in 0..40 {
            let k = rng.gen_range(1, 7);
            let sample: Vec<usize> = (0..k).map(|_| rng.gen_below(24)).collect();
            let mut q1 = Mat::zeros(k, 24);
            let mut q2 = Mat::zeros(k, 24);
            plain.gram(&sample, &mut q1, &mut Ledger::new());
            cached.gram(&sample, &mut q2, &mut Ledger::new());
            assert_eq!(q1.data(), q2.data());
        }
    }
}
