//! The staged gram engine: cache → product → reduce → epilogue.

use std::collections::HashMap;

use crate::comm::CommStats;
use crate::costmodel::{Ledger, Phase};
use crate::dense::Mat;
use crate::kernelfn::Kernel;

use super::cache::RowCache;
use super::epilogue::Epilogue;
use super::layout::{Layout, OverlapMode};
use super::product::{BlockKind, ProductCost, ProductStage};
use super::reduce::ReduceStage;

/// Where a sampled position is served from in a cached call.
enum Src {
    /// Present in the cache before this call.
    Hit,
    /// Computed this call; the payload is the index into the miss block.
    Miss(usize),
}

/// State carried from [`GramEngine::gram_start`] to
/// [`GramEngine::gram_finish`] while the posted reduction is in flight.
struct PendingGram {
    /// The sample the start was posted for (finish must match).
    sample: Vec<usize>,
    /// Deduplicated missed rows (the whole sample when the cache is off).
    miss: Vec<usize>,
    /// Staged miss block: partial product at start, reduced + mapped at
    /// finish.
    block: Mat,
    /// False when every position was a cache hit — nothing was computed
    /// or posted, finish only serves hits.
    active: bool,
}

/// One gram pipeline: a product backend, a reduction, an optional
/// nonlinear epilogue, and an optional kernel-row LRU cache in front.
/// Every oracle in the crate is a thin configuration of this struct.
pub struct GramEngine<P: ProductStage, R: ReduceStage> {
    layout: Layout,
    product: P,
    reduce: R,
    epilogue: Option<Epilogue>,
    /// `K(a_i, a_i)` for all `i` (precomputed by the configuration).
    diag: Vec<f64>,
    m: usize,
    cache: Option<RowCache>,
    /// Miss-block buffer, reused across calls.
    scratch: Mat,
    miss_rows: Vec<usize>,
    miss_pos: HashMap<usize, usize>,
    srcs: Vec<Src>,
    /// How communication is overlapped with compute. Inert when the
    /// configuration has nothing to overlap (see [`OverlapMode`]).
    overlap: OverlapMode,
    /// Split-phase call in flight ([`GramEngine::gram_start`]).
    pending: Option<PendingGram>,
}

impl<P: ProductStage, R: ReduceStage> GramEngine<P, R> {
    /// Assemble a pipeline. `epilogue` must be `Some` exactly when the
    /// product emits linear inner products; `cache_rows == 0` disables
    /// the row cache (the accounting then matches the pre-engine oracles
    /// count for count).
    pub fn new(
        layout: Layout,
        product: P,
        reduce: R,
        epilogue: Option<Epilogue>,
        diag: Vec<f64>,
        cache_rows: usize,
    ) -> GramEngine<P, R> {
        let m = product.m();
        assert_eq!(diag.len(), m, "diag length");
        assert_eq!(
            matches!(product.kind(), BlockKind::Linear),
            epilogue.is_some(),
            "Linear products need an epilogue; Kernel products must not have one"
        );
        GramEngine {
            layout,
            product,
            reduce,
            epilogue,
            diag,
            m,
            cache: (cache_rows > 0).then(|| RowCache::new(cache_rows)),
            scratch: Mat::zeros(0, 0),
            miss_rows: Vec::new(),
            miss_pos: HashMap::new(),
            srcs: Vec::new(),
            overlap: OverlapMode::Off,
            pending: None,
        }
    }

    /// Select the overlap mode (default [`OverlapMode::Off`]). A pure
    /// wall-time knob: every mode produces bitwise-identical blocks and
    /// identical total traffic; modes the configuration cannot exploit
    /// (no exchange to overlap, nothing to pipeline) degrade gracefully
    /// to the blocking schedule. Must be identical on every rank — the
    /// overlapped collectives are still collectives.
    pub fn set_overlap(&mut self, mode: OverlapMode) {
        assert!(
            self.pending.is_none(),
            "set_overlap: a split-phase gram call is in flight"
        );
        self.overlap = mode;
    }

    /// The configured overlap mode.
    pub fn overlap(&self) -> OverlapMode {
        self.overlap
    }

    /// Kernel-matrix dimension `m`.
    pub fn m(&self) -> usize {
        self.m
    }

    /// The layout this pipeline was configured for.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The configured kernel (None for finished-kernel products, whose
    /// map lives inside the product).
    pub fn kernel(&self) -> Option<Kernel> {
        self.epilogue.as_ref().map(|e| e.kernel())
    }

    /// `K(a_i, a_i)` for all `i`.
    pub fn diag(&self) -> Vec<f64> {
        self.diag.clone()
    }

    /// Row-cache capacity (0 = cache off).
    pub fn cache_capacity(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.capacity())
    }

    /// Whether `row` is currently resident in the kernel-row cache.
    ///
    /// Read-only probe — recency is *not* refreshed, so probing never
    /// perturbs the cache stream. Schedules use it to cross-check their
    /// shadow replica against the real cache.
    pub fn cache_resident(&self, row: usize) -> bool {
        self.cache.as_ref().is_some_and(|c| c.peek(row).is_some())
    }

    /// Traffic accumulated by the reduction stage.
    pub fn comm_stats(&self) -> CommStats {
        self.reduce.stats()
    }

    /// The product stage.
    pub fn product(&self) -> &P {
        &self.product
    }

    /// The reduction stage.
    pub fn reduce_stage(&self) -> &R {
        &self.reduce
    }

    /// Mutable access to the reduction stage (construction-time
    /// collectives).
    pub fn reduce_stage_mut(&mut self) -> &mut R {
        &mut self.reduce
    }

    /// Fill `q[r][·]` with kernel row `sample[r]`, recording costs.
    pub fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        assert!(
            self.pending.is_none(),
            "gram: a split-phase gram call is in flight"
        );
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.m);
        if self.cache.is_none() {
            self.compute_block(sample, q, ledger);
            return;
        }

        self.classify(sample, ledger);
        // Serve hits out of the cache (before any insert can evict them).
        self.serve_hits(sample, q, ledger);

        // Compute the deduplicated miss block through the pipeline.
        if self.miss_rows.is_empty() {
            if self.reduce.is_active() {
                ledger.cache.allreduces_saved += 1;
            }
            return;
        }
        let miss = std::mem::take(&mut self.miss_rows);
        let mut scratch = self.take_scratch(miss.len());
        self.compute_block(&miss, &mut scratch, ledger);
        self.commit_block(&miss, &scratch, q);
        self.scratch = scratch;
        self.miss_rows = miss;
    }

    /// Split-phase gram, first half ([`OverlapMode::Pipeline`]):
    /// classify, compute the partial product, and *post* the reduction.
    /// The caller overlaps unrelated compute (the previous s-step
    /// block's α updates), then calls [`GramEngine::gram_finish`] with
    /// the same sample. The classify → product → post sequence is
    /// exactly the blocking path's, so the cache stream and every bit of
    /// arithmetic are unchanged — only the wait moves.
    pub fn gram_start(&mut self, sample: &[usize], ledger: &mut Ledger) {
        assert!(
            self.pending.is_none(),
            "gram_start: a gram call is already in flight"
        );
        let miss: Vec<usize> = if self.cache.is_some() {
            self.classify(sample, ledger);
            if self.miss_rows.is_empty() {
                if self.reduce.is_active() {
                    ledger.cache.allreduces_saved += 1;
                }
                self.pending = Some(PendingGram {
                    sample: sample.to_vec(),
                    miss: Vec::new(),
                    block: Mat::zeros(0, 0),
                    active: false,
                });
                return;
            }
            std::mem::take(&mut self.miss_rows)
        } else {
            sample.to_vec()
        };
        let mut block = self.take_scratch(miss.len());
        let cost = self.product_into(&miss, &mut block, ledger);
        if self.reduce.is_active() {
            let posted = ledger.time(Phase::Allreduce, || self.reduce.reduce_start(block.data()));
            ledger.add_posted(posted);
        }
        ledger.add_kernel_call(cost.rows_charged);
        self.pending = Some(PendingGram {
            sample: sample.to_vec(),
            miss,
            block,
            active: true,
        });
    }

    /// Split-phase gram, second half: wait for the posted reduction,
    /// apply the epilogue, and fill `q` — the remaining (exposed) part
    /// of the blocking call.
    pub fn gram_finish(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        let mut pending = self
            .pending
            .take()
            .expect("gram_finish without a matching gram_start");
        assert_eq!(
            pending.sample, sample,
            "gram_finish: sample differs from the posted gram_start"
        );
        assert_eq!(q.nrows(), sample.len());
        assert_eq!(q.ncols(), self.m);
        if !pending.active {
            // Every position was a cache hit: nothing was posted.
            self.serve_hits(sample, q, ledger);
            return;
        }
        if self.reduce.is_active() {
            ledger.time(Phase::Allreduce, || {
                self.reduce.reduce_finish(pending.block.data_mut())
            });
        }
        self.apply_epilogue_stage(&pending.miss, &mut pending.block, ledger);
        if self.cache.is_some() {
            self.serve_hits(sample, q, ledger);
            self.commit_block(&pending.miss, &pending.block, q);
        } else {
            q.data_mut().copy_from_slice(pending.block.data());
        }
        self.scratch = pending.block;
        self.miss_rows = pending.miss;
    }

    /// Classify `sample` against the cache into hits and the
    /// deduplicated miss set (`self.srcs` / `self.miss_rows`), updating
    /// the cache counters. Deterministic: pure function of the sample
    /// stream and prior cache state (see module docs). Caller must hold
    /// a cache.
    fn classify(&mut self, sample: &[usize], ledger: &mut Ledger) {
        self.miss_rows.clear();
        self.miss_pos.clear();
        self.srcs.clear();
        let cache = self.cache.as_mut().expect("cached path");
        for &sr in sample {
            if let Some(&i) = self.miss_pos.get(&sr) {
                // Duplicate of a row already missed in this call.
                self.srcs.push(Src::Miss(i));
            } else if cache.contains_and_touch(sr) {
                self.srcs.push(Src::Hit);
            } else {
                let i = self.miss_rows.len();
                self.miss_pos.insert(sr, i);
                self.miss_rows.push(sr);
                self.srcs.push(Src::Miss(i));
            }
        }
        let served = (sample.len() - self.miss_rows.len()) as u64;
        ledger.cache.hits += served;
        ledger.cache.misses += self.miss_rows.len() as u64;
        if self.reduce.is_active() {
            // Each served row skips the reduction of one m-word kernel
            // row (the 1D allreduce payload; the grid layout splits the
            // same row across its reduce + allgather collectives).
            ledger.cache.words_saved += served * self.m as u64;
        }
    }

    /// Copy every `Src::Hit` position of `sample` out of the cache into
    /// `q` (no-op, untimed, when there are none).
    fn serve_hits(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        if !self.srcs.iter().any(|s| matches!(s, Src::Hit)) {
            return;
        }
        let cache = self.cache.as_ref().expect("cached path");
        ledger.time(Phase::CacheHit, || {
            for (pos, src) in self.srcs.iter().enumerate() {
                if matches!(src, Src::Hit) {
                    let row = cache.peek(sample[pos]).expect("hit row present");
                    q.row_mut(pos).copy_from_slice(row);
                }
            }
        });
    }

    /// Fill the missed positions of `q` (duplicates included) from the
    /// finished miss block, then remember the rows in the cache.
    fn commit_block(&mut self, miss: &[usize], block: &Mat, q: &mut Mat) {
        for (pos, src) in self.srcs.iter().enumerate() {
            if let Src::Miss(i) = src {
                q.row_mut(pos).copy_from_slice(block.row(*i));
            }
        }
        let cache = self.cache.as_mut().expect("cached path");
        for (i, &r) in miss.iter().enumerate() {
            cache.insert(r, block.row(i));
        }
    }

    /// The reusable miss-block buffer, sized `rows × m`.
    fn take_scratch(&mut self, rows: usize) -> Mat {
        let scratch = std::mem::replace(&mut self.scratch, Mat::zeros(0, 0));
        if scratch.nrows() != rows || scratch.ncols() != self.m {
            return Mat::zeros(rows, self.m);
        }
        scratch
    }

    /// The uncached pipeline: product → reduce → epilogue, with the same
    /// phase and flop accounting the pre-engine oracles recorded.
    fn compute_block(&mut self, rows: &[usize], out: &mut Mat, ledger: &mut Ledger) {
        debug_assert_eq!(out.nrows(), rows.len());
        debug_assert_eq!(out.ncols(), self.m);
        let cost = self.product_into(rows, out, ledger);
        if self.reduce.is_active() {
            // The per-iteration collective the s-step methods amortize.
            ledger.time(Phase::Allreduce, || self.reduce.reduce(out.data_mut()));
        }
        self.apply_epilogue_stage(rows, out, ledger);
        ledger.add_kernel_call(cost.rows_charged);
    }

    /// Fragment exchange (if any) + linear product into `out`. Under
    /// [`OverlapMode::Exchange`] the ring is posted rather than waited
    /// on: the rows whose fragments this rank already stores are
    /// computed *under* the in-flight exchange (their flops are the
    /// overlap's hidden-compute budget), the rest after the wait. Each
    /// row is still computed by exactly one pass with the stage's fixed
    /// per-entry order, so the block is bitwise identical to the
    /// blocking schedule.
    fn product_into(&mut self, rows: &[usize], out: &mut Mat, ledger: &mut Ledger) -> ProductCost {
        if !self.reduce.has_exchange() {
            let cost = ledger.time(Phase::KernelCompute, || self.product.compute(rows, out));
            ledger.add_flops(Phase::KernelCompute, cost.flops);
            return cost;
        }
        if self.overlap != OverlapMode::Exchange {
            // Blocking: assemble the sampled rows' fragments from the
            // row subcommunicator before the product reads them.
            ledger.time(Phase::FragmentExchange, || self.reduce.exchange(rows));
            let cost = ledger.time(Phase::KernelCompute, || self.product.compute(rows, out));
            ledger.add_flops(Phase::KernelCompute, cost.flops);
            return cost;
        }

        let posted = ledger.time(Phase::FragmentExchange, || self.reduce.exchange_start(rows));
        ledger.add_posted(posted);
        let mask = self.reduce.local_mask(rows);
        let owned: Vec<usize> = (0..rows.len()).filter(|&i| mask[i]).collect();
        let mut total = ProductCost {
            flops: 0.0,
            rows_charged: 0,
        };
        // Owned-rows pass, hidden under the in-flight ring.
        if !owned.is_empty() {
            let owned_rows: Vec<usize> = owned.iter().map(|&i| rows[i]).collect();
            let mut sub = Mat::zeros(owned_rows.len(), self.m);
            let cost = ledger.time(Phase::KernelCompute, || {
                self.product.compute(&owned_rows, &mut sub)
            });
            ledger.add_flops(Phase::KernelCompute, cost.flops);
            ledger.add_hidden_flops(Phase::KernelCompute, cost.flops);
            for (j, &i) in owned.iter().enumerate() {
                out.row_mut(i).copy_from_slice(sub.row(j));
            }
            total.flops += cost.flops;
            total.rows_charged += cost.rows_charged;
        }
        ledger.time(Phase::FragmentExchange, || self.reduce.exchange_finish());
        // Remote-rows pass, after the exchanged fragments landed.
        let remote: Vec<usize> = (0..rows.len()).filter(|&i| !mask[i]).collect();
        if remote.len() == rows.len() {
            // Nothing owned locally: one full pass, directly into `out`.
            let cost = ledger.time(Phase::KernelCompute, || self.product.compute(rows, out));
            ledger.add_flops(Phase::KernelCompute, cost.flops);
            total.flops += cost.flops;
            total.rows_charged += cost.rows_charged;
        } else if !remote.is_empty() {
            let remote_rows: Vec<usize> = remote.iter().map(|&i| rows[i]).collect();
            let mut sub = Mat::zeros(remote_rows.len(), self.m);
            let cost = ledger.time(Phase::KernelCompute, || {
                self.product.compute(&remote_rows, &mut sub)
            });
            ledger.add_flops(Phase::KernelCompute, cost.flops);
            for (j, &i) in remote.iter().enumerate() {
                out.row_mut(i).copy_from_slice(sub.row(j));
            }
            total.flops += cost.flops;
            total.rows_charged += cost.rows_charged;
        }
        total
    }

    /// Redundant nonlinear map (identical on every rank), spread over
    /// the product stage's worker split when it has one.
    fn apply_epilogue_stage(&mut self, rows: &[usize], out: &mut Mat, ledger: &mut Ledger) {
        if let Some(ep) = &self.epilogue {
            ledger.time(Phase::KernelCompute, || {
                self.product.apply_epilogue(ep, rows, out)
            });
            ledger.add_flops(Phase::KernelCompute, ep.flops(rows.len()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_dense_classification;
    use crate::gram::{CsrProduct, NoReduce};
    use crate::rng::Pcg;

    fn local_engine(cache_rows: usize, kernel: Kernel) -> GramEngine<CsrProduct, NoReduce> {
        let ds = gen_dense_classification(24, 6, 0.0, 11);
        let product = CsrProduct::new(ds.a.clone());
        let ep = Epilogue::new(kernel, ds.a.row_norms_sq());
        let diag = ep.diag();
        GramEngine::new(Layout::Full, product, NoReduce, Some(ep), diag, cache_rows)
    }

    #[test]
    fn cached_engine_is_bitwise_equal_to_uncached() {
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let mut plain = local_engine(0, kernel);
            let mut cached = local_engine(8, kernel);
            let mut rng = Pcg::seeded(5);
            for _ in 0..20 {
                let k = rng.gen_range(1, 6);
                let sample: Vec<usize> = (0..k).map(|_| rng.gen_below(24)).collect();
                let mut q1 = Mat::zeros(k, 24);
                let mut q2 = Mat::zeros(k, 24);
                plain.gram(&sample, &mut q1, &mut Ledger::new());
                cached.gram(&sample, &mut q2, &mut Ledger::new());
                assert_eq!(q1.data(), q2.data(), "{kernel:?} sample {sample:?}");
            }
        }
    }

    #[test]
    fn cache_counters_track_hits_and_dedup() {
        let mut e = local_engine(16, Kernel::paper_rbf());
        let mut ledger = Ledger::new();
        // Cold call with an intra-call duplicate: 2 unique misses, 1 dup.
        let mut q = Mat::zeros(3, 24);
        e.gram(&[3, 7, 3], &mut q, &mut ledger);
        assert_eq!(ledger.cache.misses, 2);
        assert_eq!(ledger.cache.hits, 1);
        // Warm call: all hits, no kernel work.
        let flops_before = ledger.flops(Phase::KernelCompute);
        let mut q2 = Mat::zeros(2, 24);
        e.gram(&[7, 3], &mut q2, &mut ledger);
        assert_eq!(ledger.cache.hits, 3);
        assert_eq!(ledger.cache.misses, 2);
        assert_eq!(ledger.flops(Phase::KernelCompute), flops_before);
        // Local engine: nothing to save on the wire.
        assert_eq!(ledger.cache.words_saved, 0);
        assert_eq!(ledger.cache.allreduces_saved, 0);
        // Rows match a fresh uncached computation bitwise.
        let mut plain = local_engine(0, Kernel::paper_rbf());
        let mut q_ref = Mat::zeros(2, 24);
        plain.gram(&[7, 3], &mut q_ref, &mut Ledger::new());
        assert_eq!(q2.data(), q_ref.data());
    }

    #[test]
    fn uncached_engine_accounting_matches_legacy_formulas() {
        let ds = gen_dense_classification(20, 6, 0.0, 1);
        let kernel = Kernel::paper_rbf();
        let product = CsrProduct::new(ds.a.clone());
        let nnz = ds.a.nnz() as f64;
        let ep = Epilogue::new(kernel, ds.a.row_norms_sq());
        let diag = ep.diag();
        let mut e = GramEngine::new(Layout::Full, product, NoReduce, Some(ep), diag, 0);
        let mut ledger = Ledger::new();
        let mut q = Mat::zeros(3, 20);
        e.gram(&[4, 17, 4], &mut q, &mut ledger);
        let expect = 2.0 * 3.0 * nnz + kernel.mu() * 3.0 * 20.0;
        assert_eq!(ledger.flops(Phase::KernelCompute), expect);
        assert_eq!(ledger.kernel_calls, 1.0);
        assert_eq!(ledger.kernel_rows, 3.0);
    }

    #[test]
    fn eviction_pressure_stays_correct() {
        // Cache far smaller than the working set: every call mixes hits,
        // misses and evictions; results must still match uncached.
        let kernel = Kernel::paper_poly();
        let mut plain = local_engine(0, kernel);
        let mut cached = local_engine(2, kernel);
        let mut rng = Pcg::seeded(17);
        for _ in 0..40 {
            let k = rng.gen_range(1, 7);
            let sample: Vec<usize> = (0..k).map(|_| rng.gen_below(24)).collect();
            let mut q1 = Mat::zeros(k, 24);
            let mut q2 = Mat::zeros(k, 24);
            plain.gram(&sample, &mut q1, &mut Ledger::new());
            cached.gram(&sample, &mut q2, &mut Ledger::new());
            assert_eq!(q1.data(), q2.data());
        }
    }
}
