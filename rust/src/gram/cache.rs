//! Deterministic kernel-row LRU cache.
//!
//! Stores *finished* kernel rows (post-reduction, post-epilogue), keyed
//! by row index. Everything is a pure function of the access sequence:
//! recency stamps come from a monotonic counter (unique, so eviction has
//! no ties), and no clock or RNG is involved. Since every rank draws the
//! sampled coordinates from the same seeded stream, identically sized
//! caches on all ranks make identical hit/miss decisions — which keeps
//! the collective reduction matched across ranks (see the module docs of
//! [`crate::gram`] for the full determinism contract).

use std::collections::HashMap;

struct Entry {
    stamp: u64,
    data: Vec<f64>,
}

/// Bounded LRU map from row index to the finished kernel row.
pub struct RowCache {
    capacity: usize,
    clock: u64,
    map: HashMap<usize, Entry>,
}

impl RowCache {
    /// `capacity` > 0 rows.
    pub fn new(capacity: usize) -> RowCache {
        assert!(capacity > 0, "RowCache capacity must be positive");
        RowCache {
            capacity,
            clock: 0,
            map: HashMap::with_capacity(capacity.min(1 << 20)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Membership test that also refreshes the row's recency.
    pub fn contains_and_touch(&mut self, row: usize) -> bool {
        self.clock += 1;
        match self.map.get_mut(&row) {
            Some(e) => {
                e.stamp = self.clock;
                true
            }
            None => false,
        }
    }

    /// Read a cached row without touching recency.
    pub fn peek(&self, row: usize) -> Option<&[f64]> {
        self.map.get(&row).map(|e| e.data.as_slice())
    }

    /// Insert (or overwrite) a row, evicting the least-recently-used
    /// entry when full. Stamps are unique, so the victim is unambiguous —
    /// eviction is deterministic even though `HashMap` iteration is not.
    ///
    /// Eviction scans all entries (O(capacity) per miss-insert). That is
    /// deliberate: a miss already costs a full kernel-row compute
    /// (≥ O(m) multiply-adds, typically O(nnz)), which dwarfs a scan of
    /// a few thousand `u64` stamps. Revisit with an intrusive LRU list
    /// if caches ever grow to ≫10⁴ rows.
    pub fn insert(&mut self, row: usize, data: &[f64]) {
        self.clock += 1;
        if let Some(e) = self.map.get_mut(&row) {
            e.stamp = self.clock;
            e.data.clear();
            e.data.extend_from_slice(data);
            return;
        }
        let mut entry = if self.map.len() >= self.capacity {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&k, _)| k)
                .expect("non-empty cache");
            let mut e = self.map.remove(&victim).expect("victim present");
            e.data.clear();
            e
        } else {
            Entry {
                stamp: 0,
                data: Vec::with_capacity(data.len()),
            }
        };
        entry.stamp = self.clock;
        entry.data.extend_from_slice(data);
        self.map.insert(row, entry);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f64, n: usize) -> Vec<f64> {
        vec![v; n]
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = RowCache::new(2);
        c.insert(1, &row(1.0, 4));
        c.insert(2, &row(2.0, 4));
        assert!(c.contains_and_touch(1)); // 1 becomes most recent
        c.insert(3, &row(3.0, 4)); // evicts 2
        assert_eq!(c.peek(2), None);
        assert_eq!(c.peek(1).unwrap()[0], 1.0);
        assert_eq!(c.peek(3).unwrap()[0], 3.0);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn insert_overwrites_in_place() {
        let mut c = RowCache::new(1);
        c.insert(7, &row(1.0, 3));
        c.insert(7, &row(9.0, 3));
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(7).unwrap(), &[9.0, 9.0, 9.0]);
    }

    #[test]
    fn access_sequence_determines_state() {
        // Two caches fed the same sequence end in the same state —
        // exercised over a sequence long enough to force many evictions.
        let seq: Vec<usize> = (0..200).map(|i| (i * 7 + i / 3) % 13).collect();
        let run = |cap: usize| -> Vec<Option<f64>> {
            let mut c = RowCache::new(cap);
            for &r in &seq {
                if !c.contains_and_touch(r) {
                    c.insert(r, &row(r as f64, 2));
                }
            }
            (0..13).map(|r| c.peek(r).map(|d| d[0])).collect()
        };
        assert_eq!(run(5), run(5));
        assert_eq!(run(5).iter().filter(|v| v.is_some()).count(), 5);
    }
}
