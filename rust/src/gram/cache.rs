//! Deterministic kernel-row LRU cache.
//!
//! Stores *finished* kernel rows (post-reduction, post-epilogue), keyed
//! by row index. Everything is a pure function of the access sequence:
//! recency is an index-linked LRU list threaded through a slab of nodes
//! (no clock, no RNG, and no `HashMap`-iteration-order dependence).
//! Since every rank draws the sampled coordinates from the same seeded
//! stream, identically sized caches on all ranks make identical hit/miss
//! decisions — which keeps the collective reduction matched across ranks
//! (see the module docs of [`crate::gram`] for the full determinism
//! contract).
//!
//! Every operation is O(1): the original implementation stamped entries
//! with a monotonic counter and scanned the whole map for the minimum
//! stamp on each evicting insert, which put an O(capacity) scan on the
//! serial hot path once the threaded product shrank the miss-compute
//! time. The linked list preserves the stamp semantics exactly — the
//! list order *is* the stamp order (every touch/insert moves a row to
//! the front; the tail is the unique minimum-stamp victim), so hit/miss
//! and eviction decisions are unchanged, as pinned by
//! `access_sequence_determines_state` and the reference-model test
//! below.

use std::collections::HashMap;

/// Null slot index for the intrusive list links.
const NIL: usize = usize::MAX;

struct Node {
    row: usize,
    prev: usize,
    next: usize,
    data: Vec<f64>,
}

/// Bounded LRU map from row index to the finished kernel row.
pub struct RowCache {
    capacity: usize,
    /// Row index → slot in `nodes`.
    map: HashMap<usize, usize>,
    /// Node slab; slots are allocated once and recycled on eviction, so
    /// row buffers are reused without reallocation.
    nodes: Vec<Node>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (NIL when empty) — the eviction victim.
    tail: usize,
}

impl RowCache {
    /// `capacity` > 0 rows.
    pub fn new(capacity: usize) -> RowCache {
        assert!(capacity > 0, "RowCache capacity must be positive");
        RowCache {
            capacity,
            map: HashMap::with_capacity(capacity.min(1 << 20)),
            nodes: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Configured capacity in rows.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rows currently cached.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Detach `slot` from the recency list.
    fn unlink(&mut self, slot: usize) {
        let (prev, next) = (self.nodes[slot].prev, self.nodes[slot].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.nodes[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.nodes[next].prev = prev;
        }
    }

    /// Attach `slot` at the most-recent end.
    fn push_front(&mut self, slot: usize) {
        self.nodes[slot].prev = NIL;
        self.nodes[slot].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    /// Move `slot` to the most-recent end.
    fn touch(&mut self, slot: usize) {
        if self.head != slot {
            self.unlink(slot);
            self.push_front(slot);
        }
    }

    /// Membership test that also refreshes the row's recency.
    pub fn contains_and_touch(&mut self, row: usize) -> bool {
        match self.map.get(&row).copied() {
            Some(slot) => {
                self.touch(slot);
                true
            }
            None => false,
        }
    }

    /// Read a cached row without touching recency.
    pub fn peek(&self, row: usize) -> Option<&[f64]> {
        self.map.get(&row).map(|&slot| self.nodes[slot].data.as_slice())
    }

    /// Insert (or overwrite) a row, evicting the least-recently-used
    /// entry when full. The tail of the recency list is the unique
    /// victim, so eviction is deterministic.
    pub fn insert(&mut self, row: usize, data: &[f64]) {
        if let Some(&slot) = self.map.get(&row) {
            let node = &mut self.nodes[slot];
            node.data.clear();
            node.data.extend_from_slice(data);
            self.touch(slot);
            return;
        }
        let slot = if self.map.len() >= self.capacity {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL, "full cache has a tail");
            let old_row = self.nodes[victim].row;
            self.map.remove(&old_row).expect("victim indexed");
            self.unlink(victim);
            let node = &mut self.nodes[victim];
            node.row = row;
            node.data.clear();
            node.data.extend_from_slice(data);
            victim
        } else {
            self.nodes.push(Node {
                row,
                prev: NIL,
                next: NIL,
                data: data.to_vec(),
            });
            self.nodes.len() - 1
        };
        self.map.insert(row, slot);
        self.push_front(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f64, n: usize) -> Vec<f64> {
        vec![v; n]
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = RowCache::new(2);
        c.insert(1, &row(1.0, 4));
        c.insert(2, &row(2.0, 4));
        assert!(c.contains_and_touch(1)); // 1 becomes most recent
        c.insert(3, &row(3.0, 4)); // evicts 2
        assert_eq!(c.peek(2), None);
        assert_eq!(c.peek(1).unwrap()[0], 1.0);
        assert_eq!(c.peek(3).unwrap()[0], 3.0);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn insert_overwrites_in_place() {
        let mut c = RowCache::new(1);
        c.insert(7, &row(1.0, 3));
        c.insert(7, &row(9.0, 3));
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(7).unwrap(), &[9.0, 9.0, 9.0]);
    }

    #[test]
    fn access_sequence_determines_state() {
        // Two caches fed the same sequence end in the same state —
        // exercised over a sequence long enough to force many evictions.
        let seq: Vec<usize> = (0..200).map(|i| (i * 7 + i / 3) % 13).collect();
        let run = |cap: usize| -> Vec<Option<f64>> {
            let mut c = RowCache::new(cap);
            for &r in &seq {
                if !c.contains_and_touch(r) {
                    c.insert(r, &row(r as f64, 2));
                }
            }
            (0..13).map(|r| c.peek(r).map(|d| d[0])).collect()
        };
        assert_eq!(run(5), run(5));
        assert_eq!(run(5).iter().filter(|v| v.is_some()).count(), 5);
    }

    /// Reference model of the original stamp-based cache: the linked
    /// list must replay its hit/miss decisions and eviction victims
    /// exactly, operation by operation.
    #[test]
    fn linked_list_matches_stamp_reference_model() {
        struct StampCache {
            capacity: usize,
            clock: u64,
            map: HashMap<usize, (u64, f64)>,
        }
        impl StampCache {
            fn contains_and_touch(&mut self, row: usize) -> bool {
                self.clock += 1;
                match self.map.get_mut(&row) {
                    Some(e) => {
                        e.0 = self.clock;
                        true
                    }
                    None => false,
                }
            }
            fn insert(&mut self, row: usize, v: f64) {
                self.clock += 1;
                if let Some(e) = self.map.get_mut(&row) {
                    *e = (self.clock, v);
                    return;
                }
                if self.map.len() >= self.capacity {
                    let victim = self
                        .map
                        .iter()
                        .min_by_key(|(_, e)| e.0)
                        .map(|(&k, _)| k)
                        .expect("non-empty");
                    self.map.remove(&victim);
                }
                self.map.insert(row, (self.clock, v));
            }
        }

        for cap in [1usize, 2, 3, 7] {
            let mut real = RowCache::new(cap);
            let mut model = StampCache {
                capacity: cap,
                clock: 0,
                map: HashMap::new(),
            };
            // A mixed access stream with repeats, overwrites and misses.
            let mut x = 88172645463325252u64;
            for step in 0..4000u64 {
                // xorshift64 — deterministic op stream.
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                let r = (x % 17) as usize;
                if x % 3 == 0 {
                    assert_eq!(
                        real.contains_and_touch(r),
                        model.contains_and_touch(r),
                        "cap={cap} step={step} row={r}"
                    );
                } else {
                    let v = step as f64;
                    real.insert(r, &row(v, 2));
                    model.insert(r, v);
                }
                // Full-state comparison: same members, same values.
                assert_eq!(real.len(), model.map.len(), "cap={cap} step={step}");
                for probe in 0..17usize {
                    assert_eq!(
                        real.peek(probe).map(|d| d[0]),
                        model.map.get(&probe).map(|e| e.1),
                        "cap={cap} step={step} probe={probe}"
                    );
                }
            }
        }
    }
}
