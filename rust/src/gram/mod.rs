//! The staged, cached gram engine — every sampled kernel-row computation
//! in the crate flows through here.
//!
//! The paper's central per-iteration cost object is the sampled kernel
//! (gram) block `Q[r][i] = K(a_{S_r}, a_i)` plus, in the distributed
//! setting, its allreduce. The crate used to carry four copies of that
//! pipeline (`LocalGram`, `DistGram`, `NystromGram`, `PjrtGram`); this
//! module decomposes it into explicit, composable stages so every oracle
//! is a thin configuration and every future backend is a plug-in:
//!
//! 1. **Layout** ([`Layout`]) — where the data lives: the full matrix on
//!    one rank, this rank's 1D-column shard (the paper's partitioning,
//!    where each of `P` ranks stores ≈ `n/P` features of every sample), or
//!    one cell of a 2D `pr × pc` process grid ([`Layout::Grid`]: feature
//!    shard × block-cyclic row group, the communication-avoiding
//!    refinement — see `docs/ARCHITECTURE.md`).
//! 2. **Linear product** ([`ProductStage`]) — the (partial) linear gram
//!    `Z = A_S Aᵀ`. [`CsrProduct`] picks between the blocked scatter-dot
//!    path and the cached-transpose path by the density heuristic;
//!    [`LowRankProduct`] multiplies precomputed Nyström factors; the
//!    PJRT runtime contributes an XLA-executing product. A product
//!    declares via [`BlockKind`] whether it emits *linear* inner products
//!    (epilogue required) or finished *kernel* values.
//! 3. **Reduction** ([`ReduceStage`]) — a no-op locally ([`NoReduce`]),
//!    the sum-allreduce of the partial block across column shards
//!    ([`AllreduceSum`]) — the communication the s-step methods
//!    amortize — or the grid pair's column-subcommunicator reduce plus
//!    row-subcommunicator allgather ([`GridReduce`]), which shrinks that
//!    collective from `P` ranks moving `k·m` words to `pc` ranks moving
//!    `k·m/pr`.
//! 4. **Epilogue** ([`Epilogue`]) — the pointwise nonlinear kernel map
//!    ([`crate::kernelfn::Kernel::apply_block`]), applied redundantly on
//!    every rank after the reduction (the paper's Theorem 1/2 schedule).
//!
//! In front of the pipeline sits an optional **kernel-row LRU cache**
//! ([`RowCache`]). DCD samples coordinates *with replacement* and s-step
//! blocks re-touch rows, so a bounded cache of finished kernel rows
//! converts repeats into copies — skipping the product, the epilogue,
//! *and the allreduce* (a real communication saving, attributed to
//! [`crate::costmodel::Phase::CacheHit`] and the
//! [`crate::costmodel::CacheStats`] counters).
//!
//! ### Determinism contract
//!
//! The cache is fully deterministic — no randomness, no clock: hits and
//! LRU evictions are a pure function of the sampled-coordinate stream,
//! which every rank draws from the same seeded generator. All ranks
//! therefore agree, call by call, on which rows miss, so the collective
//! allreduce stays correctly matched across ranks (the cache size must be
//! identical on every rank — it is part of the run configuration, see
//! `coordinator::SolverSpec::cache_rows` and `--gram-cache-rows`).
//!
//! Cached rows are *bitwise identical* to uncached recomputation: every
//! product stage computes each output row independently with a fixed
//! per-entry summation order, and each element of the allreduced block is
//! combined across ranks in a w-independent order (sibling pairs of the
//! reduction tree are fixed by rank, and f64 addition is commutative), so
//! serving a row from cache replays exactly the bits the uncached run
//! would produce. The one caveat: the Rabenseifner collective falls back
//! to recursive doubling for payloads smaller than `P` words, which
//! groups the partial sums differently — with `m ≥ P` (every realistic
//! configuration) a miss block's payload `k·m` never crosses that
//! threshold, so the contract holds. `cargo test` pins all of this
//! (`rust/tests/gram_engine_props.rs`).
//!
//! [`GridStorage`] extends the contract along a fourth axis: a
//! `Sharded` grid cell stores only its block-cyclic row group of the
//! feature shard (`≈m/pr × ≈n/pc` — per-rank memory finally shrinks
//! with `pr`) and assembles each gram call's sampled rows through the
//! pre-product **fragment exchange** (`GridReduce::exchange` → ring
//! `allgatherv` over the row subcommunicator → [`FragmentSlot`]). The
//! exchanged fragments are *verbatim copies* of the stored rows
//! ([`crate::sparse::Csr::pack_rows`] / `from_packed` round-trip
//! bitwise), the product then performs the identical arithmetic on
//! them, and the construction-time row norms are gathered the same way
//! before the unchanged column allreduce — so a sharded solve is
//! **bitwise identical to the replicated grid solve** (and therefore to
//! 1D over `pc` ranks) for every `(pr, pc, row_block, cache, threads)`.
//! Storage trades memory for exchange traffic only; it must be
//! identical on every rank (the exchange is a collective). Pinned by
//! `rust/tests/grid_layout_props.rs`.
//!
//! The same row-wise independence makes the product stage **thread-count
//! invariant**: [`crate::parallel::ParallelProduct`] splits the sampled
//! rows of any inner product across `t` scoped worker threads with a
//! deterministic contiguous partition, so each row is still computed by
//! exactly one worker with the fixed per-entry summation order. The
//! assembled block — and therefore every solver trajectory — is bitwise
//! identical for every `t`, with the cache on or off, locally or under
//! the distributed reduction (both run outside the product stage, and
//! the hit/miss stream does not depend on `t`). Unlike `cache_rows`,
//! `threads` may even differ across ranks without breaking the
//! collective matching — it changes no message and no decision, only
//! wall time. Pinned by `rust/tests/threaded_product_props.rs`, across
//! thread counts {1, 2, 3, 8}, cache on/off, product backends, and
//! DistGram ranks.
//!
//! The 2D grid layout ([`Layout::Grid`], `GridProduct` + `GridReduce`,
//! `solvers::GridGram`) extends the contract along a third axis: a
//! `pr × pc` grid solve over `P = pr·pc` ranks is **bitwise identical to
//! the 1D `ColShard` solve over `pc` ranks** — the grid keeps the 1D
//! path's `pc` feature shards and reduce tree untouched and adds row
//! parallelism *around* them, so `pr` (like `threads` and the
//! block-cyclic `row_block`) changes wall time and traffic, never a bit
//! of arithmetic. In particular `Grid{1, P}` *is* the 1D path over `P`
//! ranks, and all factorizations of `P` with equal `pc` agree bitwise
//! with each other. Equality *across different shard counts* (e.g.
//! `Grid{2, 4}` vs 1D over 8 ranks) is mathematically impossible for any
//! layout: splitting a dot product into 4 vs 8 partial sums regroups f64
//! additions — the same reason 1D runs at different `P` differ in their
//! last bits. One payload caveat mirrors the Rabenseifner one above: the
//! grid's reduce payload is `k·⌈m/pr⌉` words, which stays at or above the
//! small-vector fallback threshold whenever `m ≥ P` (every realistic
//! configuration), keeping the subgroup reduce on the same algorithm as
//! the 1D reference. Pinned by `rust/tests/grid_layout_props.rs` over
//! every `(pr, pc)` factorization of `P ∈ {2, …, 12}`, cache on/off, and
//! threads {1, 4}.

#![forbid(unsafe_code)]

mod cache;
mod engine;
mod epilogue;
mod layout;
mod product;
mod reduce;

pub use cache::RowCache;
pub use engine::GramEngine;
pub use epilogue::Epilogue;
pub use layout::{block_cyclic_rows, GridStorage, Layout, OverlapMode, DEFAULT_ROW_BLOCK};
pub use product::{
    BlockKind, CsrProduct, FragmentSlot, GridProduct, LowRankProduct, ProductCost, ProductStage,
    TRANSPOSE_GRAM_MAX_DENSITY,
};
pub use reduce::{AllreduceSum, GridReduce, NoReduce, ReduceStage};

use crate::costmodel::Ledger;
use crate::dense::Mat;

/// Produces sampled rows of the kernel matrix `K(A, A)`.
///
/// `gram(sample, q, ledger)` fills `q` (`sample.len() × m`) with
/// `q[r][i] = K(a_{sample_r}, a_i)`, recording costs. Implementations are
/// configurations of [`GramEngine`]; the solvers stay generic over this
/// trait, so serial, distributed, approximated and PJRT-executed runs use
/// identical solver code.
pub trait GramOracle {
    /// Number of samples `m` (kernel-matrix dimension).
    fn m(&self) -> usize;

    /// Fill `q[r][·]` with kernel row `sample[r]`, recording costs.
    fn gram(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger);

    /// `K(a_i, a_i)` for all `i` (cheap; used for SVM `η` sanity checks
    /// and objective evaluation).
    fn diag(&self) -> Vec<f64>;

    /// Communication statistics accumulated so far (zero for local).
    fn comm_stats(&self) -> crate::comm::CommStats {
        crate::comm::CommStats::default()
    }

    /// The overlap mode this oracle runs its communication under
    /// ([`OverlapMode::Off`] unless the oracle supports overlap and was
    /// configured otherwise). Solvers consult this to decide whether to
    /// drive the split-phase `gram_start`/`gram_finish` pipeline.
    fn overlap(&self) -> OverlapMode {
        OverlapMode::Off
    }

    /// Split-phase gram, first half: classify the sample against the
    /// cache, compute the partial product, and *post* the reduction
    /// without waiting for it. The caller may then do unrelated compute
    /// (the previous block's α updates) before calling
    /// [`GramOracle::gram_finish`] with the same sample. Default: no-op
    /// (the work happens in `gram_finish` via the blocking path), so
    /// oracles without nonblocking support stay correct under pipelined
    /// drivers.
    ///
    /// Exactly one `gram_finish` must follow each `gram_start`, in post
    /// order, with no other gram call in between on this oracle.
    fn gram_start(&mut self, _sample: &[usize], _ledger: &mut Ledger) {}

    /// Split-phase gram, second half: wait for the posted reduction,
    /// apply the epilogue, and fill `q`. Default: the blocking
    /// [`GramOracle::gram`].
    fn gram_finish(&mut self, sample: &[usize], q: &mut Mat, ledger: &mut Ledger) {
        self.gram(sample, q, ledger);
    }
}
