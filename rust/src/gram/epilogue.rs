//! Epilogue stage: the pointwise nonlinear kernel map over a reduced
//! linear gram block, applied redundantly on every rank.

use crate::dense::Mat;
use crate::kernelfn::Kernel;

/// Kernel map + the cached row norms the RBF expansion needs.
pub struct Epilogue {
    kernel: Kernel,
    /// Full-matrix `‖a_i‖²` (allreduced once at construction when the
    /// layout is sharded — they are themselves a column-shard sum).
    row_norms: Vec<f64>,
}

impl Epilogue {
    /// Pair a kernel with the (full-matrix) row norms it needs.
    pub fn new(kernel: Kernel, row_norms: Vec<f64>) -> Epilogue {
        Epilogue { kernel, row_norms }
    }

    /// The configured kernel.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The cached `‖a_i‖²` values.
    pub fn row_norms(&self) -> &[f64] {
        &self.row_norms
    }

    /// Apply the kernel map in place to the `rows.len() × m` block `q`.
    pub fn apply(&self, rows: &[usize], q: &mut Mat) {
        self.apply_chunk(rows, q.data_mut());
    }

    /// Apply the kernel map to a row-major `rows.len() × m` slice — the
    /// worker-split entry point: [`crate::parallel::ParallelProduct`]
    /// hands each worker a contiguous run of whole rows. The map is
    /// per-element, so any whole-row split is bitwise identical to
    /// [`Epilogue::apply`] over the full block.
    pub fn apply_chunk(&self, rows: &[usize], chunk: &mut [f64]) {
        let sample_norms: Vec<f64> = rows.iter().map(|&i| self.row_norms[i]).collect();
        self.kernel
            .apply_packed(chunk, &sample_norms, &self.row_norms);
    }

    /// Ledger cost of applying the map to a `rows × m` block.
    pub fn flops(&self, rows: usize) -> f64 {
        self.kernel.epilogue_flops(rows, self.row_norms.len())
    }

    /// `K(a_i, a_i)` for all `i` from the cached norms.
    pub fn diag(&self) -> Vec<f64> {
        self.row_norms
            .iter()
            .map(|&n| self.kernel.apply_scalar(n, n, n))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::gemm_nt;
    use crate::rng::Pcg;

    #[test]
    fn epilogue_matches_direct_apply_block_and_diag() {
        let mut r = Pcg::seeded(91);
        let a = Mat::from_fn(10, 4, |_, _| r.next_gaussian());
        let norms = a.row_norms_sq();
        for kernel in [Kernel::Linear, Kernel::paper_poly(), Kernel::paper_rbf()] {
            let ep = Epilogue::new(kernel, norms.clone());
            let sample = vec![2usize, 7];
            let a_s = a.gather_rows(&sample);
            let mut z = Mat::zeros(2, 10);
            gemm_nt(&a_s, &a, &mut z);
            let mut z_ref = z.clone();
            ep.apply(&sample, &mut z);
            let sn: Vec<f64> = sample.iter().map(|&i| norms[i]).collect();
            kernel.apply_block(&mut z_ref, &sn, &norms);
            assert_eq!(z.data(), z_ref.data());
            assert_eq!(ep.flops(2), kernel.epilogue_flops(2, 10));
            let d = ep.diag();
            for (i, &n) in norms.iter().enumerate() {
                assert_eq!(d[i], kernel.apply_scalar(n, n, n));
            }
        }
    }
}
